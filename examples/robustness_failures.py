"""The paper's robustness claim, end to end (Fig. 1 lower row / Fig. 3).

Runs P2PegasosMU under the paper's EXTREME failure model — 50% message drop
+ message delay uniform in [Δ, 10Δ] + churn with 90% online (lognormal
sessions, state retained offline) — and shows that convergence slows by
roughly the predicted constant factor (≈ mean delay × 1/(1-drop)) but does
NOT stall or diverge.

    PYTHONPATH=src python examples/robustness_failures.py --cycles 200
    PYTHONPATH=src python examples/robustness_failures.py --trace out.json
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.core.simulation import run_simulation
from repro.core.telemetry import Telemetry
from repro.data.synthetic import paper_dataset

SCENARIOS = {
    "none": {},
    "drop .5": dict(drop_prob=0.5),
    "delay U[Δ,10Δ]": dict(delay_max_cycles=10),
    "churn 90%": dict(online_fraction=0.9),
    "all failures": dict(drop_prob=0.5, delay_max_cycles=10,
                         online_fraction=0.9),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cycles", type=int, default=200)
    ap.add_argument("--dataset", default="spambase")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="arm one telemetry object across the whole sweep "
                         "(bitwise invisible): print the per-phase span "
                         "summary and export a Chrome trace — the metric "
                         "streams concatenate the five scenario runs in "
                         "sweep order")
    args = ap.parse_args()

    # one Telemetry across the sweep: spans share a wall-clock origin and
    # streams concatenate per run (the supported multi-run arming mode)
    tel = Telemetry(label=f"robustness sweep {args.dataset}") \
        if args.trace else None

    X, y, Xt, yt, cfg = paper_dataset(args.dataset)
    print(f"dataset={cfg.name}: N={X.shape[0]}, extreme-failure sweep, "
          f"P2PegasosMU, {args.cycles} cycles\n")
    print(f"{'scenario':>16} {'err(fresh)':>11} {'err(voted)':>11}")
    for label, kw in SCENARIOS.items():
        c = dataclasses.replace(cfg, variant="mu", **kw)
        res = run_simulation(c, X, y, Xt, yt, cycles=args.cycles,
                             eval_every=args.cycles, seed=0, telemetry=tel)
        print(f"{label:>16} {res.err_fresh[-1]:>11.4f} "
              f"{res.err_voted[-1]:>11.4f}")

    if tel is not None:
        print("\n" + tel.phase_report())
        fp = tel.export_chrome_trace(args.trace)
        print(f"trace written to {fp} — open at https://ui.perfetto.dev "
              f"or summarize with: python tools/trace_report.py {fp}")


if __name__ == "__main__":
    main()
