"""Gossip learning at a million nodes — 100× beyond the paper's PeerSim runs.

The paper's convergence claims are population-level: merged models random-
walk over *many* nodes, and related work ("On the Limit Performance of
Floating Gossip") analyzes exactly the N→∞ regime. The sharded engine makes
that regime reachable on one machine: the control plane (routing, failures)
is resolved host-side per chunk, the data plane (merge+update+cache) runs as
one ``lax.scan`` between eval points.

    PYTHONPATH=src python examples/million_nodes.py                # 10^6 nodes
    PYTHONPATH=src python examples/million_nodes.py --nodes 100000 # smaller
    PYTHONPATH=src python examples/million_nodes.py --scenario sparse

``--scenario sparse`` runs the paper's Fig. 5–7 robustness regime (80% drop,
10% online, 10Δ delays): only a fraction of a percent of the population
receives per cycle, and the engine's occupancy-based packing switches to the
delivery-proportional ``compact_all`` path — the printed compaction report
shows the chunk modes and receiver occupancy the router observed. Any key
of ``FAILURE_SCENARIOS`` is also accepted directly.

``--fault-model sign_flip --byzantine-frac 0.1 --defense norm_clip`` layers
the adversarial regime (repro.core.faults) on top: a seed-chosen Byzantine
subset corrupts every send, the receive path screens each payload per merge
round, and the run prints the engine's fault counters (corrupted sends,
gated + clipped receives). Measured trade-offs: BENCH_robustness.json.

Expected: the error curve tracks the paper's Fig. 1 shape — at fixed cycle
count the per-cycle error is population-size-invariant (each node still sees
one message per cycle), so convergence *speed per cycle* matches the 10^4
runs while the system processes 100× the node-cycles.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

# config-layer import only (no jax): the scenario choices come from the
# single registry in repro.configs.gossip_linear instead of a local copy
from repro.configs.gossip_linear import FAILURE_SCENARIOS

# short spellings for the most-used operating points; every registered
# FAILURE_SCENARIOS key is also accepted verbatim
SCENARIO_ALIASES = {"sparse": "sparse-d0.8-o0.1"}
SCENARIO_CHOICES = sorted(SCENARIO_ALIASES) + sorted(FAILURE_SCENARIOS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1_000_000)
    ap.add_argument("--cycles", type=int, default=50)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--scenario", choices=SCENARIO_CHOICES, default=None,
                    help="failure operating point: clean (no failures), "
                         "extreme (drop=0.5, 10 cycle delays, 90%% online), "
                         "sparse (alias for sparse-d0.8-o0.1 — the "
                         "delivery-proportional compact_all regime), or any "
                         "registered FAILURE_SCENARIOS key")
    ap.add_argument("--extreme", action="store_true",
                    help="alias for --scenario extreme")
    ap.add_argument("--wire-dtype",
                    choices=["f32", "bf16", "f16", "int8", "int8_sr",
                             "int4", "int4_ef", "ternary", "ternary_ef"],
                    default=None,
                    help="wire codec for the transmitted models (and the "
                         "in-flight buffer — the engine's dominant memory): "
                         "float casts, affine int8, packed int4 (2 "
                         "codes/byte) or base-3 ternary (5 codes/byte); "
                         "the _ef variants add sender-side error-feedback "
                         "residuals. Merge math stays f32")
    ap.add_argument("--fault-model",
                    choices=["sign_flip", "amplify", "zero",
                             "random_payload", "stale_replay", "bitflip"],
                    default=None,
                    help="adversarial fault model (repro.core.faults): a "
                         "seed-chosen Byzantine subset corrupts every model "
                         "it sends (bitflip corrupts the encoded wire "
                         "bytes instead). Default: no fault injection")
    ap.add_argument("--byzantine-frac", type=float, default=0.1,
                    help="fraction of nodes applying the fault "
                         "(only with --fault-model; default 0.1)")
    ap.add_argument("--defense",
                    choices=["none", "norm_clip", "cosine_gate"],
                    default="none",
                    help="receive-side payload screen, applied per merge "
                         "round: norm_clip rescales oversized payloads to "
                         "a multiple of the receiver's own norm, "
                         "cosine_gate rejects anti-aligned ones")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="arm telemetry (bitwise invisible to the run): "
                         "print the per-phase span summary and export a "
                         "Chrome trace with the per-cycle metric streams "
                         "to this path (view at ui.perfetto.dev; "
                         "summarize with tools/trace_report.py)")
    args = ap.parse_args()
    scenario = args.scenario or ("extreme" if args.extreme else "clean")
    scenario = SCENARIO_ALIASES.get(scenario, scenario)

    from repro.configs.gossip_linear import (GossipLinearConfig,
                                             with_failure_scenario)
    from repro.core.simulation import message_wire_bytes, run_simulation
    from repro.core.wire_codec import get_codec
    from repro.data.synthetic import make_linear_dataset

    n, d = args.nodes, args.dim
    wire = None if args.wire_dtype == "f32" else args.wire_dtype
    codec = get_codec(wire)
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, n + 1000, d, noise=0.07, separation=2.5)
    cfg = with_failure_scenario(
        GossipLinearConfig(
            name=f"million-{n}", dim=d, n_nodes=n, n_test=1000,
            class_ratio=(1, 1), lam=1e-3, variant="mu", cache_size=4,
            wire_dtype=wire, fault_model=args.fault_model,
            byzantine_frac=args.byzantine_frac if args.fault_model else 0.0,
            defense=args.defense),
        scenario)

    print(f"N={n:,} peers (one record each), d={d}, "
          f"{args.cycles} cycles, variant=MU, "
          f"wire={codec.name} ({message_wire_bytes(d, wire)} B/msg"
          f"{', error feedback' if codec.ef else ''}), "
          f"scenario={scenario} "
          f"(drop={cfg.drop_prob}, delay<= {cfg.delay_max_cycles} cycles, "
          f"online={cfg.online_fraction:.0%})")
    if cfg.fault_model:
        print(f"adversary: {cfg.fault_model} from "
              f"{cfg.byzantine_frac:.0%} Byzantine nodes, "
              f"defense={cfg.defense}")
    tel = None
    if args.trace:
        from repro.core.telemetry import Telemetry
        tel = Telemetry(label=f"million_nodes N={n} {scenario}")
    t0 = time.time()
    res = run_simulation(cfg, X[:n], y[:n], X[n:], y[n:],
                         cycles=args.cycles,
                         eval_every=max(args.cycles // 5, 1), seed=0,
                         engine="sharded", telemetry=tel)
    dt = time.time() - t0
    print(f"\n  {'cycle':>6} {'err(fresh)':>11} {'err(voted)':>11}")
    for cyc, ef, ev in zip(res.cycles, res.err_fresh, res.err_voted):
        print(f"  {cyc:>6} {ef:>11.4f} {ev:>11.4f}")
    print(f"\n{n * args.cycles / dt:,.0f} node-cycles/sec "
          f"({dt:.1f}s wall; {res.sent_total:,} messages sent, "
          f"{res.delivered_total:,} delivered, {res.lost_total:,} lost)")
    print(f"bandwidth: {res.wire_bytes_total / 1e9:.3f} GB on the wire "
          f"({message_wire_bytes(d, wire)} B/msg), "
          f"in-flight payload buffer {res.buf_payload_bytes / 1e6:.1f} MB")
    if codec.ef:
        print(f"error feedback: terminal EF-residual norm "
              f"{res.ef_residual_norm:.4f} (RMS per-node L2; the residual "
              f"each sender still owes the wire)")
    if cfg.fault_model:
        fs = res.fault_stats
        print(f"fault stats: {fs['corrupted']:,} corrupted sends, "
              f"{fs['gated']:,} receives gated, "
              f"{fs['clipped']:,} receives clipped by {cfg.defense}")

    # compaction observability: what the router saw, what the engine chose
    dpc = np.asarray(res.delivered_per_cycle, dtype=np.float64)
    comp = res.compaction
    modes = comp.get("chunk_modes", {})
    print(f"delivered/cycle: mean {dpc.mean():,.0f}, max {dpc.max():,.0f} "
          f"({dpc.mean() / n:.2%} of the population)")
    print("chunk packing: "
          + ", ".join(f"{k}={v}" for k, v in modes.items() if v)
          + f"; round-1 occupancy mean {comp['round1_occupancy_mean']:.2%} "
          f"max {comp['round1_occupancy_max']:.2%}, multi-receive mean "
          f"{comp['multi_occupancy_mean']:.2%}")

    if tel is not None:
        print("\n" + tel.phase_report())
        fp = tel.export_chrome_trace(args.trace)
        print(f"trace written to {fp} — open at https://ui.perfetto.dev "
              f"or summarize with: python tools/trace_report.py {fp}")


if __name__ == "__main__":
    main()
