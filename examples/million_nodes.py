"""Gossip learning at a million nodes — 100× beyond the paper's PeerSim runs.

The paper's convergence claims are population-level: merged models random-
walk over *many* nodes, and related work ("On the Limit Performance of
Floating Gossip") analyzes exactly the N→∞ regime. The sharded engine makes
that regime reachable on one machine: the control plane (routing, failures)
is resolved host-side per chunk, the data plane (merge+update+cache) runs as
one ``lax.scan`` between eval points.

    PYTHONPATH=src python examples/million_nodes.py                # 10^6 nodes
    PYTHONPATH=src python examples/million_nodes.py --nodes 100000 # smaller

Expected: the error curve tracks the paper's Fig. 1 shape — at fixed cycle
count the per-cycle error is population-size-invariant (each node still sees
one message per cycle), so convergence *speed per cycle* matches the 10^4
runs while the system processes 100× the node-cycles.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1_000_000)
    ap.add_argument("--cycles", type=int, default=50)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--extreme", action="store_true",
                    help="paper's extreme failure scenario "
                         "(drop=0.5, delay up to 10 cycles, 90%% online)")
    ap.add_argument("--wire-dtype",
                    choices=["bf16", "f16", "int8", "int8_sr"], default=None,
                    help="quantize payloads on the wire (and the in-flight "
                         "buffer — the engine's dominant memory) to this "
                         "dtype; merge math stays f32")
    args = ap.parse_args()

    from repro.configs.gossip_linear import GossipLinearConfig
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset

    n, d = args.nodes, args.dim
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, n + 1000, d, noise=0.07, separation=2.5)
    cfg = GossipLinearConfig(
        name=f"million-{n}", dim=d, n_nodes=n, n_test=1000,
        class_ratio=(1, 1), lam=1e-3, variant="mu", cache_size=4,
        drop_prob=0.5 if args.extreme else 0.0,
        delay_max_cycles=10 if args.extreme else 1,
        online_fraction=0.9 if args.extreme else 1.0,
        wire_dtype=args.wire_dtype)

    print(f"N={n:,} peers (one record each), d={d}, "
          f"{args.cycles} cycles, variant=MU, "
          f"wire={args.wire_dtype or 'f32'}, "
          f"{'extreme failures' if args.extreme else 'no failures'}")
    t0 = time.time()
    res = run_simulation(cfg, X[:n], y[:n], X[n:], y[n:],
                         cycles=args.cycles,
                         eval_every=max(args.cycles // 5, 1), seed=0,
                         engine="sharded")
    dt = time.time() - t0
    print(f"\n  {'cycle':>6} {'err(fresh)':>11} {'err(voted)':>11}")
    for cyc, ef, ev in zip(res.cycles, res.err_fresh, res.err_voted):
        print(f"  {cyc:>6} {ef:>11.4f} {ev:>11.4f}")
    print(f"\n{n * args.cycles / dt:,.0f} node-cycles/sec "
          f"({dt:.1f}s wall; {res.sent_total:,} messages sent, "
          f"{res.delivered_total:,} delivered, {res.lost_total:,} lost)")
    print(f"bandwidth: {res.wire_bytes_total / 1e9:.3f} GB on the wire, "
          f"in-flight payload buffer {res.buf_payload_bytes / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
