"""Quickstart: the paper's protocol in ~30 lines of driver code.

Runs gossip learning (P2PegasosMU) on the Spambase surrogate — 4,140 peers,
ONE data record each — and prints the 0-1 test error of the freshest and the
voted (cache-of-10) local predictions every few cycles, next to the
independent-random-walk baseline (P2PegasosRW = sequential Pegasos).

    PYTHONPATH=src python examples/quickstart.py [--cycles 120]

Expected: MU converges orders of magnitude faster than RW (the paper's
headline Fig. 1 claim); voting helps RW a lot and MU a little (Fig. 3).

``--engine sharded`` runs the same protocol on the sharded mega-population
engine (``lax.scan`` over chunks of cycles, host-side routing, optional
device-mesh node sharding) — same seed, same curves, built for N up to 10^6
(see examples/million_nodes.py).
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.core.simulation import run_simulation
from repro.data.synthetic import paper_dataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cycles", type=int, default=120)
    ap.add_argument("--dataset", default="spambase",
                    choices=["spambase", "reuters", "malicious-urls"])
    ap.add_argument("--engine", default="reference",
                    choices=["reference", "sharded"])
    args = ap.parse_args()

    X, y, Xt, yt, cfg = paper_dataset(args.dataset)
    print(f"dataset={cfg.name}: N={X.shape[0]} peers (one record each), "
          f"d={X.shape[1]}, test={Xt.shape[0]}")

    for variant in ("rw", "mu"):
        c = dataclasses.replace(cfg, variant=variant)
        res = run_simulation(c, X, y, Xt, yt, cycles=args.cycles,
                             eval_every=max(args.cycles // 8, 1), seed=0,
                             engine=args.engine)
        print(f"\nP2Pegasos{variant.upper()}")
        print(f"  {'cycle':>6} {'err(fresh)':>11} {'err(voted)':>11} "
              f"{'model-similarity':>17}")
        for cyc, ef, ev, sim in zip(res.cycles, res.err_fresh,
                                    res.err_voted, res.similarity):
            print(f"  {cyc:>6} {ef:>11.4f} {ev:>11.4f} {sim:>17.4f}")


if __name__ == "__main__":
    main()
