"""End-to-end driver: gossip-SGD vs all-reduce on a transformer LM.

The paper's protocol transposed to the datacenter (DESIGN.md Layer B): each
data-parallel replica is a *peer*; instead of all-reducing gradients every
step, a replica takes a local AdamW step and parameter-averages with ONE
partner per step (CreateModelMU with a hypercube partner schedule). This
script trains the same model both ways on the same synthetic LM stream and
prints loss + peer-disagreement so the merge DAG's consensus is visible.

Default is a CPU-sized qwen3-family model; ``--size 100m`` selects the
~100M-parameter configuration (the deliverable-scale run — give it time on
a 1-core host, or a real accelerator).

    PYTHONPATH=src python examples/gossip_lm_training.py --steps 60
    PYTHONPATH=src python examples/gossip_lm_training.py --size 100m --steps 300
"""
from __future__ import annotations

import argparse

from repro.launch.train import train

SIZES = {
    # d_model, layers  (vocab 2048, qwen3 family: GQA + qk-norm + SwiGLU)
    "tiny": (256, 2),      # ~ 5M params, seconds/step on 1 CPU core
    "20m": (512, 4),       # ~20M
    "100m": (1024, 8),     # ~105M — the deliverable-scale end-to-end run
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--peers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--merge", default="mu", choices=["mu", "um", "rw"])
    ap.add_argument("--schedule", default="hypercube",
                    choices=["hypercube", "ring", "random"])
    ap.add_argument("--skip-allreduce", action="store_true")
    args = ap.parse_args()
    d_model, layers = SIZES[args.size]

    print("=== gossip (one ppermute-hop model exchange per step) ===")
    _, hist_g = train("qwen3-1.7b", reduced=True, steps=args.steps,
                      batch=args.batch, seq_len=args.seq_len, dist="gossip",
                      n_peers=args.peers, merge=args.merge,
                      schedule=args.schedule, d_model=d_model, layers=layers)

    if not args.skip_allreduce:
        print("\n=== all-reduce baseline (conventional DP) ===")
        _, hist_a = train("qwen3-1.7b", reduced=True, steps=args.steps,
                          batch=args.batch, seq_len=args.seq_len,
                          dist="allreduce", d_model=d_model, layers=layers)
        print("\nstep   gossip-loss  allreduce-loss  peer-disagreement")
        for (s, lg, dis), (_, la, _) in zip(hist_g, hist_a):
            print(f"{s:5d}  {lg:11.4f}  {la:14.4f}  {dis:.3e}")


if __name__ == "__main__":
    main()
