"""Batched serving example: prefill + greedy decode with a KV/state cache.

Serves reduced variants of two assigned architectures whose decode paths are
structurally different — qwen3 (GQA KV cache, ring-buffer addressed) and
mamba2 (O(1) SSM recurrent state; the reason the ``long_500k`` workload is
native for that family) — through the same ``DecodeServer``.

    PYTHONPATH=src python examples/serve_batched.py --batch 4 --decode-steps 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config, reduced_config
from repro.launch.serve import DecodeServer
from repro.models import transformer as T


def serve_one(arch: str, *, batch: int, prompt_len: int, steps: int,
              max_len: int) -> None:
    cfg = reduced_config(get_config(arch), vocab=2048)
    params = T.init_params(jax.random.key(0), cfg)
    srv = DecodeServer(cfg, params, batch=batch, max_len=max_len)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (batch, prompt_len))

    t0 = time.time()
    logits, start = srv.prefill(prompts)
    t1 = time.time()
    toks = srv.decode(logits, start, steps)
    t2 = time.time()
    cache_kind = "SSM state" if cfg.family == "ssm" else "KV cache"
    print(f"[{arch}] ({cfg.family}, {cache_kind}) batch={batch}: "
          f"prefill {prompt_len} tok {t1-t0:.2f}s, "
          f"decode {steps} tok {t2-t1:.2f}s "
          f"({steps*batch/(t2-t1):.1f} tok/s)")
    print(f"  sample continuation: {toks[0][:12].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--archs", default="qwen3-1.7b,mamba2-780m")
    args = ap.parse_args()
    for arch in args.archs.split(","):
        serve_one(arch, batch=args.batch, prompt_len=args.prompt_len,
                  steps=args.decode_steps, max_len=args.max_len)


if __name__ == "__main__":
    main()
