"""Batched serving example: answer live queries from a running gossip run.

The serving tier end to end (docs/SERVING.md): a gossip protocol runs
underneath (either engine), a ``GossipServer`` adopts a fresh
``QuerySnapshot`` at every eval point, and a stream of feature-vector
queries — drawn from the held-out test set, so every answer has a label —
is batched up and answered with the cache majority vote (Algorithm 4 /
Eq. 8 as a service). Prints queries/s, p50/p99 batch latency and the
fresh-vs-voted accuracy of the *served* answers.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --engine sharded \
        --nodes 100000 --scenario extreme --wire-dtype int4 --use-kernel
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.gossip_linear import FAILURE_SCENARIOS

# same short spellings as examples/million_nodes.py; every registered
# FAILURE_SCENARIOS key is also accepted verbatim
SCENARIO_ALIASES = {"sparse": "sparse-d0.8-o0.1"}
SCENARIO_CHOICES = sorted(SCENARIO_ALIASES) + sorted(FAILURE_SCENARIOS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=10_000)
    ap.add_argument("--cycles", type=int, default=50)
    ap.add_argument("--dim", type=int, default=57)
    ap.add_argument("--engine", choices=["reference", "sharded"],
                    default="sharded")
    ap.add_argument("--scenario", choices=SCENARIO_CHOICES, default="clean",
                    help="failure operating point the protocol runs under "
                         "while serving: clean, extreme (drop=0.5, 10 cycle "
                         "delays, 90%% online), sparse (alias for "
                         "sparse-d0.8-o0.1), or any FAILURE_SCENARIOS key")
    ap.add_argument("--wire-dtype",
                    choices=["f32", "bf16", "f16", "int8", "int8_sr",
                             "int4", "int4_ef", "ternary", "ternary_ef"],
                    default=None,
                    help="wire codec for the protocol's transmitted models "
                         "(serving reads snapshots after decode; merge math "
                         "stays f32)")
    ap.add_argument("--batch", type=int, default=256,
                    help="serving batch size (one compiled predict "
                         "signature; tail batches are padded to it)")
    ap.add_argument("--queries", type=int, default=2048,
                    help="queries submitted per eval-point snapshot")
    ap.add_argument("--policy", choices=["uniform", "round_robin"],
                    default="uniform",
                    help="node-assignment policy: which node answers each "
                         "query")
    ap.add_argument("--use-kernel", action="store_true",
                    help="answer with the fused Pallas voted_predict_batched "
                         "path (interpret mode off-TPU) instead of the jnp "
                         "einsum path; answers are bitwise identical")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="arm telemetry across the protocol AND the server "
                         "(bitwise invisible): print the per-phase span "
                         "summary — including snapshot_adopt/serve_batch "
                         "serving spans and the batch-latency histogram — "
                         "and export a Chrome trace to this path")
    args = ap.parse_args()
    scenario = SCENARIO_ALIASES.get(args.scenario, args.scenario)

    from repro.configs.gossip_linear import (GossipLinearConfig,
                                             with_failure_scenario)
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset
    from repro.launch.gossip_serve import GossipServer

    n, d = args.nodes, args.dim
    wire = None if args.wire_dtype == "f32" else args.wire_dtype
    n_test = max(args.queries, 512)
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, n + n_test, d, noise=0.07,
                               separation=2.5)
    cfg = with_failure_scenario(
        GossipLinearConfig(name=f"serve-{n}", dim=d, n_nodes=n,
                           n_test=n_test, class_ratio=(1, 1), lam=1e-3,
                           variant="mu", cache_size=4, wire_dtype=wire),
        scenario)
    X_test, y_test = X[n:], y[n:]

    tel = None
    if args.trace:
        from repro.core.telemetry import Telemetry
        tel = Telemetry(label=f"serve_batched N={n} {scenario}")
    srv = GossipServer(batch_size=args.batch, policy=args.policy,
                       use_kernel=args.use_kernel, telemetry=tel)
    qrng = np.random.default_rng(7)
    labels = []

    def serve_hook(cycle, snapshot):
        srv.serve_hook(cycle, snapshot)
        idx = qrng.integers(0, n_test, args.queries)
        labels.append(y_test[idx])
        srv.submit(X_test[idx])

    print(f"N={n:,} peers, d={d}, {args.cycles} cycles, "
          f"engine={args.engine}, scenario={scenario}, "
          f"wire={wire or 'f32'}; serving {args.queries} queries per eval "
          f"point in batches of {args.batch} "
          f"({'Pallas kernel' if args.use_kernel else 'jnp einsum'} path, "
          f"{args.policy} assignment)")
    res = run_simulation(cfg, X[:n], y[:n], X_test, y_test,
                         cycles=args.cycles,
                         eval_every=max(args.cycles // 5, 1), seed=0,
                         engine=args.engine, serve_hook=serve_hook,
                         telemetry=tel)
    srv.flush()

    y_served = np.concatenate(labels)
    acc_voted = float(np.mean(srv.answers() == y_served))
    acc_fresh = float(np.mean(srv.answers_fresh() == y_served))
    s = srv.stats()

    print(f"\n  {'cycle':>6} {'err(fresh)':>11} {'err(voted)':>11} "
          f"{'served batches':>15}")
    per_cycle = {}
    for b in srv.batches:
        per_cycle[b.cycle] = per_cycle.get(b.cycle, 0) + 1
    for cyc, ef, ev in zip(res.cycles, res.err_fresh, res.err_voted):
        print(f"  {cyc:>6} {ef:>11.4f} {ev:>11.4f} "
              f"{per_cycle.get(int(cyc), 0):>15}")
    print(f"\nserved {s.queries:,} queries in {s.batches} batches: "
          f"{s.queries_per_sec:,.0f} queries/s, "
          f"p50 {s.p50_latency_s * 1e3:.2f} ms / "
          f"p99 {s.p99_latency_s * 1e3:.2f} ms per batch")
    print(f"accuracy of served answers: voted {acc_voted:.4f} "
          f"vs fresh {acc_fresh:.4f} "
          f"(voted - fresh = {acc_voted - acc_fresh:+.4f})")

    if tel is not None:
        print("\n" + tel.phase_report())
        fp = tel.export_chrome_trace(args.trace)
        print(f"trace written to {fp} — open at https://ui.perfetto.dev "
              f"or summarize with: python tools/trace_report.py {fp}")


if __name__ == "__main__":
    main()
