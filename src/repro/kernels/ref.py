"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pegasos_update_ref(w, t, x, y, lam: float):
    """Population Pegasos step. w,x: (N, d); t: (N,); y: (N,)."""
    t_new = t + 1
    eta = 1.0 / (lam * t_new.astype(jnp.float32))
    margin = y * jnp.sum(w * x, axis=-1)
    decay = (1.0 - eta * lam)[:, None]
    upd = jnp.where((margin < 1.0)[:, None], (eta * y)[:, None] * x, 0.0)
    return decay * w + upd, t_new


def merge_update_ref(w1, t1, w2, t2, x, y, lam: float):
    """Fused MU hot path: Pegasos-update(merge(m1, m2)) (Algorithms 2+3)."""
    w = (w1 + w2) / 2.0
    t = jnp.maximum(t1, t2)
    return pegasos_update_ref(w, t, x, y, lam)


def attention_ref(q, k, v, *, causal: bool = True, window=None, scale=None):
    """Masked softmax attention with GQA.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0.
    Returns (B, Sq, H, hd) in q.dtype; softmax in f32."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    offset = k.shape[1] - Sq  # decode-style alignment when Sk > Sq
    diff = (qpos + offset) - kpos
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        mask &= diff < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v.astype(jnp.float32)).astype(q.dtype)
