"""Pallas TPU kernel: fused MERGE + Pegasos update — the MU hot path.

CREATEMODELMU (Algorithm 2) is ``update(merge(m1, m2))``: executed naively
that is two full passes over the model vectors (average; then update). The
kernel fuses both into one VMEM-resident pass: HBM traffic drops from
(4 reads + 2 writes) to (3 reads + 1 write) per model pair — a 1.5× cut on
the bandwidth-bound protocol step. t = max(t1, t2) + 1 is carried along.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pegasos_update import BLK_N, LANE, _pad_to


def _merge_update_kernel(w1_ref, t1_ref, w2_ref, t2_ref, x_ref, y_ref,
                         w_out, t_out, *, lam: float):
    w = (w1_ref[...].astype(jnp.float32) + w2_ref[...].astype(jnp.float32)) / 2.0
    t = jnp.maximum(t1_ref[...], t2_ref[...]) + 1
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)

    eta = 1.0 / (lam * t.astype(jnp.float32))
    margin = y * jnp.sum(w * x, axis=-1)
    decay = (1.0 - eta * lam)[:, None]
    upd = jnp.where((margin < 1.0)[:, None], (eta * y)[:, None] * x, 0.0)
    w_out[...] = (decay * w + upd).astype(w_out.dtype)
    t_out[...] = t


@functools.partial(jax.jit, static_argnames=("lam", "interpret"))
def merge_update(w1, t1, w2, t2, x, y, *, lam: float, interpret: bool = False):
    """Fused update(merge((w1,t1), (w2,t2))) with local example (x, y)."""
    n, d = w1.shape
    pads = lambda a: _pad_to(_pad_to(a, LANE, 1), BLK_N, 0)
    pad1 = lambda a: _pad_to(a, BLK_N, 0)
    w1p, w2p, xp = pads(w1), pads(w2), pads(x)
    t1p, t2p, yp = pad1(t1), pad1(t2), pad1(y)
    np_, dp = w1p.shape
    grid = (np_ // BLK_N,)
    vec = lambda: pl.BlockSpec((BLK_N, dp), lambda i: (i, 0))
    sca = lambda: pl.BlockSpec((BLK_N,), lambda i: (i,))

    w_new, t_new = pl.pallas_call(
        functools.partial(_merge_update_kernel, lam=lam),
        grid=grid,
        in_specs=[vec(), sca(), vec(), sca(), vec(), sca()],
        out_specs=[vec(), sca()],
        out_shape=[
            jax.ShapeDtypeStruct((np_, dp), w1.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(w1p, t1p, w2p, t2p, xp, yp)
    return w_new[:n, :d], t_new[:n]
