"""Pallas TPU kernel: fused gossip-cycle receive path (deliver -> merge ->
update -> cache-write).

One gossip cycle delivers up to K messages to every node; for each the
protocol runs ``modelCache.add(createModel(m, lastModel)); lastModel <- m``
(Algorithm 1). Executed as separate XLA ops that is, per round: read the
message and the last model, write the merged+updated model, then read-modify-
write the whole (N, C, d) cache — the cache traffic alone is C× the model
traffic. This kernel keeps a node block's last model, its K winning messages,
its local example AND its cache slice resident in VMEM and applies all K
sequential receives in one pass: HBM traffic per node drops from
O(K·(C+3)·d) to the minimal read-once/write-once O((K+C+2)·d).

Supports the three CREATEMODEL variants (RW / MU / UM, Algorithm 2) with the
Pegasos update — the paper's P2Pegasos hot path. Message operands may arrive
in any wire codec (f32/bf16/f16 upcast in VMEM; affine int8 dequantized
in VMEM from per-message f16 scale/zero-point; packed int4 nibbles and
base-3 ternary trits unpacked AND dequantized in VMEM from a per-message
f16 scale), so HBM message traffic is paid at wire precision — half a byte
(int4) or a fifth of a byte (ternary) per coefficient. The pure-jnp oracle
is ``repro.core.simulation.apply_receives``; parity is tested in interpret
mode on CPU (tests/test_sharded_engine.py, tests/test_wire_codec.py).

This module also holds the send-side counterpart, ``quantize_send``: the
per-message encode of any quantized wire codec
(``repro.core.wire_codec``) as one fused pass per node block — affine int8
with the "int8_sr" stochastic-rounding uniform generated *in kernel* by an
op-exact threefry-2x32 (bitwise equal to the ``jax.random.uniform`` draw
of the jnp path, which the engines' parity contract requires), and the
packed sub-4-bit codecs with the code packing and the error-feedback
residual update (``(w + ef) - decode(encode(w + ef))``) fused into the
same pass (tests/test_send_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# The threefry-2x32 cipher, the counter-at-position uniform it feeds and
# the sub-4-bit pack/unpack helpers live in repro.core.wire_codec (shared
# with the jnp codecs and the compacted send path); they are pure jnp
# integer ops, so they trace inside the kernel body too — and integer ops
# are exact, so kernel and jnp paths agree bitwise by construction.
#
# Why not ``pltpu.prng_random_bits``: the TPU-native PRNG is a *different*
# generator — its stream cannot match the ``jax.random.uniform`` draw the
# reference engine (and the jnp ``quantize_wire`` path) consume, and the
# engines' parity contract requires bitwise-identical stochastic-rounding
# noise everywhere. Threefry is 20 rounds of uint32 add/rotate/xor on the
# VPU — cheap relative to the (N, d) HBM traffic this kernel saves.
from repro.core.faults import apply_defense
from repro.core.wire_codec import (get_codec, symmetric_scale,
                                   unpack_int4, unpack_ternary)
from repro.core.wire_codec import uniform_at as _uniform_at
from repro.kernels.pegasos_update import BLK_N, LANE, _pad_to

C_SUB = 8          # pad the cache axis to the f32 sublane multiple
SEND_BLK = 32      # node block of the send kernel (int8 min sublane tile)


def _pegasos(w, t, x, y, lam: float):
    """(BLK, d) Pegasos step in f32 — mirrors Algorithm 3 lines 1-10."""
    t = t + 1
    eta = 1.0 / (lam * t.astype(jnp.float32))
    margin = y * jnp.sum(w * x, axis=-1)
    decay = (1.0 - eta * lam)[:, None]
    upd = jnp.where((margin < 1.0)[:, None], (eta * y)[:, None] * x, 0.0)
    return decay * w + upd, t


def _decode_msg(raw, msc, mzp, dp: int, wire_mode: str):
    """In-VMEM wire decode of one round's message block.

    ``raw``: the (BLK, P) payload block as stored (float cast, int8 codes,
    or packed uint8 bytes); returns the (BLK, dp) f32 coefficients. The
    float expressions repeat ``wire_codec``'s decode op order exactly
    (cast-then-multiply-then-add), and the sub-4-bit unpacks ARE the shared
    ``unpack_int4``/``unpack_ternary`` helpers (integer-exact), so kernel
    and jnp paths agree bitwise. Packed payload pad bytes decode to finite
    garbage in lanes >= d, which the caller's padding contract discards."""
    if wire_mode == "float":
        return raw.astype(jnp.float32)
    if wire_mode == "affine8":
        return (raw.astype(jnp.float32) * msc.astype(jnp.float32)[:, None]
                + mzp.astype(jnp.float32)[:, None])
    unpack = {"int4": unpack_int4, "ternary": unpack_ternary}[wire_mode]
    q = unpack(raw, dp)                            # (BLK, dp) int32 codes
    return q.astype(jnp.float32) * msc.astype(jnp.float32)[:, None]


def _cycle_kernel(msg_w_ref, msg_t_ref, msc_ref, mzp_ref, valid_ref, x_ref,
                  y_ref, last_w_ref, last_t_ref, cw_ref, ct_ref, ptr_ref,
                  cnt_ref, out_lw, out_lt, out_cw, out_ct, out_ptr, out_cnt,
                  out_gated, out_clipped, *, variant: str, lam: float,
                  c_real: int, k_rounds: int, wire_mode: str = "float",
                  defense: str = "none", d_real: int = 0):
    """``msc_ref``/``mzp_ref`` are the per-message f16 scale/zero-point of
    the quantized wire codecs (None lanes when the codec does not carry
    them): messages stream into VMEM at wire precision and are decoded by
    :func:`_decode_msg` — same expressions (and op order) as the
    ``repro.core.wire_codec`` decoders, so kernel and jnp paths agree.

    ``defense`` runs ``faults.apply_defense`` between the decode and the
    merge of every round (the jnp round-chain placement); its reductions
    mask the padded lanes (``d_real``) to zero, which keeps them bitwise
    equal to the unpadded jnp sums (the ``_pegasos`` margin precedent) —
    required because packed payload pad bytes decode to finite garbage.
    ``out_gated``/``out_clipped`` accumulate the per-node screen counts
    (zeros under ``"none"``)."""
    lw = last_w_ref[...].astype(jnp.float32)       # (BLK, d)
    lt = last_t_ref[...]                           # (BLK,)
    cw = cw_ref[...].astype(jnp.float32)           # (BLK, C_pad, d)
    ct = ct_ref[...]                               # (BLK, C_pad)
    ptr = ptr_ref[...]                             # (BLK,)
    cnt = cnt_ref[...]
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    blk, c_pad = ct.shape
    dp = lw.shape[1]
    real = (lax.broadcasted_iota(jnp.int32, (blk, dp), 1) < d_real
            if defense != "none" else None)
    gated = jnp.zeros((blk,), jnp.int32)
    clipped = jnp.zeros((blk,), jnp.int32)

    for kk in range(k_rounds):
        vm = valid_ref[kk, :] > 0                  # (BLK,) receives this round
        mw = _decode_msg(msg_w_ref[kk, :, :],
                         msc_ref[kk, :] if msc_ref is not None else None,
                         mzp_ref[kk, :] if mzp_ref is not None else None,
                         dp, wire_mode)
        mw, vm, g, cl = apply_defense(defense, mw, vm, lw, real=real)
        gated = gated + g.astype(jnp.int32)
        clipped = clipped + cl.astype(jnp.int32)
        mt = msg_t_ref[kk, :]
        if variant == "mu":                        # update(merge(m, last))
            nw, nt = _pegasos((mw + lw) / 2.0, jnp.maximum(mt, lt), x, y, lam)
        elif variant == "um":                      # merge(update(m), update(last))
            w1, t1 = _pegasos(mw, mt, x, y, lam)
            w2, t2 = _pegasos(lw, lt, x, y, lam)
            nw, nt = (w1 + w2) / 2.0, jnp.maximum(t1, t2)
        else:                                      # rw: update(m)
            nw, nt = _pegasos(mw, mt, x, y, lam)
        # cache_add on the vm subset: one-hot write at slot ptr % C
        slot = ptr % c_real
        onehot = (lax.broadcasted_iota(jnp.int32, (blk, c_pad), 1)
                  == slot[:, None]) & vm[:, None]
        cw = jnp.where(onehot[:, :, None], nw[:, None, :], cw)
        ct = jnp.where(onehot, nt[:, None], ct)
        ptr = ptr + vm.astype(jnp.int32)
        cnt = jnp.minimum(cnt + vm.astype(jnp.int32), c_real)
        # lastModel <- received model
        lw = jnp.where(vm[:, None], mw, lw)
        lt = jnp.where(vm, mt, lt)

    out_lw[...] = lw.astype(out_lw.dtype)
    out_lt[...] = lt
    out_cw[...] = cw.astype(out_cw.dtype)
    out_ct[...] = ct
    out_ptr[...] = ptr
    out_cnt[...] = cnt
    out_gated[...] = gated
    out_clipped[...] = clipped


def _kernel_no_meta(msg_w_ref, msg_t_ref, valid_ref, *rest, **kw):
    """Adapter for float payloads: no scale/zero-point operands."""
    _cycle_kernel(msg_w_ref, msg_t_ref, None, None, valid_ref, *rest, **kw)


def _kernel_scale_only(msg_w_ref, msg_t_ref, msc_ref, valid_ref, *rest,
                       **kw):
    """Adapter for the packed symmetric codecs: scale lane, no zero-point."""
    _cycle_kernel(msg_w_ref, msg_t_ref, msc_ref, None, valid_ref, *rest,
                  **kw)


def _wire_mode(wire, msg_scale, msg_zp) -> str:
    """The kernel's static decode mode for a wire-codec name (legacy
    callers that pass scale/zero-point without a name mean affine int8;
    a scale WITHOUT a name or zero-point is ambiguous — the packed codecs
    must name themselves, so silently decoding as float would corrupt the
    merge: refuse instead)."""
    if wire is not None:
        codec = get_codec(wire)
        if not codec.quantized:
            return "float"
        if codec.has_zp:
            return "affine8"
        return "int4" if codec.group == 2 else "ternary"
    if msg_scale is not None and msg_zp is not None:
        return "affine8"
    if msg_scale is not None:
        raise ValueError("msg_scale without msg_zp needs an explicit "
                         "wire= codec name (scale-only codecs are packed)")
    return "float"


@functools.partial(jax.jit, static_argnames=("variant", "lam", "interpret",
                                             "wire", "defense"))
def fused_receive_apply(last_w, last_t, cache_w, cache_t, ptr, count,
                        msg_w, msg_t, valid, x, y, *, msg_scale=None,
                        msg_zp=None, wire=None, variant: str, lam: float,
                        interpret: bool = False, defense: str = "none"):
    """Fused K-receive apply for one cycle.

    last_w, x: (N, d); cache_w: (N, C, d); msg_w: (K, N, P);
    msg_t, valid: (K, N) int32; returns the updated
    (last_w, last_t, cache_w, cache_t, ptr, count, gated, clipped) —
    the trailing (N,) int32 pair counts the messages the static
    ``defense`` screen rejected/rescaled in-kernel (zeros for "none").

    ``msg_w`` may arrive in any wire codec's payload representation (the
    simulator's in-flight buffer under ``cfg.wire_dtype``, named by the
    static ``wire``): bf16/f16 are upcast in VMEM; int8 payloads pass their
    per-message f16 ``msg_scale``/``msg_zp`` (K, N) and are
    affine-dequantized in VMEM; packed int4/ternary payloads (P = the
    codec's packed byte width) pass ``msg_scale`` only and are
    unpacked-and-dequantized in VMEM. Either way HBM message traffic is
    paid at wire precision. The node block widens to the minimum sublane
    tile of the payload dtype (16 for 2-byte, 32 for 1-byte operands)."""
    n, d = last_w.shape
    _, c, _ = cache_w.shape
    k = msg_w.shape[0]
    mode = _wire_mode(wire, msg_scale, msg_zp)
    blk = max(BLK_N, 32 // jnp.dtype(msg_w.dtype).itemsize)

    pad_nd = lambda a: _pad_to(_pad_to(a, LANE, 1), blk, 0)
    pad_n = lambda a: _pad_to(a, blk, 0)
    lw, xp = pad_nd(last_w), pad_nd(x)
    lt, yp = pad_n(last_t), pad_n(y)
    cwp = _pad_to(_pad_to(_pad_to(cache_w, LANE, 2), C_SUB, 1), blk, 0)
    ctp = _pad_to(_pad_to(cache_t, C_SUB, 1), blk, 0)
    ptrp, cntp = pad_n(ptr), pad_n(count)
    mw = _pad_to(_pad_to(msg_w, LANE, 2), blk, 1)
    mt = _pad_to(msg_t, blk, 1)
    vl = _pad_to(valid, blk, 1)
    np_, dp = lw.shape
    cp = cwp.shape[1]
    mp = mw.shape[2]                  # payload width (== dp unless packed)
    if mode in ("int4", "ternary"):
        # every real coefficient lane must be coverable from the padded
        # payload block (codes-per-byte × padded bytes >= padded d)
        assert mp * get_codec(wire).group >= dp, (mp, dp, wire)
    grid = (np_ // blk,)

    vec = pl.BlockSpec((blk, dp), lambda i: (i, 0))
    sca = pl.BlockSpec((blk,), lambda i: (i,))
    kvec = pl.BlockSpec((k, blk, mp), lambda i: (0, i, 0))
    ksca = pl.BlockSpec((k, blk), lambda i: (0, i))
    cvec = pl.BlockSpec((blk, cp, dp), lambda i: (i, 0, 0))
    csca = pl.BlockSpec((blk, cp), lambda i: (i, 0))

    kw = dict(variant=variant, lam=lam, c_real=c, k_rounds=k,
              wire_mode=mode, defense=defense, d_real=d)
    if mode == "affine8":
        kernel = functools.partial(_cycle_kernel, **kw)
        meta_args = (_pad_to(msg_scale, blk, 1), _pad_to(msg_zp, blk, 1))
        meta_specs = [ksca, ksca]
    elif mode in ("int4", "ternary"):
        kernel = functools.partial(_kernel_scale_only, **kw)
        meta_args = (_pad_to(msg_scale, blk, 1),)
        meta_specs = [ksca]
    else:
        kernel = functools.partial(_kernel_no_meta, **kw)
        meta_args = ()
        meta_specs = []

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[kvec, ksca, *meta_specs, ksca, vec, sca, vec, sca, cvec,
                  csca, sca, sca],
        out_specs=[vec, sca, cvec, csca, sca, sca, sca, sca],
        out_shape=[
            jax.ShapeDtypeStruct((np_, dp), last_w.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_, cp, dp), cache_w.dtype),
            jax.ShapeDtypeStruct((np_, cp), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(mw, mt, *meta_args, vl, xp, yp, lw, lt, cwp, ctp, ptrp, cntp)
    lw_n, lt_n, cw_n, ct_n, ptr_n, cnt_n, gated_n, clipped_n = outs
    return (lw_n[:n, :d], lt_n[:n], cw_n[:n, :c, :d], ct_n[:n, :c],
            ptr_n[:n], cnt_n[:n], gated_n[:n], clipped_n[:n])


# ---------------------------------------------------------------------------
# send-side quantization kernel
# ---------------------------------------------------------------------------




def _send_kernel(key_ref, w_ref, q_out, sc_out, zp_out, *, n_real: int,
                 d_real: int, qmax: int, stochastic: bool):
    """Per-message affine int8 quantization of a node block — the same op
    sequence (and order) as ``gossip_optimizer.quantize_wire``, so kernel
    and jnp sends agree bitwise. Padded lanes are masked out of the range
    reduction; padded rows quantize garbage that the caller slices off."""
    w = w_ref[...].astype(jnp.float32)             # (BLK, dp)
    blk, dp = w.shape
    lane = lax.broadcasted_iota(jnp.int32, (blk, dp), 1)
    real = lane < d_real
    f16_max = float(jnp.finfo(jnp.float16).max)
    sat = lambda v: jnp.clip(v, -f16_max, f16_max).astype(jnp.float16)
    lo = jnp.min(jnp.where(real, w, jnp.inf), axis=-1)
    hi = jnp.max(jnp.where(real, w, -jnp.inf), axis=-1)
    zp = sat((hi + lo) * 0.5)
    zpf = zp.astype(jnp.float32)
    scale = sat(jnp.maximum(hi - zpf, zpf - lo) / qmax)
    sf = jnp.where(scale > 0, scale, jnp.float16(1)).astype(jnp.float32)
    u = (w - zpf[:, None]) / sf[:, None]
    if stochastic:
        row = (pl.program_id(0) * blk
               + lax.broadcasted_iota(jnp.int32, (blk, dp), 0))
        noise = _uniform_at(key_ref[0], key_ref[1], row * d_real + lane,
                            n_real * d_real)
        u = jnp.floor(u + noise)
    else:
        u = jnp.round(u)
    q_out[...] = jnp.clip(u, -127, 127).astype(jnp.int8)
    sc_out[...] = scale
    zp_out[...] = zp


def _pack_send_kernel(w_ref, ef_ref, q_out, sc_out, res_out, *, d_real: int,
                      qmax: int, pack, cols: int):
    """Packed symmetric send: symmetric f16 scale over the real lanes,
    round-to-nearest codes, in-kernel packing (the shared ``wire_codec``
    pack helper — integer-exact, so kernel bytes == jnp bytes), and the
    fused error-feedback residual ``x - q·scale`` when ``ef_ref``/
    ``res_out`` are wired. Padded lanes hold zeros (both ``w`` and ``ef``
    pad with 0), so they quantize to code 0 — exactly the pad code of the
    jnp pack — and the packed pad bytes beyond the real width are sliced
    off by the caller."""
    x = w_ref[...].astype(jnp.float32)             # (BLK, dp)
    if ef_ref is not None:
        x = x + ef_ref[...].astype(jnp.float32)
    blk, dp = x.shape
    lane = lax.broadcasted_iota(jnp.int32, (blk, dp), 1)
    real = lane < d_real
    # |pad| = 0 never raises the per-message max, so the masked reduction
    # equals the jnp codec's reduction over exactly the real lanes
    scale, sf = symmetric_scale(jnp.where(real, x, 0.0), qmax)
    q = jnp.clip(jnp.round(x / sf[:, None]), -qmax, qmax).astype(jnp.int32)
    packed = pack(q)                               # (BLK, ceil(dp/group))
    g = packed.shape[-1]
    if g < cols:
        packed = jnp.concatenate(
            [packed, jnp.zeros((blk, cols - g), jnp.uint8)], axis=-1)
    q_out[...] = packed
    sc_out[...] = scale
    if res_out is not None:
        res_out[...] = x - q.astype(jnp.float32) * scale.astype(
            jnp.float32)[:, None]


def _pack_send_ef(w_ref, ef_ref, q_out, sc_out, res_out, **kw):
    _pack_send_kernel(w_ref, ef_ref, q_out, sc_out, res_out, **kw)


def _pack_send_plain(w_ref, q_out, sc_out, **kw):
    _pack_send_kernel(w_ref, None, q_out, sc_out, None, **kw)


@functools.partial(jax.jit, static_argnames=("name", "interpret"))
def quantize_send(w, name: str, key_data=None, ef=None, *,
                  interpret: bool = False):
    """Fused send-side quantization: the wire codec's encode as one Pallas
    pass per node block.

    ``w``: (N, d) f32 fresh models. For the affine int8 codecs returns
    ``(q, scale, zp)`` bitwise equal to ``quantize_wire(w, name, key)`` —
    including the "int8_sr" stochastic-rounding draw, whose threefry
    uniform is generated *inside* the kernel from ``key_data``
    (= ``jax.random.key_data(k_recv)``, the same per-cycle key slot both
    engines use). For the packed sub-4-bit codecs returns
    ``(payload, scale)`` — or ``(payload, scale, resid)`` when ``ef`` (the
    (N, d) f32 error-feedback accumulator) is passed: the kernel encodes
    ``w + ef``, packs the codes in VMEM and emits the EF residual
    ``(w + ef) - decode(...)`` from the same pass, bitwise equal to the
    jnp ``codec.encode``/``decode`` chain (the caller applies the
    send-mask ``where`` to the residual). This closes the last dense f32
    pass of the send path: the jnp encoder materializes the range
    reductions, the scaled quotient and the noise/codes as separate (N, d)
    HBM-resident intermediates, the kernel streams each node block through
    VMEM once and writes the packed codes + f16 scalars."""
    from repro.core.wire_codec import INT8_QMAX

    n, d = w.shape
    codec = get_codec(name)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), LANE, 1), SEND_BLK, 0)
    np_, dp = wp.shape
    grid = (np_ // SEND_BLK,)

    if codec.has_zp:                  # affine int8 family
        if ef is not None:
            raise ValueError(f"{name!r} keeps no error-feedback state — "
                             "ef is only accepted by the _ef codecs")
        stochastic = codec.stochastic
        if stochastic and key_data is None:
            raise ValueError("int8_sr quantization needs key_data")
        kd = (jnp.asarray(key_data, jnp.uint32).reshape(2) if stochastic
              else jnp.zeros((2,), jnp.uint32))

        from jax.experimental.pallas import tpu as pltpu
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((SEND_BLK, dp), lambda i, *_: (i, 0))],
            out_specs=[pl.BlockSpec((SEND_BLK, dp), lambda i, *_: (i, 0)),
                       pl.BlockSpec((SEND_BLK,), lambda i, *_: (i,)),
                       pl.BlockSpec((SEND_BLK,), lambda i, *_: (i,))])
        q, sc, zp = pl.pallas_call(
            functools.partial(_send_kernel, n_real=n, d_real=d,
                              qmax=INT8_QMAX, stochastic=stochastic),
            grid_spec=grid_spec,
            out_shape=[jax.ShapeDtypeStruct((np_, dp), jnp.int8),
                       jax.ShapeDtypeStruct((np_,), jnp.float16),
                       jax.ShapeDtypeStruct((np_,), jnp.float16)],
            interpret=interpret,
        )(kd, wp)
        return q[:n, :d], sc[:n], zp[:n]

    if not codec.quantized:
        raise ValueError(f"quantize_send needs a quantized wire codec, "
                         f"got {name!r}")

    cols = codec.payload_cols(d)
    colsp = -(-cols // LANE) * LANE
    assert colsp >= -(-dp // codec.group), (colsp, dp, name)
    kw = dict(d_real=d, qmax=codec.qmax, pack=codec._pack, cols=colsp)
    blkvec = pl.BlockSpec((SEND_BLK, dp), lambda i: (i, 0))
    qvec = pl.BlockSpec((SEND_BLK, colsp), lambda i: (i, 0))
    sca = pl.BlockSpec((SEND_BLK,), lambda i: (i,))
    if ef is not None:
        efp = _pad_to(_pad_to(ef.astype(jnp.float32), LANE, 1), SEND_BLK, 0)
        q, sc, resid = pl.pallas_call(
            functools.partial(_pack_send_ef, **kw),
            grid=grid,
            in_specs=[blkvec, blkvec],
            out_specs=[qvec, sca, blkvec],
            out_shape=[jax.ShapeDtypeStruct((np_, colsp), jnp.uint8),
                       jax.ShapeDtypeStruct((np_,), jnp.float16),
                       jax.ShapeDtypeStruct((np_, dp), jnp.float32)],
            interpret=interpret,
        )(wp, efp)
        return q[:n, :cols], sc[:n], resid[:n, :d]
    q, sc = pl.pallas_call(
        functools.partial(_pack_send_plain, **kw),
        grid=grid,
        in_specs=[blkvec],
        out_specs=[qvec, sca],
        out_shape=[jax.ShapeDtypeStruct((np_, colsp), jnp.uint8),
                   jax.ShapeDtypeStruct((np_,), jnp.float16)],
        interpret=interpret,
    )(wp)
    return q[:n, :cols], sc[:n]
