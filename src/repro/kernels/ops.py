"""Public jit'd wrappers for the Pallas kernels.

On the CPU host (this container) the kernels execute in ``interpret=True``
mode — the kernel body runs as traced JAX ops, validating the exact tiling /
masking / accumulation logic against ``ref.py``. On a TPU backend the same
calls compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gossip_merge as _gm
from repro.kernels import pegasos_update as _pu


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pegasos_update(w, t, x, y, *, lam: float):
    return _pu.pegasos_update(w, t, x, y, lam=lam, interpret=_interpret())


def merge_update(w1, t1, w2, t2, x, y, *, lam: float):
    return _gm.merge_update(w1, t1, w2, t2, x, y, lam=lam,
                            interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    blk_q: int = _fa.DEFAULT_BLK_Q,
                    blk_k: int = _fa.DEFAULT_BLK_K):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               blk_q=blk_q, blk_k=blk_k,
                               interpret=_interpret())
