"""Pallas TPU kernel: batched VOTEDPREDICT over gathered cache rows.

The serving tier's hot path (Algorithm 4 as a *service*): a batch of M
queries, each routed to one node, answered by a majority vote over that
node's cache ring buffer — ``(queries × cached models)`` scores, votes and
the vote reduction fused into ONE pass over VMEM-resident tiles. The jnp
oracle is :func:`repro.core.cache.voted_predict` restricted to the
(query, assigned node) pairs; the kernel reproduces its ±1 predictions
bitwise (the vote counts are exact small-integer sums, and the tie-break
``p_ratio - 0.5 >= 0`` and the ``score >= 0`` sign convention are applied
identically — pinned by tests/test_serving.py).

TPU adaptation: cache rows are tiled (BLK_M, c_pad, d_pad) with d padded
to the 128-lane boundary and the cache axis to the f32 sublane multiple;
pad lanes are masked out of the score reduction and pad cache slots out
of the vote (the ``fused_receive_apply`` masking precedent) — a padded
query row carries count 0 and is sliced off by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.pegasos_update import _pad_to

BLK_M = 8          # queries per grid step
LANE = 128         # TPU lane width: d padded to a multiple
C_SUB = 8          # f32 sublane multiple: cache axis padded to it


def _voted_kernel(w_ref, x_ref, cnt_ref, out_ref, *, c_real: int,
                  d_real: int):
    w = w_ref[...].astype(jnp.float32)          # (BLK_M, c_pad, d_pad)
    x = x_ref[...].astype(jnp.float32)          # (BLK_M, d_pad)
    cnt = cnt_ref[...]                          # (BLK_M,) int32
    blk, c_pad, d_pad = w.shape

    # score each (query, cache slot) pair; pad d-lanes masked to zero keeps
    # the reduction bitwise-clean like the fused_receive_apply margins
    lane = lax.broadcasted_iota(jnp.int32, (blk, c_pad, d_pad), 2)
    prod = jnp.where(lane < d_real, w * x[:, None, :], 0.0)
    scores = jnp.sum(prod, axis=-1)             # (BLK_M, c_pad)

    # Algorithm 4 vote: score >= 0 counts positive (the score == 0 sign
    # convention of cache.voted_predict); only the first `count` ring
    # slots are valid — which also masks every padded cache slot, since
    # count <= c_real <= c_pad
    votes = (scores >= 0).astype(jnp.float32)
    slot = lax.broadcasted_iota(jnp.int32, (blk, c_pad), 1)
    pos = jnp.sum(jnp.where(slot < cnt[:, None], votes, 0.0), axis=-1)
    # pad query rows ride with count 0: max(cnt, 1) only guards their
    # division — real rows always have count >= 1 (init_cache seeds one)
    p_ratio = pos / jnp.maximum(cnt, 1).astype(jnp.float32)
    out_ref[...] = jnp.where(p_ratio - 0.5 >= 0, 1.0, -1.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def voted_predict_batched(w, count, X, *, interpret: bool = False):
    """w: (M, C, d) per-query gathered cache weights; count: (M,) int32
    valid-slot counts; X: (M, d) query points. Returns (M,) ±1 f32
    predictions — the majority vote of each query's assigned cache."""
    m, c, d = w.shape
    wp = _pad_to(_pad_to(_pad_to(w, LANE, 2), C_SUB, 1), BLK_M, 0)
    xp = _pad_to(_pad_to(X, LANE, 1), BLK_M, 0)
    cntp = _pad_to(count.astype(jnp.int32), BLK_M, 0)
    mp, c_pad, d_pad = wp.shape
    grid = (mp // BLK_M,)

    out = pl.pallas_call(
        functools.partial(_voted_kernel, c_real=c, d_real=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLK_M, c_pad, d_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLK_M, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((BLK_M,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLK_M,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=interpret,
    )(wp, xp, cntp)
    return out[:m]
