"""Pallas TPU kernel: blocked causal/GQA/sliding-window flash attention.

Online-softmax forward over (BLK_Q × BLK_K) tiles with f32 VMEM scratch for
the running max / normalizer / accumulator. TPU adaptation choices:

* tiles default to 128×128 — MXU-aligned on both matmul dims, and the
  (8, 128) VREG layout divides every tile;
* the running statistics live in VMEM scratch across the innermost KV grid
  dimension (TPU grid iteration is sequential, so no atomics are needed —
  this replaces the GPU warp-level reduction idiom);
* GQA is handled in the BlockSpec index maps (query-head row -> shared KV
  row), so KV tiles are fetched once per q-head group, not replicated in HBM;
* causal and sliding-window blocks that are fully masked are skipped with
  ``pl.when`` — the compiler still schedules the grid, but no FLOPs or VMEM
  loads are issued for them (block-sparsity the way TPU prefers it).

Backward pass is left to XLA autodiff of the reference path; the kernel is
exposed for the forward/serving path (``attn_impl='pallas'``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, blk_q: int, blk_k: int,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + blk_q - 1
    if window is not None:
        relevant &= k_start + blk_k - 1 >= q_start - (window - 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (blk_q, hd)
        k = k_ref[0].astype(jnp.float32)                  # (blk_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        diff = qpos - kpos
        mask = jnp.ones((blk_q, blk_k), bool)
        if causal:
            mask &= diff >= 0
        if window is not None:
            mask &= diff < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = alpha[:, None] * acc_scr[...] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    blk_q: int = DEFAULT_BLK_Q, blk_k: int = DEFAULT_BLK_K,
                    interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd), H % KV == 0 -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = 1.0 / float(hd) ** 0.5

    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    s_pad = (-S) % max(blk_q, blk_k)
    hd_pad = (-hd) % 128

    qt = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * KV, S, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * KV, S, hd)
    if s_pad or hd_pad:
        qt = jnp.pad(qt, ((0, 0), (0, s_pad), (0, hd_pad)))
        kt = jnp.pad(kt, ((0, 0), (0, s_pad), (0, hd_pad)))
        vt = jnp.pad(vt, ((0, 0), (0, s_pad), (0, hd_pad)))
    sp = S + s_pad
    hdp = hd + hd_pad
    n_q, n_k = sp // blk_q, sp // blk_k

    def q_map(b, qi, ki):
        return (b, qi, 0)

    def kv_map(b, qi, ki):
        return ((b // H) * KV + (b % H) // rep, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, blk_q=blk_q, blk_k=blk_k, n_k=n_k),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hdp), q_map),
            pl.BlockSpec((1, blk_k, hdp), kv_map),
            pl.BlockSpec((1, blk_k, hdp), kv_map),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hdp), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, sp, hdp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, hdp), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :S, :hd].reshape(B, H, S, hd)
    return jnp.moveaxis(out, 1, 2)
