"""Pallas TPU kernel: population Pegasos update (Algorithm 3, lines 1–10).

The protocol's compute hot spot: every gossip cycle, every node updates the
model it just received against its local example — at network scale this is
a (N_models × d) fused read-modify-write. The kernel fuses the margin dot
product, the hinge branch, the weight decay, and the axpy into ONE pass over
VMEM-resident tiles (HBM traffic = read w,x + write w; the pure-XLA version
materializes the margin and the scaled copies separately).

TPU adaptation: models are tiled (BLK_N, d_pad) with d padded to the
128-lane boundary; the margin reduction runs on the VPU in f32; the hinge
condition is a per-row select — no MXU needed, the kernel is bandwidth-bound
by design (arithmetic intensity ≈ 3 flops/byte), so the win is purely the
fusion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_N = 8
LANE = 128


def _pegasos_kernel(w_ref, t_ref, x_ref, y_ref, w_out, t_out, *, lam: float):
    w = w_ref[...].astype(jnp.float32)          # (BLK_N, d_pad)
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)          # (BLK_N,)
    t = t_ref[...] + 1                          # (BLK_N,) int32

    eta = 1.0 / (lam * t.astype(jnp.float32))   # (BLK_N,)
    margin = y * jnp.sum(w * x, axis=-1)        # (BLK_N,)
    decay = (1.0 - eta * lam)[:, None]
    hinge = (margin < 1.0)[:, None]
    upd = jnp.where(hinge, (eta * y)[:, None] * x, 0.0)
    w_out[...] = (decay * w + upd).astype(w_out.dtype)
    t_out[...] = t


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("lam", "interpret"))
def pegasos_update(w, t, x, y, *, lam: float, interpret: bool = False):
    """w, x: (N, d); t: (N,) int32; y: (N,) ±1. Returns (w', t')."""
    n, d = w.shape
    wp = _pad_to(_pad_to(w, LANE, 1), BLK_N, 0)
    xp = _pad_to(_pad_to(x, LANE, 1), BLK_N, 0)
    tp = _pad_to(t, BLK_N, 0)
    yp = _pad_to(y, BLK_N, 0)
    np_, dp = wp.shape
    grid = (np_ // BLK_N,)

    w_new, t_new = pl.pallas_call(
        functools.partial(_pegasos_kernel, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLK_N, dp), lambda i: (i, 0)),
            pl.BlockSpec((BLK_N,), lambda i: (i,)),
            pl.BlockSpec((BLK_N, dp), lambda i: (i, 0)),
            pl.BlockSpec((BLK_N,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLK_N, dp), lambda i: (i, 0)),
            pl.BlockSpec((BLK_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, dp), w.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(wp, tp, xp, yp)
    return w_new[:n, :d], t_new[:n]
