"""Pallas TPU kernels for the protocol hot spots + flash attention.

Validated in interpret mode on CPU against the pure-jnp oracles in ref.py;
compiled by Mosaic on TPU backends. Use ``repro.kernels.ops`` for the
public jit'd entry points.
"""
from repro.kernels import (flash_attention, gossip_cycle, gossip_merge, ops,
                           pegasos_update, ref, voted_predict)

__all__ = ["ops", "ref", "pegasos_update", "gossip_merge", "gossip_cycle",
           "flash_attention", "voted_predict"]
