"""Msgpack pytree checkpointing.

Layout: ``<dir>/step_<n>/state.msgpack`` + ``manifest.json``. Arrays are
stored as raw little-endian bytes with dtype/shape metadata; bfloat16 is
round-tripped through uint16 views (numpy lacks the dtype). Restore
reproduces the exact tree structure (dicts/lists/tuples/scalars).

On a multi-host deployment each host would write its addressable shards;
in this single-process container the tree is fully gathered — the format
keeps a ``shard`` field so the sharded writer can extend it.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x):
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return {b"__nd__": True, b"dtype": "bfloat16",
                b"shape": list(x.shape),
                b"data": x.view(np.uint16).tobytes()}
    return {b"__nd__": True, b"dtype": x.dtype.str, b"shape": list(x.shape),
            b"data": x.tobytes()}


def _decode_leaf(d):
    shape = tuple(d[b"shape"])
    dt = d[b"dtype"]
    dt = dt.decode() if isinstance(dt, bytes) else dt
    if dt == "bfloat16":
        arr = np.frombuffer(d[b"data"], np.uint16).reshape(shape)
        return jnp.asarray(arr).view(jnp.bfloat16)
    return jnp.asarray(np.frombuffer(d[b"data"], np.dtype(dt)).reshape(shape))


def save_checkpoint(ckpt_dir: str | Path, step: int, state: Any) -> Path:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree.flatten(state)
    payload = msgpack.packb([_encode_leaf(x) for x in leaves], use_bin_type=True)
    (d / "state.msgpack").write_bytes(payload)
    (d / "manifest.json").write_text(json.dumps({
        "step": step, "n_leaves": len(leaves), "treedef": str(treedef),
        "shard": 0, "n_shards": 1}))
    return d


def latest_step(ckpt_dir: str | Path) -> int | None:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates leaf count/shapes)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    raw = msgpack.unpackb((d / "state.msgpack").read_bytes(), raw=True)
    leaves, treedef = jax.tree.flatten(like)
    assert len(raw) == len(leaves), (len(raw), len(leaves))
    new = [_decode_leaf(r) for r in raw]
    for a, b in zip(new, leaves):
        assert a.shape == b.shape, (a.shape, b.shape)
    return jax.tree.unflatten(treedef, new)
