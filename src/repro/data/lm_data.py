"""Synthetic LM token pipeline.

Deterministic, seeded synthetic corpus with learnable structure: tokens
follow a mixture of (a) a first-order Markov chain with a banded transition
kernel and (b) copy-back spans — so a transformer's loss actually decreases
during the example training runs (unlike uniform noise).

The pipeline mirrors a production input layer: sharded per-host generation,
epoch reshuffling, and a ``__next__`` returning {tokens, labels} ready for
``pjit`` (labels = tokens shifted left).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    markov_band: int = 32
    copy_prob: float = 0.3

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._step = 0

    def _sample_doc(self, rng, length: int) -> np.ndarray:
        v = self.vocab_size
        toks = np.empty(length, dtype=np.int64)
        toks[0] = rng.integers(0, v)
        i = 1
        while i < length:
            if i > 16 and rng.random() < self.copy_prob:
                # copy-back span: repeat an earlier window (induction heads)
                span = int(rng.integers(4, 16))
                start = int(rng.integers(0, i - span)) if i - span > 0 else 0
                span = min(span, length - i)
                toks[i:i + span] = toks[start:start + span]
                i += span
            else:
                # banded Markov step
                step = int(rng.integers(1, self.markov_band))
                toks[i] = (toks[i - 1] * 31 + step) % v
                i += 1
        return toks

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        batch = np.stack([self._sample_doc(rng, self.seq_len + 1)
                          for _ in range(self.batch_size)])
        return {"tokens": batch[:, :-1].astype(np.int32),
                "labels": batch[:, 1:].astype(np.int32)}


def synthetic_lm_batches(vocab_size: int, seq_len: int, batch_size: int,
                         steps: int, seed: int = 0):
    ds = SyntheticLMDataset(vocab_size, seq_len, batch_size, seed)
    for _ in range(steps):
        yield next(ds)
