from repro.data.synthetic import make_linear_dataset, paper_dataset
from repro.data.lm_data import synthetic_lm_batches, SyntheticLMDataset

__all__ = ["make_linear_dataset", "paper_dataset", "synthetic_lm_batches",
           "SyntheticLMDataset"]
