"""Surrogate datasets matching the paper's Table I geometries.

The UCI originals (Reuters subset, Spambase, Malicious URLs) are not
available in this offline container, so we generate classification problems
with the *same* dimension, training-set size (= network size N: one record
per node), test-set size, class ratio, and a comparable Bayes error. The
generator mixes a linearly separable core with label noise and (for the
high-dimensional Reuters surrogate) sparse features — giving 0-1 error
floors in the ballpark of Table I so the convergence *dynamics* (the
paper's actual claim) are exercised on realistic geometry.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.configs.gossip_linear import DATASETS, GossipLinearConfig


def make_linear_dataset(rng: np.random.Generator, n: int, d: int,
                        *, noise: float = 0.1, sparsity: float = 0.0,
                        class_ratio: Tuple[int, int] = (1, 1),
                        separation: float = 3.0):
    """Gaussian class-conditional data with a controlled Bayes floor.

    X = noise_cloud + (separation/√d)·y·w_true, then a ``noise`` label-flip —
    so the optimal linear error ≈ Φ(-separation) + noise·(1-2Φ(-separation)),
    letting us match Table I floors. ``sparsity`` zeroes feature entries
    (Reuters-like bag-of-words surrogate)."""
    w_true = rng.normal(size=d)
    w_true /= np.linalg.norm(w_true)
    X = rng.normal(size=(n, d)).astype(np.float32) / np.sqrt(d)
    if sparsity > 0:
        mask = rng.random((n, d)) >= sparsity
        X = (X * mask / np.sqrt(max(1.0 - sparsity, 1e-6))).astype(np.float32)
    pos, neg = class_ratio
    y = np.where(rng.random(n) < pos / (pos + neg), 1.0, -1.0).astype(np.float32)
    X = (X + (separation / np.sqrt(d)) * y[:, None] * w_true[None, :]).astype(np.float32)
    flip = rng.random(n) < noise
    y[flip] = -y[flip]
    return X, y


_PAPER_NOISE = {
    # tuned so sequential Pegasos (20k iters) lands near Table I's 0-1 errors
    # (reuters 0.025, spambase 0.111, malicious-urls 0.080)
    "reuters": dict(noise=0.02, sparsity=0.9, separation=4.0),
    "spambase": dict(noise=0.10, sparsity=0.0, separation=2.5),
    "malicious-urls": dict(noise=0.07, sparsity=0.0, separation=2.5),
}


def paper_dataset(name: str, seed: int = 0):
    """(X_train, y_train, X_test, y_test, cfg) for a Table I surrogate."""
    cfg: GossipLinearConfig = DATASETS[name]
    rng = np.random.default_rng(seed)
    kw = _PAPER_NOISE[name]
    X, y = make_linear_dataset(rng, cfg.n_nodes + cfg.n_test, cfg.dim,
                               class_ratio=cfg.class_ratio, **kw)
    return (X[:cfg.n_nodes], y[:cfg.n_nodes],
            X[cfg.n_nodes:], y[cfg.n_nodes:], cfg)
