"""Logical-axis -> PartitionSpec rules.

Every parameter leaf carries logical axis names (see ``models/layers.py``).
A :class:`LogicalRules` profile maps each logical name to an ordered list of
candidate mesh axes; the first candidate that (a) divides the dimension size
and (b) is not already used by another dim of the same tensor wins, otherwise
the dim is replicated. This degrades gracefully across the heterogeneous
assigned architectures (e.g. llama4's 40 heads don't divide a 16-way model
axis -> heads fall back to replication while d_ff still shards).

Profiles:
  * ``tp_fsdp`` (default) — Megatron TP over 'model' + ZeRO-3 FSDP over
    'data' ('pod','data' in multi-pod) for the big dims.
  * ``tp_only`` — TP over 'model', replicated over 'data'; required by the
    gossip optimizer where each data-rank (peer) owns a full, *divergent*
    model copy (the peer dim itself is sharded over the peer axes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# leaves smaller than this are always replicated (norm scales, gates, ...)
MIN_SHARD_ELEMS = 1 << 16


@dataclass(frozen=True)
class LogicalRules:
    name: str
    # logical axis -> candidate mesh axes, in priority order. A candidate may
    # itself be a tuple of mesh axes (sharded over their product).
    table: Dict[str, Tuple] = field(default_factory=dict)

    def candidates(self, logical: Optional[str]):
        if logical is None:
            return ()
        return self.table.get(logical, ())


def _fsdp_axes(multi_pod: bool):
    return (("pod", "data"), ("data",)) if multi_pod else (("data",),)


def default_rules(*, multi_pod: bool = False, fsdp: bool = True,
                  moe_sharding: str = "expert", peer_axes: Tuple[str, ...] = (),
                  inference: bool = False):
    """Build the standard rule table for a (pod?, data, model) mesh.

    inference=True (the decode profile, EXPERIMENTS.md §Perf): weights are
    *stationary* — big dims are 2D-sharded over ('model','data') (falling
    back to 'model' when indivisible) and the FSDP 'embed' sharding is
    dropped, so no weight is ever re-gathered per decoded token; matmul
    contractions produce small activation psums instead. Storage stays fully
    sharded (405B bf16 = ~3–4 GB/device on 256 chips)."""
    if inference:
        two_d = (("model", "data"), ("model",))
        t = {
            "vocab": (("model",),),
            "embed": (),
            "embed_table": (),
            "ffn": two_d,
            "heads": two_d,
            "kv_heads": (("model",),),
            "head_dim": (("data",),),
            "expert": (("model",),) if moe_sharding == "expert" else (),
            # 'expert' mode: E on model, d_ff_expert on data (2D);
            # 'tensor' mode: d_ff_expert on (model, data)
            "expert_ffn": two_d if moe_sharding == "tensor" else (("data",),),
            "expert_router": (),
            "layers": (),
            "conv": (),
            "state": (),
            "peers": (),
            "batch": (),
            "seq": (),
        }
        return LogicalRules("tp2d_inference", t)
    fsdp_c = _fsdp_axes(multi_pod) if fsdp else ()
    # when gossiping, the peer axes must never shard parameter dims
    fsdp_c = tuple(c for c in fsdp_c
                   if not any(a in peer_axes for a in (c if isinstance(c, tuple) else (c,))))
    t = {
        "vocab": (("model",),) + fsdp_c,
        "embed": fsdp_c,
        "embed_table": (),          # see models/layers.embedding_spec
        "ffn": (("model",),),
        "heads": (("model",),),
        "kv_heads": (("model",),),
        "head_dim": (),
        "expert": (("model",),) if moe_sharding == "expert" else (),
        "expert_ffn": (("model",),) if moe_sharding == "tensor" else fsdp_c,
        "expert_router": (),
        "layers": (),
        "conv": (),
        "state": (),
        "peers": (tuple(peer_axes),) if peer_axes else (),
        # activations / inputs
        "batch": ((("pod", "data") if multi_pod else ("data",)),),
        "seq": (),
    }
    return LogicalRules("tp_fsdp" if fsdp else "tp_only", t)


def partition_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                   mesh_sizes: Dict[str, int], rules: LogicalRules) -> PS:
    """Resolve one tensor's logical axes into a PartitionSpec."""
    if int(np.prod(shape)) < MIN_SHARD_ELEMS and "peers" not in axes:
        return PS()
    used: set = set()
    out = []
    for dim, logical in zip(shape, axes):
        chosen = None
        for cand in rules.candidates(logical):
            cand_t = cand if isinstance(cand, tuple) else (cand,)
            size = int(np.prod([mesh_sizes[a] for a in cand_t]))
            if dim % size == 0 and size > 1 and not (used & set(cand_t)):
                chosen = cand_t if len(cand_t) > 1 else cand_t[0]
                used.update(cand_t)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return PS(*out)


def params_pspecs(axes_tree, sds_tree, mesh: Mesh, rules: LogicalRules):
    """PartitionSpec tree for a params tree given its logical-axes tree."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda ax, sds: partition_spec(sds.shape, ax, sizes, rules),
        axes_tree, sds_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def cache_pspecs(cache_sds, mesh: Mesh, *, multi_pod: bool = False,
                 profile: str = "context"):
    """Heuristic PartitionSpecs for decode caches / recurrent states.

    profile='context' (default — see EXPERIMENTS.md §Perf, decode hillclimb):
      shard the KV *length* dim (the longest dim) over 'data'
      (context-parallel decode: GSPMD turns the softmax/contraction over the
      sharded length into small activation psums, and the weights stay
      sharded — no per-token FSDP re-gather), then a heads-like dim over
      'model'; batch stays unsharded. Falls back to batch-sharding when the
      length dim does not divide (e.g. whisper's 1500-frame cross cache).

    profile='batch' (the v0 baseline): shard the batch dim over
    ('pod','data') when divisible, else the longest dim; plus a heads-like
    dim over 'model'."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    bsz = int(np.prod([sizes[a] for a in batch_axes]))

    def batch_dim(shape):
        for bdim in (0, 1):
            if shape[bdim] % bsz == 0 and shape[bdim] >= bsz:
                return bdim
        return None

    def length_dim(shape):
        ldim = int(np.argmax(shape))
        if shape[ldim] % bsz == 0 and shape[ldim] >= 4 * bsz:
            return ldim
        return None

    def one(sds):
        shape = sds.shape
        spec = [None] * len(shape)
        if len(shape) >= 3:
            order = (length_dim, batch_dim) if profile == "context" \
                else (batch_dim, length_dim)
            for f in order:
                d = f(shape)
                if d is not None:
                    spec[d] = batch_axes if multi_pod else "data"
                    break
            # additionally shard the first eligible dim over 'model'. For a
            # 5D KV leaf this is the BATCH dim — deliberate: batch-over-model
            # × length-over-data is the good 2D cache layout (each model
            # shard attends for its batch slice; only (B,1,·) activations
            # reshard around the attention block). Sharding head_dim over
            # model instead was measured 135× WORSE (the q·k contraction
            # over a sharded head_dim psums the full (B,KV,rep,1,S) logits
            # per layer) — EXPERIMENTS.md §Perf decode iter A-3b.
            for hdim in range(len(shape)):
                if spec[hdim] is None and shape[hdim] % sizes["model"] == 0 \
                        and shape[hdim] >= sizes["model"] and shape[hdim] <= 1024:
                    spec[hdim] = "model"
                    break
        elif len(shape) == 2:
            if shape[-1] % sizes["model"] == 0 and shape[-1] >= sizes["model"]:
                spec[-1] = "model"
        while spec and spec[-1] is None:
            spec.pop()
        return PS(*spec)

    return jax.tree.map(one, cache_sds)


def named_sharding_tree(pspec_tree, mesh: Mesh):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspec_tree,
                        is_leaf=lambda x: isinstance(x, PS))
