"""Version-compat wrapper for ``shard_map``.

``shard_map`` moved from ``jax.experimental.shard_map`` (kwargs
``check_rep``/``auto``) to top-level ``jax.shard_map`` (kwargs
``check_vma``/``axis_names``) across JAX releases. Every call site in this
repo goes through :func:`shard_map_compat` so both spellings work.
"""
from __future__ import annotations

import jax


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes=None,
                     check: bool = False):
    """``shard_map(f, mesh, in_specs, out_specs)`` on any supported JAX.

    ``manual_axes``: mesh axes the body handles manually; the remaining
    axes stay auto-sharded (partial-manual mode — ``axis_names`` on newer
    JAX, ``auto`` = the complement on older JAX). ``None`` means fully
    manual over every mesh axis.

    ``check=False`` disables replication/varying-manual-axes checking
    (``check_rep`` on older JAX, ``check_vma`` on newer) — the call sites
    here permute or act element-wise per shard, which the checker cannot
    always verify."""
    if hasattr(jax, "shard_map"):
        kw = {} if manual_axes is None else {"axis_names": set(manual_axes)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check, **kw)
    from jax.experimental.shard_map import shard_map
    auto = (frozenset() if manual_axes is None
            else frozenset(mesh.axis_names) - frozenset(manual_axes))
    return shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check, auto=auto)
