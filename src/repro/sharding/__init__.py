from repro.sharding.compat import shard_map_compat
from repro.sharding.rules import (
    LogicalRules,
    default_rules,
    partition_spec,
    params_pspecs,
    cache_pspecs,
    named_sharding_tree,
)

__all__ = [
    "shard_map_compat",
    "LogicalRules",
    "default_rules",
    "partition_spec",
    "params_pspecs",
    "cache_pspecs",
    "named_sharding_tree",
]
