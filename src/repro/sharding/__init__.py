from repro.sharding.rules import (
    LogicalRules,
    default_rules,
    partition_spec,
    params_pspecs,
    cache_pspecs,
    named_sharding_tree,
)

__all__ = [
    "LogicalRules",
    "default_rules",
    "partition_spec",
    "params_pspecs",
    "cache_pspecs",
    "named_sharding_tree",
]
