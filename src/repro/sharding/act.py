"""Activation sharding constraints.

XLA's sharding propagation, given only input/param shardings, can settle on
a batch-replicated / feature-sharded fixpoint for the activations (observed
on the 16×16 mesh: full-batch f32 logits all-reduced across the mesh). The
fix is standard: pin the activation layout at module boundaries with
``with_sharding_constraint``.

The context is set by the step builders (launch/specs.py) around tracing;
model code calls the ``shard_*`` helpers, which are no-ops when no context
is active (CPU tests, single-device runs). Under the gossip optimizer the
peer axis is handled by ``vmap(..., spmd_axis_name=...)`` and the inner
context uses ``batch_axes=()`` (per-peer batch replicated within the peer's
device group).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np
from jax.sharding import PartitionSpec as PS
from jax import lax


@dataclass
class _ActCtx:
    mesh_sizes: dict
    batch_axes: Tuple[str, ...]
    model_axis: str = "model"
    mesh: object = None


_CTX: Optional[_ActCtx] = None


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes: Tuple[str, ...],
                        model_axis: str = "model"):
    global _CTX
    prev = _CTX
    _CTX = _ActCtx(dict(zip(mesh.axis_names, mesh.devices.shape)),
                   tuple(batch_axes), model_axis, mesh)
    try:
        yield
    finally:
        _CTX = prev


def current_ctx() -> Optional[_ActCtx]:
    """The active activation-sharding context (mesh + axis layout), or None.
    Used by modules that need manual shard_map blocks (MoE combine-reduce)."""
    return _CTX


def _axis_size(axes) -> int:
    if _CTX is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([_CTX.mesh_sizes.get(a, 1) for a in axes]))


def _constrain(x, spec_entries):
    from jax.lax import with_sharding_constraint
    while spec_entries and spec_entries[-1] is None:
        spec_entries = spec_entries[:-1]
    return with_sharding_constraint(x, PS(*spec_entries))


def _batch_entry():
    ba = _CTX.batch_axes
    if not ba:
        return None
    return ba if len(ba) > 1 else ba[0]


def shard_activations(x):
    """(B, S, D) or (B, S): batch over the batch axes, rest replicated."""
    if _CTX is None:
        return x
    b = x.shape[0]
    entry = _batch_entry()
    if entry is None or b % _axis_size(entry) != 0:
        return x
    return _constrain(x, [entry] + [None] * (x.ndim - 1))


def shard_logits(x):
    """(B, S, V) or (B, C, V): batch over batch axes, vocab over model."""
    if _CTX is None:
        return x
    entries = [None] * x.ndim
    entry = _batch_entry()
    if entry is not None and x.shape[0] % _axis_size(entry) == 0:
        entries[0] = entry
    if x.shape[-1] % _axis_size(_CTX.model_axis) == 0:
        entries[-1] = _CTX.model_axis
    return _constrain(x, entries)


def shard_heads(x, head_dim_index: int = 2):
    """(B, S, H, hd): batch over batch axes, heads over model if divisible."""
    if _CTX is None:
        return x
    entries = [None] * x.ndim
    entry = _batch_entry()
    if entry is not None and x.shape[0] % _axis_size(entry) == 0:
        entries[0] = entry
    if x.shape[head_dim_index] % _axis_size(_CTX.model_axis) == 0:
        entries[head_dim_index] = _CTX.model_axis
    return _constrain(x, entries)


def shard_expert_buffer(buf, moe_sharding: str):
    """(G, E, C, D) grouped dispatch buffer (or legacy (E, C, D)):
    groups over the batch axes, experts over model => the token->expert
    movement between the two layouts lowers to an all-to-all."""
    if _CTX is None:
        return buf
    entries = [None] * buf.ndim
    e_dim = buf.ndim - 3          # 1 for (G,E,C,D), 0 for (E,C,D)
    if e_dim == 1:
        entry = _batch_entry()
        if entry is not None and buf.shape[0] % _axis_size(entry) == 0:
            entries[0] = entry
    if moe_sharding == "expert" and buf.shape[e_dim] % _axis_size(_CTX.model_axis) == 0:
        entries[e_dim] = _CTX.model_axis
    return _constrain(buf, entries)


def shard_group_tokens(x):
    """(G, Tg, D) grouped token block: groups over the batch axes."""
    if _CTX is None:
        return x
    entry = _batch_entry()
    if entry is None or x.shape[0] % _axis_size(entry) != 0:
        return x
    return _constrain(x, [entry] + [None] * (x.ndim - 1))
