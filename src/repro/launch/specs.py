"""input_specs() + step builders for the dry-run / trainer / server.

Everything here works on ``ShapeDtypeStruct`` stand-ins: weak-type-correct,
shardable, and never allocating — 405B-scale params and half-terabyte KV
caches stay abstract through ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.config.base import (GossipConfig, InputShape, ModelConfig,
                               INPUT_SHAPES)
from repro.core.gossip_optimizer import (make_allreduce_train_step,
                                         make_gossip_train_step)
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import make_optimizer, warmup_cosine
from repro.sharding import cache_pspecs, default_rules, params_pspecs
from repro.sharding.act import activation_sharding

LONG_WINDOW = 8192          # SWA window for dense archs on long_500k


# ---------------------------------------------------------------------------
# workload-variant resolution
# ---------------------------------------------------------------------------


def resolve_variant(cfg: ModelConfig, shape: InputShape) -> Tuple[ModelConfig, Dict]:
    """Adapt a config to a workload shape; returns (cfg, notes).

    * long_500k on full-attention archs -> sliding-window variant (the
      sub-quadratic requirement); natively windowed/SSM archs unchanged.
    * whisper: long_500k unsupported (documented skip); decode self-cache
      capped at max_target_positions.
    """
    notes: Dict = {}
    if shape.name == "long_500k":
        if cfg.family == "audio":
            raise ValueError("long_500k x whisper: documented skip (DESIGN.md)")
        a = cfg.attention
        if a is not None and a.sliding_window is None:
            has_global_attn = any(k in ("attn", "cross", "selfcross")
                                  for k in cfg.layer_pattern)
            if has_global_attn:
                cfg = cfg.replace(
                    attention=dataclasses.replace(a, sliding_window=LONG_WINDOW))
                notes["attn"] = f"swa{LONG_WINDOW}"
    if cfg.family == "audio" and shape.kind == "decode":
        notes["self_cache"] = f"capped at {cfg.max_target_positions} target positions"
    return cfg, notes


def needs_encoder_input(cfg: ModelConfig) -> bool:
    return cfg.family in ("vlm", "audio")


def encoder_input_sds(cfg: ModelConfig, batch: int):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct(
            (batch, cfg.cross_attn.source_len, cfg.d_model), cfg.compute_dtype)
    d = cfg.encoder.d_model or cfg.d_model
    return jax.ShapeDtypeStruct((batch, cfg.encoder.source_len, d),
                                cfg.compute_dtype)


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                n_peers: int = 0) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this workload."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if n_peers:
            assert gb % n_peers == 0
            tok = jax.ShapeDtypeStruct((n_peers, gb // n_peers, s), jnp.int32)
        else:
            tok = jax.ShapeDtypeStruct((gb, s), jnp.int32)
        out = {"tokens": tok, "labels": tok}
        if needs_encoder_input(cfg):
            if n_peers:
                e = encoder_input_sds(cfg, gb // n_peers)
                out["encoder_out"] = jax.ShapeDtypeStruct((n_peers,) + e.shape, e.dtype)
            else:
                out["encoder_out"] = encoder_input_sds(cfg, gb)
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32)}
        if needs_encoder_input(cfg):
            out["encoder_out"] = encoder_input_sds(cfg, gb)
        return out
    # decode: ONE new token + the KV/state cache of seq_len positions
    out = {
        "token": jax.ShapeDtypeStruct((gb,), jnp.int32),
        "cache": T.cache_spec(cfg, gb, s),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return out


# ---------------------------------------------------------------------------
# sharding resolution
# ---------------------------------------------------------------------------


def _batch_spec(mesh, ndim: int, *, peer: bool = False,
                peer_axes: Tuple[str, ...] = ()):
    multi = "pod" in mesh.axis_names
    if peer:
        rest = tuple(a for a in (("pod", "data") if multi else ("data",))
                     if a not in peer_axes)
        second = rest[0] if rest else None
        return PS(peer_axes if len(peer_axes) > 1 else peer_axes[0], second,
                  *([None] * (ndim - 2)))
    bx = ("pod", "data") if multi else "data"
    return PS(bx, *([None] * (ndim - 1)))


def shardings_for(cfg: ModelConfig, mesh, *, gossip: Optional[GossipConfig] = None,
                  peer_axes: Tuple[str, ...] = ("data",), inference: bool = False):
    """(params_pspecs, rules) for this config on this mesh."""
    multi = "pod" in mesh.axis_names
    moe_mode = cfg.moe.sharding if cfg.moe else "expert"
    if gossip is not None:
        rules = default_rules(multi_pod=multi, fsdp=True,
                              moe_sharding=moe_mode, peer_axes=peer_axes)
    else:
        rules = default_rules(multi_pod=multi, fsdp=True, moe_sharding=moe_mode,
                              inference=inference)
    spec = T.model_spec(cfg)
    axes = L.spec_axes(spec)
    sds = L.abstract_params(spec)
    pspecs = params_pspecs(axes, sds, mesh, rules)
    if gossip is not None:
        # prepend the peer axis to every leaf spec
        def add_peer(ps):
            return PS(peer_axes if len(peer_axes) > 1 else peer_axes[0], *ps)
        pspecs = jax.tree.map(add_peer, pspecs, is_leaf=lambda x: isinstance(x, PS))
    return pspecs, rules


def _stack_sds(tree, n):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


# ---------------------------------------------------------------------------
# step builders (train / prefill / decode), all returning
# (fn, arg_sds: tuple, in_shardings: tuple)
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        enc = batch.get("encoder_out")
        return T.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                         encoder_out=enc)
    return loss_fn


def build_train_step(cfg: ModelConfig, shape: InputShape, mesh, *,
                     optimizer: str = "adamw",
                     gossip: Optional[GossipConfig] = None,
                     n_peers: int = 0, lr: float = 3e-4):
    sched = warmup_cosine(lr, 100, 10_000)
    opt = make_optimizer(optimizer, sched)
    loss_fn = make_loss_fn(cfg)

    params_sds = T.abstract_params(cfg)
    if gossip is not None:
        params_sds = _stack_sds(params_sds, n_peers)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    step_sds = jax.ShapeDtypeStruct((), jnp.int32)
    batch_sds = input_specs(cfg, shape, n_peers=n_peers if gossip else 0)

    peer_axes = ("data",)
    pspecs, _ = shardings_for(cfg, mesh, gossip=gossip, peer_axes=peer_axes)
    ns = lambda tree: jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree,
                                   is_leaf=lambda x: isinstance(x, PS))
    params_sh = ns(pspecs)
    opt_sh = jax.tree.map(
        lambda s: params_sh, {k: None for k in opt_sds}) if opt_sds else {}
    # opt state mirrors the params tree per top-level slot ("m"/"v")
    opt_sh = {k: params_sh for k in opt_sds}

    def batch_sharding(sds_tree):
        def one(sds):
            if gossip is not None:
                return NamedSharding(mesh, _batch_spec(mesh, len(sds.shape),
                                                       peer=True,
                                                       peer_axes=peer_axes))
            return NamedSharding(mesh, _batch_spec(mesh, len(sds.shape)))
        return jax.tree.map(one, sds_tree)

    batch_sh = batch_sharding(batch_sds)
    rep = NamedSharding(mesh, PS())

    multi = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi else ("data",)

    if gossip is not None:
        g_step = make_gossip_train_step(loss_fn, opt, n_peers, gossip,
                                        spmd_axis="data", mesh=mesh,
                                        peer_axes=peer_axes)
        # the partner permutation is STATIC (compile-time schedule) so the
        # exchange lowers to a collective-permute, not a gathered take();
        # lower with the round-0 hypercube pairing as the representative —
        # every round of the schedule has identical cost structure.
        from repro.core.gossip_optimizer import perms_for_step
        perm0, _ = perms_for_step(gossip, 0, n_peers)

        def step(params, opt_state, step_idx, batch):
            from repro.core.gossip_optimizer import GossipState
            # per-peer batch is replicated within the peer's device group;
            # the peer dim itself is handled by vmap(spmd_axis_name='data')
            with activation_sharding(mesh, ()):
                st, loss, _ = g_step(GossipState(params, opt_state, step_idx),
                                     batch, perm0)
            return st.params, st.opt_state, st.step, loss

        arg_sds = (params_sds, opt_sds, step_sds, batch_sds)
        in_sh = (params_sh, opt_sh, rep, batch_sh)
        return step, arg_sds, in_sh

    a_step = make_allreduce_train_step(loss_fn, opt)

    def step(params, opt_state, step_idx, batch):
        with activation_sharding(mesh, batch_axes):
            new_p, new_o, loss, _ = a_step(params, opt_state, batch, step_idx)
        return new_p, new_o, step_idx + 1, loss

    arg_sds = (params_sds, opt_sds, step_sds, batch_sds)
    in_sh = (params_sh, opt_sh, rep, batch_sh)
    return step, arg_sds, in_sh


def build_prefill_step(cfg: ModelConfig, shape: InputShape, mesh):
    params_sds = T.abstract_params(cfg)
    pspecs, _ = shardings_for(cfg, mesh)
    params_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                             is_leaf=lambda x: isinstance(x, PS))
    batch_sds = input_specs(cfg, shape)
    batch_sh = jax.tree.map(
        lambda sds: NamedSharding(mesh, _batch_spec(mesh, len(sds.shape))),
        batch_sds)

    multi = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi else ("data",)

    def step(params, batch):
        # realistic prefill output: next-token logits only (the KV cache
        # emission is the decode path's input; see EXPERIMENTS.md §Dry-run)
        with activation_sharding(mesh, batch_axes):
            logits, _ = T.forward(params, cfg, batch["tokens"],
                                  encoder_out=batch.get("encoder_out"),
                                  last_only=True)
        return logits

    return step, (params_sds, batch_sds), (params_sh, batch_sh)


def build_decode_step(cfg: ModelConfig, shape: InputShape, mesh, *,
                      profile: str = "context"):
    """Decode step builder.

    profile='context' (default; EXPERIMENTS.md §Perf decode hillclimb):
      * KV caches sharded along the *length* dim over 'data'
        (context-parallel decode — the attention softmax/contraction over
        the sharded length lowers to small activation psums);
      * the token batch and activations replicated over 'data', so the
        FSDP-sharded weights are consumed *in place* (partial matmuls +
        activation psums) instead of being re-all-gathered every token;
      * serving weights in the compute dtype (bf16), not the f32 training
        master copy (halves HBM and any remaining gather bytes).
    profile='batch' reproduces the v0 baseline (batch-sharded cache,
    f32 weights, per-token weight all-gathers).
    """
    multi = "pod" in mesh.axis_names
    params_sds = T.abstract_params(cfg)
    if profile == "context":
        params_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, cfg.compute_dtype if s.dtype == jnp.float32 else s.dtype),
            params_sds)
    # NOTE: inference=True (2D weight sharding) was tried and REFUTED — it
    # introduces cache/attention resharding conflicts that cost more than
    # the remaining weight traffic (EXPERIMENTS.md §Perf decode iter 3).
    pspecs, _ = shardings_for(cfg, mesh, inference=(profile == "tp2d"))
    params_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                             is_leaf=lambda x: isinstance(x, PS))
    specs = input_specs(cfg, shape)
    cache_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                            cache_pspecs(specs["cache"], mesh, multi_pod=multi,
                                         profile=profile),
                            is_leaf=lambda x: isinstance(x, PS))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bx = ("pod", "data") if multi else ("data",)
    bsz = int(np.prod([sizes[a] for a in bx]))
    gb = specs["token"].shape[0]
    if profile == "context":
        tok_spec = PS()
        dec_batch_axes = ()
    else:
        tok_spec = PS(bx if multi else "data") if gb % bsz == 0 else PS()
        dec_batch_axes = bx if gb % bsz == 0 else ()
    tok_sh = NamedSharding(mesh, tok_spec)
    rep = NamedSharding(mesh, PS())

    def step(params, token, cache, index):
        with activation_sharding(mesh, dec_batch_axes):
            return T.decode_step(params, cfg, token, cache, index)

    arg_sds = (params_sds, specs["token"], specs["cache"], specs["index"])
    in_sh = (params_sh, tok_sh, cache_sh, rep)
    return step, arg_sds, in_sh


def _with_dispatch_groups(cfg: ModelConfig, shape: InputShape, mesh) -> ModelConfig:
    """Set the MoE grouped-dispatch count to the batch-shard size, so each
    data shard owns its (E, C_group, D) buffer (see models/moe.py)."""
    if cfg.moe is None or cfg.moe.dispatch_groups != 1:
        return cfg
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bx = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bsz = int(np.prod([sizes[a] for a in bx]))
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill") else 1)
    combine = "reduce" if cfg.moe.sharding == "tensor" else cfg.moe.combine
    if bsz > 1 and shape.global_batch % bsz == 0 and tokens % bsz == 0:
        return cfg.replace(moe=dataclasses.replace(
            cfg.moe, dispatch_groups=bsz, combine=combine))
    return cfg


def build_step(cfg: ModelConfig, shape: InputShape, mesh, *, dist: str = "allreduce",
               n_peers: int = 0, optimizer: str = "adamw",
               decode_profile: str = "context"):
    """Dispatch on workload kind; returns (fn, arg_sds, in_shardings, notes)."""
    cfg, notes = resolve_variant(cfg, shape)
    cfg = _with_dispatch_groups(cfg, shape, mesh)
    if cfg.moe is not None and cfg.moe.dispatch_groups > 1:
        notes["moe"] = f"grouped-dispatch G={cfg.moe.dispatch_groups}"
    if shape.kind == "train":
        gossip = GossipConfig() if dist == "gossip" else None
        if dist == "gossip" and n_peers == 0:
            n_peers = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
        fn, sds, sh = build_train_step(cfg, shape, mesh, optimizer=optimizer,
                                       gossip=gossip, n_peers=n_peers)
    elif shape.kind == "prefill":
        fn, sds, sh = build_prefill_step(cfg, shape, mesh)
    else:
        fn, sds, sh = build_decode_step(cfg, shape, mesh,
                                        profile=decode_profile)
        notes["decode"] = decode_profile
    return fn, sds, sh, notes
