"""Finite-difference roofline costing.

``compiled.cost_analysis()`` (and a line-wise HLO collective parse) count a
``while``-loop body ONCE, so a layer-scanned model under-reports FLOPs /
bytes / collective bytes by ~num_layers×. Rather than trust loop-trip
heuristics, we compile two *fully unrolled* shallow variants of the same
architecture — depth = 1 and 2 pattern periods, with the inner scans
(chunked attention, chunked xent) also disabled so the HLO is loop-free —
and extrapolate linearly in depth:

    cost(L) = c1 + (c2 - c1) / p · (L - p)

Exact for everything that is per-layer (all layer matmuls, FSDP
all-gathers, TP all-reduces, MoE all-to-alls) and for everything that is
depth-independent (embedding, xent, gradient reduction of the head) — the
two classes the linear model separates by construction. Whisper's encoder
tower is depth-constant here (its own layers unroll identically in both
compiles), so it lands in c1's constant term, also exact.

The *full-depth* compile (launch/dryrun.py) remains the proof that the real
scanned program lowers, fits, and schedules collectives; this module only
supplies the roofline numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from repro.config.base import InputShape, ModelConfig
from repro.launch import roofline as RL
from repro.launch.mesh import num_chips
from repro.launch.specs import build_step, resolve_variant


def _unrolled(cfg: ModelConfig, periods: int) -> ModelConfig:
    p = len(cfg.layer_pattern)
    # loop-free HLO for honest op counting: full attention -> 'xla' (single
    # masked block); sliding-window -> 'banded' (static query blocks with
    # statically sliced key spans — counts S*(window+chunk), matching the
    # windowed chunked runtime path, not the masked full S^2)
    windowed = cfg.attention is not None and (
        cfg.attention.sliding_window is not None
        or any(k == "local" for k in cfg.layer_pattern))
    impl = "banded" if windowed else "xla"
    return cfg.replace(
        num_layers=periods * p,
        scan_layers=False,
        attn_impl=impl if cfg.attn_impl == "chunked" else cfg.attn_impl,
        xent_chunk=1 << 30,          # disable the xent scan
        remat=cfg.remat,             # checkpoint recompute stays, statically inlined
    )


def _measure(cfg: ModelConfig, shape: InputShape, mesh, *, dist: str,
             optimizer: str, decode_profile: str = "context") -> Dict[str, float]:
    fn, arg_sds, in_sh, _ = build_step(cfg, shape, mesh, dist=dist,
                                       optimizer=optimizer,
                                       decode_profile=decode_profile)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*arg_sds).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = RL.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_operand": float(coll.total_operand_bytes),
        "coll_wire": float(coll.wire_bytes),
        "coll_per_op": dict(coll.per_op),
        "coll_counts": dict(coll.count),
    }


def fd_roofline(cfg: ModelConfig, shape: InputShape, mesh, *,
                dist: str = "allreduce", optimizer: str = "adamw",
                decode_profile: str = "context") -> RL.Roofline:
    """Depth-extrapolated roofline for the full-depth config."""
    vcfg, _ = resolve_variant(cfg, shape)
    p = len(vcfg.layer_pattern)
    L = vcfg.num_layers
    c1 = _measure(_unrolled(vcfg, 1), shape, mesh, dist=dist, optimizer=optimizer,
                  decode_profile=decode_profile)
    c2 = _measure(_unrolled(vcfg, 2), shape, mesh, dist=dist, optimizer=optimizer,
                  decode_profile=decode_profile)

    def extrap(key):
        slope = (c2[key] - c1[key]) / p
        return max(c1[key] + slope * (L - p), 0.0)

    flops = extrap("flops")
    bytes_ = extrap("bytes")
    wire = extrap("coll_wire")
    operand = extrap("coll_operand")
    per_op = {k: int(max(c1["coll_per_op"].get(k, 0)
                         + (c2["coll_per_op"].get(k, 0)
                            - c1["coll_per_op"].get(k, 0)) / p * (L - p), 0))
              for k in set(c1["coll_per_op"]) | set(c2["coll_per_op"])}
    counts = {k: int(max(c1["coll_counts"].get(k, 0)
                         + (c2["coll_counts"].get(k, 0)
                            - c1["coll_counts"].get(k, 0)) / p * (L - p), 0))
              for k in set(c1["coll_counts"]) | set(c2["coll_counts"])}

    chips = num_chips(mesh)
    mf = RL.model_flops_for(vcfg, shape)
    compute_s = flops / RL.PEAK_FLOPS_BF16
    memory_s = bytes_ / RL.HBM_BW
    collective_s = wire / RL.ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    return RL.Roofline(
        flops_per_device=flops, bytes_per_device=bytes_,
        collective_bytes_per_device=operand, wire_bytes_per_device=wire,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=max(terms, key=terms.get),
        model_flops=mf, useful_ratio=mf / (flops * chips) if flops else 0.0,
        collectives=per_op, collective_counts=counts,
    )
