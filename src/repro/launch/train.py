"""End-to-end trainer.

Runs real optimization (not a dry-run): synthetic LM data pipeline ->
model -> gossip or all-reduce distributed step -> metrics + checkpoints.
On this CPU container it drives the ~100M-parameter example configs; on a
TPU pod the same entry point scales to the assigned architectures (the
step functions are identical to the dry-run's).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 200 --dist gossip --peers 4
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import GossipConfig, get_config, reduced_config
from repro.core.gossip_optimizer import (GossipState, gossip_merge,
                                         make_allreduce_train_step,
                                         make_gossip_train_step,
                                         peer_disagreement, perms_for_step,
                                         stack_for_peers, unstack_mean)
from repro.data import SyntheticLMDataset
from repro.models import transformer as T
from repro.models import vision as V
from repro.optim import make_optimizer, warmup_cosine


def make_example_config(arch: str, reduced: bool, *, d_model: int = 0,
                        layers: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg, d_model=d_model or 256, layers=layers or 2,
                             vocab=2048)
    return cfg


def train(arch: str = "qwen3-1.7b", *, reduced: bool = True, steps: int = 100,
          batch: int = 8, seq_len: int = 128, lr: float = 1e-3,
          dist: str = "allreduce", n_peers: int = 4, merge: str = "mu",
          schedule: str = "hypercube", optimizer: str = "adamw",
          seed: int = 0, log_every: int = 10, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 0, d_model: int = 0, layers: int = 0):
    cfg = make_example_config(arch, reduced, d_model=d_model, layers=layers)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"dist={dist}" + (f" peers={n_peers} merge={merge}" if dist == "gossip" else ""))

    key = jax.random.key(seed)
    params = T.init_params(key, cfg)
    sched = warmup_cosine(lr, min(20, steps // 5 + 1), steps)
    opt = make_optimizer(optimizer, sched)
    ds = SyntheticLMDataset(cfg.vocab_size, seq_len, batch, seed=seed)

    enc_key = jax.random.key(seed + 1)

    def add_encoder(b, leading=None):
        if cfg.family == "vlm":
            e = V.dummy_patch_embeddings(enc_key, cfg, batch if leading is None
                                         else batch // n_peers)
        elif cfg.family == "audio":
            e = V.dummy_frame_embeddings(enc_key, cfg, batch if leading is None
                                         else batch // n_peers)
        else:
            return b
        if leading is not None:
            e = jnp.broadcast_to(e[None], (leading,) + e.shape)
        b["encoder_out"] = e
        return b

    def loss_fn(p, b):
        return T.lm_loss(p, cfg, b["tokens"], b["labels"],
                         encoder_out=b.get("encoder_out"))

    history = []
    t0 = time.time()
    if dist == "gossip":
        assert batch % n_peers == 0
        gcfg = GossipConfig(schedule=schedule, merge=merge)
        sp = stack_for_peers(params, n_peers)
        state = GossipState(sp, opt.init(sp), jnp.zeros((), jnp.int32))
        step_fn = jax.jit(make_gossip_train_step(loss_fn, opt, n_peers, gcfg),
                          static_argnums=(2, 3))
        for s in range(steps):
            raw = next(ds)
            b = {k: jnp.asarray(v).reshape(n_peers, batch // n_peers, seq_len)
                 for k, v in raw.items()}
            b = add_encoder(b, leading=n_peers)
            perm, _ = perms_for_step(gcfg, s, n_peers)
            state, loss, _ = step_fn(state, b, tuple(int(x) for x in perm), None)
            if (s + 1) % log_every == 0 or s == steps - 1:
                dis = float(peer_disagreement(state.params))
                print(f"step {s+1:5d}  loss {float(loss):.4f}  "
                      f"peer-disagreement {dis:.2e}  "
                      f"({(time.time()-t0)/(s+1):.2f}s/step)")
                history.append((s + 1, float(loss), dis))
            if ckpt_dir and ckpt_every and (s + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, s + 1,
                                {"params": unstack_mean(state.params)})
        final_params = unstack_mean(state.params)
    else:
        step_fn = jax.jit(make_allreduce_train_step(loss_fn, opt))
        opt_state = opt.init(params)
        step_idx = jnp.zeros((), jnp.int32)
        for s in range(steps):
            b = {k: jnp.asarray(v) for k, v in next(ds).items()}
            b = add_encoder(b)
            params, opt_state, loss, _ = step_fn(params, opt_state, b, step_idx)
            step_idx = step_idx + 1
            if (s + 1) % log_every == 0 or s == steps - 1:
                print(f"step {s+1:5d}  loss {float(loss):.4f}  "
                      f"({(time.time()-t0)/(s+1):.2f}s/step)")
                history.append((s + 1, float(loss), 0.0))
            if ckpt_dir and ckpt_every and (s + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, s + 1, {"params": params})
        final_params = params
    return final_params, history


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--dist", default="allreduce", choices=["allreduce", "gossip"])
    p.add_argument("--peers", type=int, default=4)
    p.add_argument("--merge", default="mu", choices=["mu", "um", "rw"])
    p.add_argument("--schedule", default="hypercube")
    p.add_argument("--optimizer", default="adamw")
    p.add_argument("--d-model", type=int, default=0)
    p.add_argument("--layers", type=int, default=0)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--seed", type=int, default=0)
    a = p.parse_args()
    train(a.arch, reduced=a.reduced, steps=a.steps, batch=a.batch,
          seq_len=a.seq_len, lr=a.lr, dist=a.dist, n_peers=a.peers,
          merge=a.merge, schedule=a.schedule, optimizer=a.optimizer,
          seed=a.seed, ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
          d_model=a.d_model, layers=a.layers)


if __name__ == "__main__":
    main()
