import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) combination this lowers and
compiles the corresponding step program against ShapeDtypeStruct stand-ins
(no allocation), prints memory_analysis() (fits-in-HBM proof) and
cost_analysis() (FLOPs/bytes for §Roofline), and parses the collective
schedule from the compiled HLO.

The two XLA_FLAGS lines above MUST stay the first statements of this module
— jax locks the device count on first init, and only the dry-run may see 512
placeholder devices (tests and benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.config import INPUT_SHAPES, get_config
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh, num_chips
from repro.launch.specs import build_step, resolve_variant

# documented skips (DESIGN.md §4)
SKIPS = {("whisper-medium", "long_500k"): "decoder specified for <=448 target "
         "positions / 30-s encoder windows; 524k cache contradicts the arch"}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               dist: str = "allreduce", optimizer: str | None = None,
               decode_profile: str = "context",
               verbose: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": SKIPS[(arch, shape_name)]}
    if optimizer is None:
        # 405B-class: bf16-momentum SGD (AdamW f32 moments cannot fit 16G HBM
        # on a single pod; see EXPERIMENTS.md §Dry-run)
        optimizer = "sgdm" if arch == "llama3-405b" else "adamw"

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, arg_sds, in_sh, notes = build_step(cfg, shape, mesh, dist=dist,
                                           optimizer=optimizer,
                                           decode_profile=decode_profile)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*arg_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # CPU backend may not implement it
            mem["error"] = str(e)

        vcfg, _ = resolve_variant(cfg, shape)
        mf = RL.model_flops_for(vcfg, shape)
        raw = RL.analyze(compiled, chips=num_chips(mesh), model_flops=mf,
                         hlo_text=compiled.as_text())

    # depth-extrapolated roofline (scan bodies are undercounted by
    # cost_analysis; see launch/costs.py)
    from repro.launch.costs import fd_roofline
    roof = fd_roofline(cfg, shape, mesh, dist=dist, optimizer=optimizer,
                       decode_profile=decode_profile)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "dist": dist, "optimizer": optimizer, "status": "ok",
        "notes": notes,
        "chips": num_chips(mesh),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "raw_flops_per_device": raw.flops_per_device,
        "raw_collective_counts": raw.collective_counts,
        "flops_per_device": roof.flops_per_device,
        "bytes_per_device": roof.bytes_per_device,
        "collective_operand_bytes": roof.collective_bytes_per_device,
        "collective_wire_bytes": roof.wire_bytes_per_device,
        "collectives": roof.collectives,
        "collective_counts": roof.collective_counts,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "dominant": roof.dominant,
        "model_flops": mf,
        "useful_ratio": roof.useful_ratio,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']} ({dist})] "
              f"compile {t_compile:.0f}s  dominant={roof.dominant}  "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms useful={roof.useful_ratio:.2f}")
        print("  memory_analysis:", mem)
        print("  collectives:", roof.collective_counts)
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--dist", default="allreduce", choices=["allreduce", "gossip"])
    p.add_argument("--optimizer", default=None)
    p.add_argument("--out", default="results/dryrun")
    args = p.parse_args()

    from repro.configs import ARCH_IDS
    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}__{args.dist}"
                fp = outdir / f"{tag}.json"
                if fp.exists():
                    rec = json.loads(fp.read_text())
                    print(f"[cached] {tag}: {rec['status']}")
                    n_ok += rec["status"] == "ok"
                    n_skip += rec["status"] == "skip"
                    continue
                try:
                    rec = dryrun_one(arch, shape, multi_pod=multi,
                                     dist=args.dist, optimizer=args.optimizer)
                except ValueError as e:   # documented skip raised in variant
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "skip", "reason": str(e)}
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "fail", "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}: {e}")
                fp.write_text(json.dumps(rec, indent=1))
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skip"
                n_fail += rec["status"] == "fail"
    print(f"dry-run complete: ok={n_ok} skip={n_skip} fail={n_fail}")


if __name__ == "__main__":
    main()
