"""Batched decode server.

Serves a model with batched requests: prompts are prefilled into the KV /
recurrent-state cache, then decoded greedily one token per step for the
whole batch (the decode_32k / long_500k workload shapes lower exactly this
``serve_step``).

Prefill here feeds the prompt through ``decode_step`` position-by-position
(cache-filling is exact; a fused full-sequence prefill that emits the cache
directly is the production optimization and shares all kernels with
forward()).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 4 --prompt-len 32 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced_config
from repro.models import transformer as T
from repro.models import vision as V


class DecodeServer:
    """Holds params + compiled step; serves batches of token prompts."""

    def __init__(self, cfg, params, *, batch: int, max_len: int,
                 window: int | None = None, fused_prefill: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.window = window
        self.fused_prefill = fused_prefill
        self._src = None
        self.cache = T.init_cache(cfg, batch, max_len, window)
        if cfg.family in ("vlm", "audio"):
            self._attach_cross_kv()
        self._step = jax.jit(
            lambda p, tok, cache, idx: T.decode_step(p, cfg, tok, cache, idx))
        self._prefill = jax.jit(
            lambda p, toks, enc: T.prefill(p, cfg, toks, max_len,
                                           encoder_out=enc, window=window))

    def _attach_cross_kv(self):
        """Fill the cross-attention K/V cache slots from the (stubbed)
        encoder output — the serve-time analogue of encoder prefill."""
        cfg = self.cfg
        key = jax.random.key(0)
        if cfg.family == "vlm":
            src = V.dummy_patch_embeddings(key, cfg, self.batch)
            self._src = src
        else:
            raw = V.dummy_frame_embeddings(key, cfg, self.batch)
            self._src = raw          # T.prefill runs the encoder itself
            from repro.models.encdec import encoder_forward
            src = encoder_forward(self.params["encoder"], cfg, raw)

        def fill(blocks_cache, blocks_params, kinds):
            for j, kind in enumerate(kinds):
                if kind not in ("cross", "selfcross"):
                    continue
                lc = blocks_cache[f"l{j}"]
                nb = lc["ck"].shape[0]
                cks, cvs = [], []
                for i in range(nb):
                    lp = jax.tree.map(lambda p: p[i], blocks_params)[f"l{j}"]
                    k = jnp.einsum("bsd,dhk->bshk", src, lp["cross_attn"]["wk"].astype(src.dtype))
                    v = jnp.einsum("bsd,dhk->bshk", src, lp["cross_attn"]["wv"].astype(src.dtype))
                    cks.append(k.astype(lc["ck"].dtype))
                    cvs.append(v.astype(lc["cv"].dtype))
                lc["ck"] = jnp.stack(cks)
                lc["cv"] = jnp.stack(cvs)

        if "blocks" in self.cache:
            fill(self.cache["blocks"], self.params["blocks"], self.cfg.layer_pattern)

    def prefill(self, prompts: np.ndarray):
        """prompts: (batch, prompt_len) int32. Fills the cache.

        Fused path (default): one full-sequence forward emits the whole
        cache (tests/test_fused_prefill.py proves equivalence to the
        token-by-token path, which remains available with
        ``fused_prefill=False``)."""
        assert prompts.shape[0] == self.batch
        if self.fused_prefill:
            toks = jnp.asarray(prompts, jnp.int32)
            logits, self.cache = self._prefill(self.params, toks, self._src)
            return logits, prompts.shape[1]
        logits = None
        for i in range(prompts.shape[1]):
            tok = jnp.asarray(prompts[:, i], jnp.int32)
            logits, self.cache = self._step(self.params, tok, self.cache,
                                            jnp.int32(i))
        return logits, prompts.shape[1]

    def decode(self, first_logits, start: int, steps: int, *, greedy=True,
               key=None):
        """Greedy (or sampled) continuation for the whole batch."""
        out = []
        logits = first_logits
        for s in range(steps):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(np.asarray(tok))
            logits, self.cache = self._step(self.params, tok, self.cache,
                                            jnp.int32(start + s))
        return np.stack(out, axis=1)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--decode-steps", type=int, default=32)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--window", type=int, default=0)
    a = p.parse_args()

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = reduced_config(cfg, vocab=2048)
    params = T.init_params(jax.random.key(0), cfg)
    srv = DecodeServer(cfg, params, batch=a.batch, max_len=a.max_len,
                       window=a.window or None)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (a.batch, a.prompt_len))
    t0 = time.time()
    logits, start = srv.prefill(prompts)
    t1 = time.time()
    toks = srv.decode(logits, start, a.decode_steps)
    t2 = time.time()
    print(f"arch={cfg.name} batch={a.batch} prefill {a.prompt_len} tok in "
          f"{t1-t0:.2f}s; decoded {a.decode_steps} tok in {t2-t1:.2f}s "
          f"({a.decode_steps*a.batch/(t2-t1):.1f} tok/s)")
    print("sample continuation:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
