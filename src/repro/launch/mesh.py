"""Production mesh builders.

Target: TPU v5e pods — 256 chips per pod, 2 pods for the multi-pod run.
``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax

# TPU v5e roofline constants (per chip), per the assignment
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names, for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_chips(mesh) -> int:
    return int(mesh.devices.size)
