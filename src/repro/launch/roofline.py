"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds (per-step):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` on an SPMD executable reports *per-device* flops/bytes,
so we scale by ``chips`` to get the global quantities before applying the
formulas (net effect: per-device quantity / per-chip peak — the physically
meaningful number).

collective_bytes is NOT in cost_analysis: we parse the compiled HLO and sum
the **operand** sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Result shapes are printed per-device; we
recover operand sizes per op semantics (all-gather operand = result/G,
reduce-scatter operand = result×G, G = replica-group size).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# result-typed collective ops:  %name = TYPE[shape] op-name(
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    per_op: Dict[str, int] = field(default_factory=dict)      # op -> operand bytes
    count: Dict[str, int] = field(default_factory=dict)
    total_operand_bytes: int = 0                              # per device
    wire_bytes: int = 0                                       # per device, algo-aware


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device operand bytes of every collective in an HLO module."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:   # the -start op already counted the bytes
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        res = _shape_bytes(dtype, dims)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if op == "all-gather":
            operand = res // max(g, 1)
            wire = res - operand                     # receives G-1 shards
        elif op == "reduce-scatter":
            operand = res * g
            wire = res * (g - 1)
        elif op == "all-reduce":
            operand = res
            wire = 2 * res * (g - 1) // max(g, 1)    # ring: reduce-scatter + all-gather
        else:                                        # all-to-all, collective-permute
            operand = res
            wire = res
        st.per_op[op] = st.per_op.get(op, 0) + operand
        st.count[op] = st.count.get(op, 0) + 1
        st.total_operand_bytes += operand
        st.wire_bytes += wire
    return st


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    wire_bytes_per_device: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: Dict[str, int]
    collective_counts: Dict[str, int]


def analyze(compiled, *, chips: int, model_flops: float = 0.0,
            hlo_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / (flops * chips) if flops > 0 else 0.0
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=float(coll.total_operand_bytes),
        wire_bytes_per_device=float(coll.wire_bytes),
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        collectives=coll.per_op,
        collective_counts=coll.count,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per step.

    D = tokens processed: global_batch×seq for train/prefill, global_batch
    for one decode step. Train counts fwd+bwd (the 6); inference counts 2·N·D."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch
