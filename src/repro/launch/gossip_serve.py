"""Gossip-ensemble request loop: batch accumulation over live snapshots.

The gossip rework of the ``DecodeServer`` seed shape (``launch/serve.py``):
instead of a KV cache fed by prefill, the server holds the latest
:class:`repro.core.serving.QuerySnapshot` of a *running* protocol and
answers batches of feature-vector queries with the cache majority vote
(Algorithm 4 / Eq. 8 as a service). Wire it to an engine by passing
``server.serve_hook`` as the ``serve_hook=`` of
``repro.core.simulation.run_simulation`` — the hook refreshes the snapshot
at every eval point while the protocol keeps gossiping underneath.

Request path: ``submit()`` accumulates queries; every full ``batch_size``
batch is answered immediately (node assignment by the configured policy,
then ``serve_voted`` / ``serve_voted_kernel``, optionally ``serve_fresh``
alongside for the fresh-vs-voted comparison); ``flush()`` pads the tail to
the batch shape — one compiled signature per (N, batch) — and slices the
answers back. Per-batch latency is measured around the predict dispatch
with the answer blocked to completion and recorded into the shared
fixed-bucket :class:`repro.core.telemetry.LatencyHistogram`; ``stats()``
aggregates queries/s and p50/p90/p99/p999 batch latency from it (the same
histogram BENCH_serving.json dumps bucket-wise). Pass ``telemetry=`` to
additionally record snapshot-adoption and batch-assembly spans on the
"serving" trace track.

    PYTHONPATH=src python examples/serve_batched.py    # end-to-end driver
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import serving
from repro.core import telemetry as telemetry_mod
from repro.core.telemetry import LatencyHistogram


@dataclass
class ServedBatch:
    """One answered batch: which snapshot served it and how fast."""
    cycle: int                 # protocol cycle of the serving snapshot
    size: int                  # real queries (the tail batch is padded)
    latency_s: float           # dispatch -> answers materialized
    query_ids: np.ndarray      # (size,) submission order ids
    assign: np.ndarray         # (size,) serving node per query
    preds: np.ndarray          # (size,) ±1 voted answers
    preds_fresh: Optional[np.ndarray] = None   # (size,) PREDICT answers


@dataclass
class ServeStats:
    queries: int
    batches: int
    queries_per_sec: float
    p50_latency_s: float
    p99_latency_s: float
    serve_seconds: float
    # histogram-backed tail percentiles (same fixed buckets everywhere)
    p90_latency_s: float = 0.0
    p999_latency_s: float = 0.0
    latency_hist: Optional[dict] = None


@dataclass
class GossipServer:
    """Holds the live snapshot + compiled batched vote; serves query batches.

    ``policy``: node assignment for incoming queries
    (``serving.ASSIGN_POLICIES``). ``use_kernel`` answers with the fused
    Pallas ``voted_predict_batched`` path instead of the jnp einsum path —
    the two are bitwise-interchangeable. ``compare_fresh`` additionally
    answers every batch with the freshest-model PREDICT (outside the
    latency window) for the fresh-vs-voted accuracy comparison. For a
    fixed ``seed`` and submission order the served answers are
    reproducible bit for bit."""
    batch_size: int = 256
    policy: str = "uniform"
    seed: int = 0
    use_kernel: bool = False
    compare_fresh: bool = True
    telemetry: Optional[telemetry_mod.Telemetry] = None

    snapshot: Optional[serving.QuerySnapshot] = None
    snapshot_cycle: int = -1
    batches: List[ServedBatch] = field(default_factory=list)
    hist: LatencyHistogram = field(default_factory=LatencyHistogram)
    _pending_x: List[np.ndarray] = field(default_factory=list)
    _pending_ids: List[int] = field(default_factory=list)
    _next_id: int = 0
    _served: int = 0           # assignment-policy offset across batches

    def __post_init__(self):
        if self.telemetry is not None:
            # share the server's histogram so it rides in the trace export
            self.telemetry.histograms["serve_batch_latency"] = self.hist

    # ------------------------------------------------------------------ hook
    def serve_hook(self, cycle: int, snapshot: serving.QuerySnapshot):
        """The ``serve_hook`` for ``run_simulation``: adopt the fresh
        snapshot, blocking until the engine materialized EVERY leaf (the
        cache tensor dominates at large N) — so the batch latency below
        measures serving, not leftover simulation compute."""
        with telemetry_mod.maybe_span(self.telemetry, "snapshot_adopt",
                                      track="serving", cycle=int(cycle)):
            jax.block_until_ready(snapshot)
            self.snapshot = snapshot
            self.snapshot_cycle = int(cycle)

    # --------------------------------------------------------------- queries
    def submit(self, X) -> None:
        """Accumulate queries (rows of X); answer every full batch."""
        X = np.asarray(X, np.float32)
        for row in X:
            self._pending_x.append(row)
            self._pending_ids.append(self._next_id)
            self._next_id += 1
            if len(self._pending_x) >= self.batch_size:
                self._serve_pending()

    def flush(self) -> None:
        """Answer the partial tail batch (padded to the compiled shape)."""
        if self._pending_x:
            self._serve_pending()

    def _serve_pending(self) -> None:
        with telemetry_mod.maybe_span(self.telemetry, "serve_batch",
                                      track="serving"):
            self._serve_pending_inner()

    def _serve_pending_inner(self) -> None:
        if self.snapshot is None:
            raise RuntimeError("no snapshot yet — wire serve_hook into "
                               "run_simulation before submitting queries")
        k = min(len(self._pending_x), self.batch_size)
        xb = np.stack(self._pending_x[:k])
        ids = np.asarray(self._pending_ids[:k])
        del self._pending_x[:k], self._pending_ids[:k]
        if k < self.batch_size:                  # tail: pad, serve, slice
            xb = np.concatenate(
                [xb, np.zeros((self.batch_size - k, xb.shape[1]),
                              np.float32)])

        snap = self.snapshot
        n_nodes = snap.count.shape[0]
        assign = serving.assign_queries(
            self.batch_size, n_nodes, policy=self.policy, seed=self.seed,
            offset=self._served)
        self._served += k
        xj = jnp.asarray(xb)
        aj = jnp.asarray(assign)

        t0 = time.perf_counter()
        if self.use_kernel:
            preds = serving.serve_voted_kernel(snap.w, snap.count, xj, aj)
        else:
            preds = serving.serve_voted(snap.w, snap.count, xj, aj)
        preds.block_until_ready()
        dt = time.perf_counter() - t0
        self.hist.record(dt)

        fresh = None
        if self.compare_fresh:
            fresh = np.asarray(
                serving.serve_fresh(snap.fresh_w, xj, aj))[:k]
        self.batches.append(ServedBatch(
            cycle=self.snapshot_cycle, size=k, latency_s=dt,
            query_ids=ids, assign=assign[:k],
            preds=np.asarray(preds)[:k], preds_fresh=fresh))

    # ----------------------------------------------------------------- stats
    def answers(self) -> np.ndarray:
        """All voted answers in submission order."""
        out = np.zeros(self._next_id, np.float32)
        for b in self.batches:
            out[b.query_ids] = b.preds
        return out

    def answers_fresh(self) -> np.ndarray:
        out = np.zeros(self._next_id, np.float32)
        for b in self.batches:
            if b.preds_fresh is not None:
                out[b.query_ids] = b.preds_fresh
        return out

    def stats(self) -> ServeStats:
        """Aggregate from the shared fixed-bucket histogram — the same
        p50/p90/p99/p999 estimator every latency number in the repo uses
        (``repro.core.telemetry.LatencyHistogram``; the previous inline
        ``np.percentile`` copy is gone)."""
        h = self.hist
        total = h.total
        q = int(sum(b.size for b in self.batches))
        return ServeStats(
            queries=q, batches=len(self.batches),
            queries_per_sec=q / total if total > 0 else 0.0,
            p50_latency_s=h.p50, p99_latency_s=h.p99,
            serve_seconds=total, p90_latency_s=h.p90,
            p999_latency_s=h.p999,
            latency_hist=h.to_dict() if h.count else None)
