"""Gossip-SGD: the paper's protocol as a datacenter training primitive.

Each data-parallel replica is a *peer* holding its own (divergent) copy of
the model. Instead of all-reducing gradients every step, a peer takes a
local optimizer step and **averages parameters with one partner** chosen by
a time-varying permutation — exactly CREATEMODELMU/UM (Algorithm 2) with a
deterministic peer-sampling schedule:

  MU:  params <- update( merge(params, partner(params)) )   (merge, then step)
  UM:  params <- merge( update(params), update(partner) )   (step, then merge)
  RW:  no merge (independent local SGD — the paper's baseline)

Communication cost per step per peer = 1 model (one ppermute hop), vs
2×model for ring all-reduce of gradients — the paper's 'one message per
cycle' economy, measurable in the dry-run collective-bytes term.

Implementation: pure pjit. Per-peer parameters are stacked on a leading
'peers' dim sharded over the peer mesh axes ('data', or 'pod' for models
that only fit one copy per pod); the merge is ``take(params, perm, axis=0)``
which XLA lowers to a collective-permute over the peer axis. The loss is
vmapped over (peer, per-peer batch), so compute is identical to plain data
parallelism — only the cross-replica reduction changes, which is precisely
the paper's intervention.

Multi-pod hierarchy: with peers on the 'data' axis inside each pod, an
additional cross-pod merge runs every ``pod_every`` steps (Section II's
communication-cost hierarchy: slow links used 1/K as often).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import GossipConfig
from repro.core.peer_sampling import partner_schedule
from repro.optim.optimizers import Optimizer


class GossipState(NamedTuple):
    params: dict            # per-peer stacked params (peers, ...)
    opt_state: dict         # per-peer stacked optimizer state
    step: jnp.ndarray       # () int32


# ---------------------------------------------------------------------------
# wire dtypes — shared by the on-mesh optimizer (``exchange_dtype``) and the
# protocol simulator (``GossipLinearConfig.wire_dtype``): the transmitted
# model is quantized on the wire, the merge arithmetic stays f32.
#
# Two families:
#
# * float wire dtypes ("bf16"/"f16") — a plain dtype cast at send time;
# * sub-byte wire dtypes ("int8"/"int8_sr") — per-message affine int8
#   quantization: each transmitted model carries an f16 (scale, zero_point)
#   pair computed from that message's coefficient range, and the receiver
#   dequantizes before the f32 merge. "int8_sr" replaces round-to-nearest
#   with stochastic rounding (unbiased: E[q] = w), driven by a counter-based
#   threefry key so runs stay reproducible.
# ---------------------------------------------------------------------------

WIRE_DTYPES = {"bf16": jnp.bfloat16, "f16": jnp.float16, "f32": jnp.float32,
               "int8": jnp.int8, "int8_sr": jnp.int8}

# wire-dtype names that use per-message affine int8 quantization
INT8_WIRE_DTYPES = frozenset({"int8", "int8_sr"})

# int8 payloads target [-126, 126]: one code of headroom keeps the clip at
# ±127 inert even after the scale is rounded to its f16 wire representation
INT8_QMAX = 126


def resolve_wire_dtype(name):
    """Wire-dtype name -> jnp dtype, or None for full precision.

    ``None``/``""``/``"f32"`` mean no quantization (f32 is the native payload
    dtype, so requesting it is a no-op). ``"int8"`` and ``"int8_sr"`` both
    resolve to ``jnp.int8`` — the payload storage dtype; the rounding mode is
    carried by the *name* (see :func:`quantize_wire`)."""
    if not name or name == "f32":
        return None
    try:
        return WIRE_DTYPES[name]
    except KeyError:
        raise ValueError(f"unknown wire dtype {name!r} "
                         f"(expected one of {sorted(WIRE_DTYPES)})") from None


def is_quantized_wire(name) -> bool:
    """True for the affine-int8 wire dtypes (payload needs scale/zero-point)."""
    return name in INT8_WIRE_DTYPES


def is_stochastic_wire(name) -> bool:
    """True when the wire dtype rounds stochastically (needs a PRNG key)."""
    return name == "int8_sr"


def wire_itemsize(name) -> int:
    """Bytes per transmitted model coefficient for a wire-dtype name."""
    dt = resolve_wire_dtype(name)
    return 4 if dt is None else jnp.dtype(dt).itemsize


def wire_overhead_bytes(name) -> int:
    """Per-message metadata bytes beyond the coefficients: the affine int8
    dtypes ship an f16 scale + f16 zero-point with every message."""
    return 4 if is_quantized_wire(name) else 0


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32 block cipher on uint32 arrays — op-for-op the unrolled
    lowering of JAX's ``threefry2x32_p`` (jax._src.prng), so the bits are
    identical to what ``jax.random`` produces for the same key/counters.
    Pure jnp integer ops: usable under jit, inside ``lax.scan`` bodies and
    inside Pallas kernels alike."""
    def rotl(v, r):
        return (v << jnp.uint32(r)) | (v >> jnp.uint32(32 - r))

    rot = ((13, 15, 26, 6), (17, 29, 16, 24))
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(0x1BD11BDA))
    x = [x0 + ks[0], x1 + ks[1]]
    for i in range(5):
        for r in rot[i % 2]:
            x[0] = x[0] + x[1]
            x[1] = rotl(x[1], r)
            x[1] = x[0] ^ x[1]
        x[0] = x[0] + ks[(i + 1) % 3]
        x[1] = x[1] + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x[0], x[1]


def uniform_at(k0, k1, p, size: int):
    """``jax.random.uniform(key, shape)`` evaluated at flat positions ``p``
    of an array with ``size`` total elements.

    Reproduces the original (non-partitionable) threefry counter scheme of
    ``jax._src.prng._threefry_random_bits_original`` bit for bit: the iota
    counter array of ``size`` elements is split in half (odd sizes pad one
    zero), element p < half is lane 0 of the block (p, half+p), element
    p >= half is lane 1 of the block (p-half, p) — each element evaluates
    exactly one 20-round block, with no cross-lane communication. The
    uint32 bits map to [0, 1) floats with the same mantissa-fill transform
    ``jax.random.uniform`` applies.

    This is what lets both the Pallas send kernel and the compacted
    send path regenerate the "int8_sr" noise for an arbitrary *subset* of
    messages without a dense (N, d) draw, bitwise-equal to the full-array
    ``jax.random.uniform`` the reference engine consumes."""
    if jax.config.jax_threefry_partitionable:
        # the partitionable PRNG uses a different counter scheme: this
        # helper would silently diverge from jax.random.uniform and break
        # the engines' bitwise int8_sr parity contract — fail loudly
        # instead (supporting it means implementing the partitionable
        # scheme here AND in the Pallas send kernel, both parity-tested)
        raise NotImplementedError(
            "uniform_at implements the original (non-partitionable) "
            "threefry counter scheme; run with "
            "jax_threefry_partitionable=False for the int8_sr wire dtype")
    half = (size + 1) // 2
    is_lo = p < half
    pair = p + half
    x0 = jnp.where(is_lo, p, p - half)
    # the odd-size zero pad sits at padded position `size`
    x1 = jnp.where(is_lo, jnp.where(pair < size, pair, 0), p)
    y0, y1 = threefry2x32(k0, k1, x0.astype(jnp.uint32),
                          x1.astype(jnp.uint32))
    bits = jnp.where(is_lo, y0, y1)
    fbits = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    return jax.lax.bitcast_convert_type(fbits, jnp.float32) - 1.0


def sr_noise_for_rows(key, rows, d: int, n_total: int):
    """The ``jax.random.uniform(key, (n_total, d))`` noise of a full-array
    "int8_sr" quantization, evaluated only at the given ``rows``:
    ``sr_noise_for_rows(key, rows, d, n)`` ==
    ``jax.random.uniform(key, (n, d))[rows]`` bitwise, at O(len(rows)·d)
    threefry work. ``key`` is a typed threefry key (the per-cycle
    ``k_recv`` slot)."""
    kd = jax.random.key_data(key).astype(jnp.uint32)
    p = rows[:, None] * d + jnp.arange(d, dtype=rows.dtype)[None, :]
    return uniform_at(kd[0], kd[1], p, n_total * d)


def quantize_wire(w, name, key=None, noise=None):
    """Per-message affine int8 quantization of a batch of models.

    ``w``: (..., d) f32 — each slice along the last axis is one transmitted
    model (one message). Returns ``(q, scale, zp)`` with ``q`` int8 of
    ``w.shape`` and ``scale``/``zp`` f16 of ``w.shape[:-1]`` — the f16
    values are exactly what rides the wire, and the SAME rounded values are
    used by the quantizer itself, so the round-trip error is bounded by one
    quantization step of the *transmitted* scale:

      |w - dequantize(q, scale, zp)| <= scale      (per coordinate)

    (<= scale/2 for round-to-nearest; stochastic rounding is unbiased but
    may land a full step away). ``zp`` is the f16-rounded range midpoint and
    ``scale`` covers the residual range ``max(hi-zp, zp-lo)`` over
    ``INT8_QMAX`` codes, so codes stay within ±127 even after f16 rounding —
    the defensive clip never distorts.

    ``name``: "int8" rounds to nearest (deterministic); "int8_sr" adds
    uniform [0, 1) noise before the floor — ``key`` (threefry) is required
    and makes the draw reproducible: both simulator engines feed the same
    per-cycle ``k_recv`` key here, keeping cross-engine parity bitwise.
    ``noise`` (optional, "int8_sr" only) supplies the uniform draw directly
    instead of ``key`` — the compacted send path passes
    :func:`sr_noise_for_rows` values so a subset quantization consumes
    exactly the noise the full-array draw would have given those rows.

    Precondition: coefficients are expected inside the f16-representable
    range (|w| ≲ 6.5e4 — far beyond any non-divergent linear model here;
    Pegasos is bounded by 1/sqrt(lam)). Outside it the f16 scale/zero-point
    SATURATE at the f16 max instead of overflowing to inf, so a divergent
    run stays finite on the wire (grossly quantized) rather than flooding
    every merge with NaNs."""
    f16_max = float(jnp.finfo(jnp.float16).max)
    sat = lambda v: jnp.clip(v, -f16_max, f16_max).astype(jnp.float16)
    w = w.astype(jnp.float32)
    lo = jnp.min(w, axis=-1)
    hi = jnp.max(w, axis=-1)
    zp = sat((hi + lo) * 0.5)
    zpf = zp.astype(jnp.float32)
    scale = sat(jnp.maximum(hi - zpf, zpf - lo) / INT8_QMAX)
    # guarded divisor: a constant message (hi == lo, scale 0) maps every
    # coordinate to code 0 and dequantizes to exactly zp
    sf = jnp.where(scale > 0, scale, jnp.float16(1)).astype(jnp.float32)
    u = (w - zpf[..., None]) / sf[..., None]
    if name == "int8_sr":
        if noise is None:
            if key is None:
                raise ValueError("int8_sr quantization needs a PRNG key")
            noise = jax.random.uniform(key, w.shape)
        u = jnp.floor(u + noise)
    else:
        u = jnp.round(u)
    q = jnp.clip(u, -127, 127).astype(jnp.int8)
    return q, scale, zp


def dequantize_wire(q, scale, zp):
    """Inverse of :func:`quantize_wire`: ``q * scale + zp`` in f32.

    The Pallas ``gossip_cycle`` kernel applies this same expression in-VMEM
    (same op order), so kernel and jnp paths agree bitwise."""
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            + zp.astype(jnp.float32)[..., None])


def stack_for_peers(params, n_peers: int):
    """Replicate params onto the peer axis: (…)-tree -> (peers, …)-tree."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (n_peers,) + p.shape),
                        params)


def unstack_mean(params):
    """Consensus model: average over the peer axis (what the paper's nodes
    would each converge to; used for eval/checkpointing)."""
    return jax.tree.map(lambda p: jnp.mean(p.astype(jnp.float32), axis=0), params)


def gossip_merge(params, perm, *, mesh=None, peer_axes: Tuple[str, ...] = (),
                 exchange_dtype=None):
    """MERGE with the partner given by ``perm`` (symmetric pairing):
    w_i <- (w_i + w_perm[i]) / 2.

    ``perm`` must be a STATIC (numpy/tuple) index vector — the partner
    schedule is compile-time data. With a mesh, the exchange is an honest
    ``lax.ppermute`` over the peer axes inside a partial-manual
    ``shard_map`` (non-peer dims stay auto-sharded). Without a mesh
    (CPU tests / single device) it is a static-index take, which is
    numerically identical.

    Why not a traced ``jnp.take``: GSPMD cannot prove a traced gather is a
    permutation and lowers it to a full all-gather of the params over the
    peer axis — measured at 5.7 GB/device/step for qwen3-8b vs 0.03 GB for
    the ppermute (EXPERIMENTS.md §Perf, gossip hillclimb).

    ``exchange_dtype`` (beyond-paper): wire dtype for the exchanged model
    (e.g. bf16) — the partner's contribution is quantized on the wire but
    the average is taken in f32, halving the sync wire bytes. ``jnp.int8``
    (``resolve_wire_dtype("int8")``/``("int8_sr")``) selects per-row affine
    int8 quantization — each leaf row is quantized over its last axis with
    :func:`quantize_wire` and dequantized before the f32 average, the exact
    semantics of the protocol simulator's int8 wire path (pinned in
    tests/test_wire_quantization.py). The optimizer path always rounds to
    nearest: stochastic rounding needs a per-step key, which the simulator's
    per-cycle ``k_recv`` stream provides but the train step does not thread."""
    perm = np.asarray(perm)
    pairs = [(s, int(perm[s])) for s in range(len(perm))]
    int8_exchange = (exchange_dtype is not None
                     and jnp.dtype(exchange_dtype) == jnp.int8)

    def int8_wire(v):
        """Affine round-trip with per-peer-row grouping: a leaf must never
        share one scale across peers, so rank-<2 leaves (per-peer scalars
        here; per-device scalars in the mesh body) gain a trailing axis of
        one before the per-last-axis quantization."""
        x = v[..., None] if v.ndim < 2 else v
        return dequantize_wire(*quantize_wire(x, "int8")).reshape(v.shape)

    def on_wire(partner):
        if exchange_dtype is None:
            return partner
        if int8_exchange:
            return int8_wire(partner)
        return partner.astype(exchange_dtype)

    def avg_take(p):
        partner = on_wire(p[perm])
        return ((p.astype(jnp.float32) + partner.astype(jnp.float32)) / 2.0).astype(p.dtype)

    if mesh is None or not peer_axes:
        return jax.tree.map(avg_take, params)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    psz = int(np.prod([sizes[a] for a in peer_axes]))
    if psz == 1 or psz != len(perm):
        return jax.tree.map(avg_take, params)

    from jax.sharding import PartitionSpec as PS
    axis = peer_axes if len(peer_axes) > 1 else peer_axes[0]

    def body(tree):
        def avg(x):
            if exchange_dtype is None or x.dtype == exchange_dtype:
                xin = jax.lax.ppermute(x, axis, pairs)
            elif int8_exchange:
                # quantize locally, permute the int8 codes plus their f16
                # scale/zero-point, dequantize on arrival: d + 4 wire bytes
                # per row instead of 4d. Integer codes are opaque to the
                # algebraic simplifier, so no bitcast trick is needed.
                # Rank-<2 blocks take the same trailing-axis path as
                # ``int8_wire`` so mesh and non-mesh grouping agree.
                xg = x[..., None] if x.ndim < 2 else x
                q, sc, zp = quantize_wire(xg, "int8")
                xin = dequantize_wire(jax.lax.ppermute(q, axis, pairs),
                                      jax.lax.ppermute(sc, axis, pairs),
                                      jax.lax.ppermute(zp, axis, pairs)
                                      ).reshape(x.shape)
            else:
                # permute a bitcast integer view of the quantized value:
                # a plain convert around the ppermute gets commuted back to
                # the wide dtype by the algebraic simplifier (the wire-dtype
                # saving would silently vanish); a bitcast is opaque to it.
                xw = jax.lax.bitcast_convert_type(x.astype(exchange_dtype),
                                                  jnp.uint16)
                xin = jax.lax.bitcast_convert_type(
                    jax.lax.ppermute(xw, axis, pairs), exchange_dtype)
            return ((x.astype(jnp.float32) + xin.astype(jnp.float32)) / 2.0).astype(x.dtype)
        return jax.tree.map(avg, tree)

    from repro.sharding.compat import shard_map_compat
    return shard_map_compat(body, mesh=mesh, in_specs=PS(axis),
                            out_specs=PS(axis),
                            manual_axes=set(peer_axes))(params)


def peer_disagreement(params):
    """Mean relative L2 distance of each peer from the consensus — the
    model-similarity diagnostic of the paper's Fig. 2, for pytrees."""
    mean = unstack_mean(params)
    num = sum(jnp.sum(jnp.square(p.astype(jnp.float32) - m[None]))
              for p, m in zip(jax.tree.leaves(params), jax.tree.leaves(mean)))
    den = sum(p.shape[0] * jnp.sum(jnp.square(m.astype(jnp.float32)))
              for p, m in zip(jax.tree.leaves(params), jax.tree.leaves(mean)))
    return jnp.sqrt(num / jnp.maximum(den, 1e-12))


def make_gossip_train_step(loss_fn: Callable, opt: Optimizer, n_peers: int,
                           cfg: GossipConfig, *, spmd_axis: Optional[str] = None,
                           mesh=None, peer_axes: Tuple[str, ...] = ()):
    """Build the jittable gossip training step.

    loss_fn(params, batch) -> (loss, metrics) for ONE peer;
    the step takes stacked params (peers, …) and batch (peers, per_peer, …).
    The partner permutation ``perm`` is STATIC (hashable tuple) — jit it
    with ``static_argnums=2``; a schedule has only O(log peers) distinct
    permutations, so the compile cache stays small, and the exchange lowers
    to a true collective-permute (see :func:`gossip_merge`). ``spmd_axis``
    names the mesh axis the peer dim is sharded over (vmap's
    spmd_axis_name), so per-peer activation constraints compose with the
    peer sharding.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    vmap_kw = {"spmd_axis_name": spmd_axis} if spmd_axis else {}
    xdt = resolve_wire_dtype(cfg.exchange_dtype)
    merge_kw = dict(mesh=mesh, exchange_dtype=xdt,
                    peer_axes=peer_axes or
                    ((spmd_axis,) if spmd_axis and mesh is not None else ()))

    def local_update(params, opt_state, batch, step):
        (loss, metrics), grads = jax.vmap(grad_fn, **vmap_kw)(params, batch)
        # optimizers are element-wise -> broadcast over the peer axis;
        # the step counter is shared.
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss.mean(), metrics

    def train_step(state: GossipState, batch, perm, pod_perm=None):
        params, opt_state = state.params, state.opt_state
        if cfg.merge == "mu":
            params = gossip_merge(params, perm, **merge_kw)
        params, opt_state, loss, metrics = local_update(
            params, opt_state, batch, state.step)
        if cfg.merge == "um":
            params = gossip_merge(params, perm, **merge_kw)
        if pod_perm is not None:
            params = gossip_merge(params, pod_perm, **merge_kw)
        return GossipState(params, opt_state, state.step + 1), loss, metrics

    return train_step


def make_allreduce_train_step(loss_fn: Callable, opt: Optimizer):
    """Baseline: conventional data parallelism. Params carry NO peer dim;
    the batch keeps its global leading dim and XLA inserts the gradient
    all-reduce via sharding propagation."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss, metrics

    return train_step


def perms_for_step(cfg: GossipConfig, step: int, n_peers: int,
                   n_pods: int = 1) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Host-side partner permutations for a given step (passed as args)."""
    perm = partner_schedule(cfg.schedule, step, n_peers, cfg.seed)
    pod_perm = None
    if n_pods > 1 and cfg.pod_every > 0 and (step + 1) % cfg.pod_every == 0:
        # pair each peer with the same peer index in the partner pod:
        # global peer id = pod * peers_per_pod + local
        per_pod = n_peers // n_pods
        pods = partner_schedule("hypercube", step // cfg.pod_every, n_pods, cfg.seed)
        pod_perm = np.concatenate([pods[p] * per_pod + np.arange(per_pod)
                                   for p in range(n_pods)])
    return perm, pod_perm


# ---------------------------------------------------------------------------
# shard_map runtime for the paper's linear models (peers == devices)
# ---------------------------------------------------------------------------


def linear_gossip_mesh_step(w, t, X_local, y_local, perm, *, lam: float,
                            variant: str, axis: str = "data",
                            drop_mask=None):
    """One gossip cycle with peers = mesh devices, inside ``shard_map``.

    w: (d,) per-device model, t: () counter, (X_local, y_local): this peer's
    data shard (the fully-distributed limit is one record). ``perm`` pairs
    of (src, dst) for ``lax.ppermute`` over ``axis``. ``drop_mask`` (bool)
    simulates the paper's message-drop failures on-mesh."""
    from repro.core.learners import LinearModel, pegasos_update

    def merge_with_partner(w, t):
        w_in = jax.lax.ppermute(w, axis, perm)
        t_in = jax.lax.ppermute(t, axis, perm)
        if drop_mask is not None:
            w_in = jnp.where(drop_mask, w, w_in)
            t_in = jnp.where(drop_mask, t, t_in)
        return (w + w_in) / 2.0, jnp.maximum(t, t_in)

    def update(w, t):
        m = LinearModel(w, t)
        i = t % X_local.shape[0]
        m = pegasos_update(m, X_local[i], y_local[i], lam)
        return m.w, m.t

    if variant == "mu":
        w, t = merge_with_partner(w, t)
        w, t = update(w, t)
    elif variant == "um":
        w, t = update(w, t)
        w, t = merge_with_partner(w, t)
    else:  # rw
        w, t = update(w, t)
    return w, t
