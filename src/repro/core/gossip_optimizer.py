"""Gossip-SGD: the paper's protocol as a datacenter training primitive.

Each data-parallel replica is a *peer* holding its own (divergent) copy of
the model. Instead of all-reducing gradients every step, a peer takes a
local optimizer step and **averages parameters with one partner** chosen by
a time-varying permutation — exactly CREATEMODELMU/UM (Algorithm 2) with a
deterministic peer-sampling schedule:

  MU:  params <- update( merge(params, partner(params)) )   (merge, then step)
  UM:  params <- merge( update(params), update(partner) )   (step, then merge)
  RW:  no merge (independent local SGD — the paper's baseline)

Communication cost per step per peer = 1 model (one ppermute hop), vs
2×model for ring all-reduce of gradients — the paper's 'one message per
cycle' economy, measurable in the dry-run collective-bytes term.

Implementation: pure pjit. Per-peer parameters are stacked on a leading
'peers' dim sharded over the peer mesh axes ('data', or 'pod' for models
that only fit one copy per pod); the merge is ``take(params, perm, axis=0)``
which XLA lowers to a collective-permute over the peer axis. The loss is
vmapped over (peer, per-peer batch), so compute is identical to plain data
parallelism — only the cross-replica reduction changes, which is precisely
the paper's intervention.

Multi-pod hierarchy: with peers on the 'data' axis inside each pod, an
additional cross-pod merge runs every ``pod_every`` steps (Section II's
communication-cost hierarchy: slow links used 1/K as often).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import GossipConfig
from repro.core.peer_sampling import partner_schedule
from repro.optim.optimizers import Optimizer


class GossipState(NamedTuple):
    params: dict            # per-peer stacked params (peers, ...)
    opt_state: dict         # per-peer stacked optimizer state
    step: jnp.ndarray       # () int32


# ---------------------------------------------------------------------------
# wire codecs — the registry, the quantizers and the reproducible-noise
# helpers live in repro.core.wire_codec (one home for the constants and the
# pack/unpack logic shared with the Pallas kernels); re-exported here
# because this module is the optimizer-side consumer (``exchange_dtype``)
# and the historical import site.
# ---------------------------------------------------------------------------

from repro.core.wire_codec import (INT8_QMAX, INT8_WIRE_DTYPES,  # noqa: F401
                                   WIRE_CODECS, WIRE_DTYPES,
                                   dequantize_wire, deterministic_codec,
                                   get_codec, is_quantized_wire,
                                   is_stochastic_wire, quantize_wire,
                                   resolve_wire_dtype, sr_noise_for_rows,
                                   threefry2x32, uniform_at, wire_itemsize,
                                   wire_overhead_bytes)


def _resolve_exchange(exchange_dtype):
    """Normalize ``gossip_merge``'s ``exchange_dtype`` argument.

    Accepts a wire-codec *name* ("bf16", "int8", "int4_ef", …), a plain
    jnp dtype (the legacy spelling: 16-bit floats cast, ``jnp.int8`` = the
    "int8" codec), or None. Returns ``(codec, cast_dtype)`` — exactly one
    of which is non-None for a quantizing exchange: ``codec`` for the
    scale-carrying codecs (always the deterministic sibling — no per-step
    key exists here), ``cast_dtype`` for plain float casts."""
    if exchange_dtype is None:
        return None, None
    if isinstance(exchange_dtype, str):
        codec = deterministic_codec(get_codec(exchange_dtype))
        if codec.quantized:
            return codec, None
        if codec.name == "f32":
            return None, None
        return None, codec.payload_dtype
    dtype = jnp.dtype(exchange_dtype)
    if dtype == jnp.int8:
        return get_codec("int8"), None
    return None, exchange_dtype


def stack_for_peers(params, n_peers: int):
    """Replicate params onto the peer axis: (…)-tree -> (peers, …)-tree."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (n_peers,) + p.shape),
                        params)


def unstack_mean(params):
    """Consensus model: average over the peer axis (what the paper's nodes
    would each converge to; used for eval/checkpointing)."""
    return jax.tree.map(lambda p: jnp.mean(p.astype(jnp.float32), axis=0), params)


def gossip_merge(params, perm, *, mesh=None, peer_axes: Tuple[str, ...] = (),
                 exchange_dtype=None):
    """MERGE with the partner given by ``perm`` (symmetric pairing):
    w_i <- (w_i + w_perm[i]) / 2.

    ``perm`` must be a STATIC (numpy/tuple) index vector — the partner
    schedule is compile-time data. With a mesh, the exchange is an honest
    ``lax.ppermute`` over the peer axes inside a partial-manual
    ``shard_map`` (non-peer dims stay auto-sharded). Without a mesh
    (CPU tests / single device) it is a static-index take, which is
    numerically identical.

    Why not a traced ``jnp.take``: GSPMD cannot prove a traced gather is a
    permutation and lowers it to a full all-gather of the params over the
    peer axis — measured at 5.7 GB/device/step for qwen3-8b vs 0.03 GB for
    the ppermute (EXPERIMENTS.md §Perf, gossip hillclimb).

    ``exchange_dtype`` (beyond-paper): wire representation of the exchanged
    model — the partner's contribution is quantized on the wire but the
    average is taken in f32, cutting the sync wire bytes. Accepts a wire
    *codec name* from ``repro.core.wire_codec.WIRE_CODECS`` ("bf16",
    "int8", "int4", "ternary", …), a plain jnp dtype (legacy spelling:
    bf16/f16 cast; ``jnp.int8`` = the "int8" codec), or None. Quantized
    codecs round-trip each leaf row through ``codec.encode``/``decode``
    over its last axis before the f32 average — the exact semantics of the
    protocol simulator's wire path (pinned in
    tests/test_wire_quantization.py and tests/test_wire_codec.py). The
    optimizer path always rounds to nearest ("int8_sr" maps to its
    deterministic sibling: a train step threads no per-step key) and keeps
    no error-feedback state (the ``_ef`` codecs quantize one-shot here —
    EF residuals are per-*sender* protocol state, which lives in the
    simulator engines, not in the stateless merge)."""
    perm = np.asarray(perm)
    pairs = [(s, int(perm[s])) for s in range(len(perm))]
    codec, cast_dtype = _resolve_exchange(exchange_dtype)

    def codec_roundtrip(v):
        """Quantized round-trip with per-peer-row grouping: a leaf must
        never share one scale across peers, so rank-<2 leaves (per-peer
        scalars here; per-device scalars in the mesh body) gain a trailing
        axis of one before the per-last-axis quantization."""
        x = v[..., None] if v.ndim < 2 else v
        return codec.roundtrip(x).reshape(v.shape)

    def on_wire(partner):
        if codec is not None:
            return codec_roundtrip(partner)
        if cast_dtype is not None:
            return partner.astype(cast_dtype)
        return partner

    def avg_take(p):
        partner = on_wire(p[perm])
        return ((p.astype(jnp.float32) + partner.astype(jnp.float32)) / 2.0).astype(p.dtype)

    if mesh is None or not peer_axes:
        return jax.tree.map(avg_take, params)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    psz = int(np.prod([sizes[a] for a in peer_axes]))
    if psz == 1 or psz != len(perm):
        return jax.tree.map(avg_take, params)

    from jax.sharding import PartitionSpec as PS
    axis = peer_axes if len(peer_axes) > 1 else peer_axes[0]

    def body(tree):
        def avg(x):
            if codec is not None:
                # quantize locally, permute the packed codes plus their f16
                # scale (and zero-point when the codec carries one),
                # dequantize on arrival: payload + overhead wire bytes per
                # row instead of 4d. Integer codes are opaque to the
                # algebraic simplifier, so no bitcast trick is needed.
                # Rank-<2 blocks take the same trailing-axis path as
                # ``codec_roundtrip`` so mesh and non-mesh grouping agree.
                xg = x[..., None] if x.ndim < 2 else x
                payload, sc, zp = codec.encode(xg)
                xin = codec.decode(
                    jax.lax.ppermute(payload, axis, pairs),
                    jax.lax.ppermute(sc, axis, pairs),
                    jax.lax.ppermute(zp, axis, pairs) if zp is not None
                    else None,
                    xg.shape[-1]).reshape(x.shape)
            elif cast_dtype is None or x.dtype == cast_dtype:
                xin = jax.lax.ppermute(x, axis, pairs)
            else:
                # permute a bitcast integer view of the quantized value:
                # a plain convert around the ppermute gets commuted back to
                # the wide dtype by the algebraic simplifier (the wire-dtype
                # saving would silently vanish); a bitcast is opaque to it.
                xw = jax.lax.bitcast_convert_type(x.astype(cast_dtype),
                                                  jnp.uint16)
                xin = jax.lax.bitcast_convert_type(
                    jax.lax.ppermute(xw, axis, pairs), cast_dtype)
            return ((x.astype(jnp.float32) + xin.astype(jnp.float32)) / 2.0).astype(x.dtype)
        return jax.tree.map(avg, tree)

    from repro.sharding.compat import shard_map_compat
    return shard_map_compat(body, mesh=mesh, in_specs=PS(axis),
                            out_specs=PS(axis),
                            manual_axes=set(peer_axes))(params)


def peer_disagreement(params):
    """Mean relative L2 distance of each peer from the consensus — the
    model-similarity diagnostic of the paper's Fig. 2, for pytrees."""
    mean = unstack_mean(params)
    num = sum(jnp.sum(jnp.square(p.astype(jnp.float32) - m[None]))
              for p, m in zip(jax.tree.leaves(params), jax.tree.leaves(mean)))
    den = sum(p.shape[0] * jnp.sum(jnp.square(m.astype(jnp.float32)))
              for p, m in zip(jax.tree.leaves(params), jax.tree.leaves(mean)))
    return jnp.sqrt(num / jnp.maximum(den, 1e-12))


def make_gossip_train_step(loss_fn: Callable, opt: Optimizer, n_peers: int,
                           cfg: GossipConfig, *, spmd_axis: Optional[str] = None,
                           mesh=None, peer_axes: Tuple[str, ...] = ()):
    """Build the jittable gossip training step.

    loss_fn(params, batch) -> (loss, metrics) for ONE peer;
    the step takes stacked params (peers, …) and batch (peers, per_peer, …).
    The partner permutation ``perm`` is STATIC (hashable tuple) — jit it
    with ``static_argnums=2``; a schedule has only O(log peers) distinct
    permutations, so the compile cache stays small, and the exchange lowers
    to a true collective-permute (see :func:`gossip_merge`). ``spmd_axis``
    names the mesh axis the peer dim is sharded over (vmap's
    spmd_axis_name), so per-peer activation constraints compose with the
    peer sharding.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    vmap_kw = {"spmd_axis_name": spmd_axis} if spmd_axis else {}
    # the codec *name* goes straight through — gossip_merge resolves it
    # (any registered wire codec works as an exchange representation)
    merge_kw = dict(mesh=mesh, exchange_dtype=cfg.exchange_dtype or None,
                    peer_axes=peer_axes or
                    ((spmd_axis,) if spmd_axis and mesh is not None else ()))

    def local_update(params, opt_state, batch, step):
        (loss, metrics), grads = jax.vmap(grad_fn, **vmap_kw)(params, batch)
        # optimizers are element-wise -> broadcast over the peer axis;
        # the step counter is shared.
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss.mean(), metrics

    def train_step(state: GossipState, batch, perm, pod_perm=None):
        params, opt_state = state.params, state.opt_state
        if cfg.merge == "mu":
            params = gossip_merge(params, perm, **merge_kw)
        params, opt_state, loss, metrics = local_update(
            params, opt_state, batch, state.step)
        if cfg.merge == "um":
            params = gossip_merge(params, perm, **merge_kw)
        if pod_perm is not None:
            params = gossip_merge(params, pod_perm, **merge_kw)
        return GossipState(params, opt_state, state.step + 1), loss, metrics

    return train_step


def make_allreduce_train_step(loss_fn: Callable, opt: Optimizer):
    """Baseline: conventional data parallelism. Params carry NO peer dim;
    the batch keeps its global leading dim and XLA inserts the gradient
    all-reduce via sharding propagation."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss, metrics

    return train_step


def perms_for_step(cfg: GossipConfig, step: int, n_peers: int,
                   n_pods: int = 1) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Host-side partner permutations for a given step (passed as args)."""
    perm = partner_schedule(cfg.schedule, step, n_peers, cfg.seed)
    pod_perm = None
    if n_pods > 1 and cfg.pod_every > 0 and (step + 1) % cfg.pod_every == 0:
        # pair each peer with the same peer index in the partner pod:
        # global peer id = pod * peers_per_pod + local
        per_pod = n_peers // n_pods
        pods = partner_schedule("hypercube", step // cfg.pod_every, n_pods, cfg.seed)
        pod_perm = np.concatenate([pods[p] * per_pod + np.arange(per_pod)
                                   for p in range(n_pods)])
    return perm, pod_perm


# ---------------------------------------------------------------------------
# shard_map runtime for the paper's linear models (peers == devices)
# ---------------------------------------------------------------------------


def linear_gossip_mesh_step(w, t, X_local, y_local, perm, *, lam: float,
                            variant: str, axis: str = "data",
                            drop_mask=None):
    """One gossip cycle with peers = mesh devices, inside ``shard_map``.

    w: (d,) per-device model, t: () counter, (X_local, y_local): this peer's
    data shard (the fully-distributed limit is one record). ``perm`` pairs
    of (src, dst) for ``lax.ppermute`` over ``axis``. ``drop_mask`` (bool)
    simulates the paper's message-drop failures on-mesh."""
    from repro.core.learners import LinearModel, pegasos_update

    def merge_with_partner(w, t):
        w_in = jax.lax.ppermute(w, axis, perm)
        t_in = jax.lax.ppermute(t, axis, perm)
        if drop_mask is not None:
            w_in = jnp.where(drop_mask, w, w_in)
            t_in = jnp.where(drop_mask, t, t_in)
        return (w + w_in) / 2.0, jnp.maximum(t, t_in)

    def update(w, t):
        m = LinearModel(w, t)
        i = t % X_local.shape[0]
        m = pegasos_update(m, X_local[i], y_local[i], lam)
        return m.w, m.t

    if variant == "mu":
        w, t = merge_with_partner(w, t)
        w, t = update(w, t)
    elif variant == "um":
        w, t = update(w, t)
        w, t = merge_with_partner(w, t)
    else:  # rw
        w, t = update(w, t)
    return w, t
