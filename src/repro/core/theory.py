"""Theorem 1 validation: the P2PegasosMU regret bound.

    (1/t) Σ_i [ f_i(w̄^(i)) − f_i(w*) ]  ≤  G² (log t + 1) / (2 λ t)

where the sequence w^(0..t) follows the *worst ancestor* path of the merge
DAG (Eq. 11), w̄^(i) is the pre-update average of the two ancestors, and
f_i is the λ-strong instantaneous objective (Eq. 10) for the example used at
step i.

We instrument a small exact MU chain: at every merge-update we record
(w̄, example) along the worst-ancestor path, compute f_i(w̄^(i)) − f_i(w*)
with w* obtained by full-batch subgradient descent on f (Eq. 9), and compare
the running average against the bound. G is sup‖∇‖ ≤ λ‖w‖ + max‖x‖, bounded
using the Pegasos ball ‖w‖ ≤ 1/√λ · max‖x‖ (Shalev-Shwartz et al.).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.learners import LinearModel, make_update
from repro.core.merge import merge


def svm_objective(w, X, y, lam: float):
    """f(w) of Eq. (9): λ/2 ‖w‖² + mean hinge loss."""
    hinge = jnp.maximum(0.0, 1.0 - y * (X @ w))
    return lam / 2.0 * jnp.dot(w, w) + jnp.mean(hinge)


def f_i(w, x, y, lam: float):
    """The instantaneous objective of Eq. (10)."""
    return lam / 2.0 * jnp.dot(w, w) + jnp.maximum(0.0, 1.0 - y * jnp.dot(w, x))


def solve_w_star(X, y, lam: float, iters: int = 4000, lr0: float = 1.0):
    """Full-batch Pegasos-style subgradient descent to the global optimum of
    the λ-strongly-convex objective (deterministic, averaged iterates)."""
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    d = X.shape[1]

    @jax.jit
    def step(carry, t):
        w, wsum = carry
        margin = y * (X @ w)
        g = lam * w - jnp.mean(jnp.where(margin < 1.0, 1.0, 0.0)[:, None]
                               * (y[:, None] * X), axis=0)
        eta = 1.0 / (lam * (t + 1.0))
        w = w - eta * g
        return (w, wsum + w), None

    (w, wsum), _ = jax.lax.scan(step, (jnp.zeros(d), jnp.zeros(d)),
                                jnp.arange(iters, dtype=jnp.float32))
    w_avg = wsum / iters
    # take the better of last / averaged iterate
    return jax.lax.cond(svm_objective(w, X, y, lam) < svm_objective(w_avg, X, y, lam),
                        lambda: w, lambda: w_avg)


@dataclass
class RegretTrace:
    t: List[int]
    avg_regret: List[float]
    bound: List[float]
    holds: bool


def mu_chain_regret(X, y, lam: float, steps: int, seed: int = 0) -> RegretTrace:
    """Follow one model along an MU merge chain and track Theorem 1's bound.

    At step i the model merges with an independently-evolved partner model
    (the other ancestor, kept deliberately *worse* by giving it fewer
    updates — realizing the worst-ancestor path of Eq. 11) and is updated
    with a uniformly sampled example (x_i, y_i)."""
    n, d = X.shape
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    rng = np.random.default_rng(seed)
    upd = make_update("pegasos", lam=lam)

    w_star = solve_w_star(X, y, lam)
    max_x = float(jnp.max(jnp.linalg.norm(X, axis=1)))
    G = lam * (max_x / np.sqrt(lam)) + max_x          # ‖∇f_i‖ ≤ λ‖w‖ + ‖x‖

    main = LinearModel(jnp.zeros(d), jnp.zeros((), jnp.int32))
    partner = LinearModel(jnp.zeros(d), jnp.zeros((), jnp.int32))

    trace = RegretTrace([], [], [], True)
    total = 0.0
    for i in range(1, steps + 1):
        wbar_model = merge(main, partner)
        idx = int(rng.integers(0, n))
        xi, yi = X[idx], y[idx]
        total += float(f_i(wbar_model.w, xi, yi, lam)
                       - f_i(w_star, xi, yi, lam))
        main = upd(wbar_model, xi, yi)
        # the partner receives an update only every other step -> it stays the
        # "further-from-w*" ancestor, as in the worst-ancestor construction
        if i % 2 == 0:
            jdx = int(rng.integers(0, n))
            partner = upd(partner, X[jdx], y[jdx])
        avg = total / i
        bound = G ** 2 * (np.log(i) + 1.0) / (2.0 * lam * i)
        trace.t.append(i)
        trace.avg_regret.append(avg)
        trace.bound.append(bound)
    trace.holds = all(r <= b + 1e-6 for r, b in zip(trace.avg_regret, trace.bound))
    return trace
