"""Baseline algorithms of Section VI-A.e.

* P2PEGASOSRW — the gossip sim with variant='rw' (equals sequential Pegasos
  per cycle count when failure-free).
* WB1 (Eq. 18) — weighted bagging over N independent Pegasos models, each
  trained on an independent random sample stream: the *ideal* use of the N
  parallel updates per cycle.
* WB2 (Eq. 19) — weighted bagging over min(2^t, N) models: accounts for a
  gossip node only having been influenced by ~2^t models at cycle t.
* Sequential Pegasos — the single-model baseline of Table I.

All are vectorized over the model population: one jitted update per cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.learners import LinearModel, init_model, make_update


@dataclass
class BaggingResult:
    cycles: List[int]
    err_wb1: List[float]
    err_wb2: List[float]
    err_single: List[float]     # mean error of the individual models (≈ Pegasos)


@jax.jit
def _bagging_update(W, t, X, y, sample_idx, lam):
    """One cycle: model i gets training example sample_idx[i]."""
    m = LinearModel(W, t)
    upd = make_update("pegasos", lam=lam)
    return upd(m, X[sample_idx], y[sample_idx])


@jax.jit
def _weighted_vote_err(W, X_test, y_test):
    scores = X_test @ W.T                      # (m_test, N_models)
    pred = jnp.where(scores.sum(axis=1) >= 0, 1.0, -1.0)
    return jnp.mean(pred != y_test)


@jax.jit
def _mean_single_err(W, X_test, y_test):
    pred = jnp.where(X_test @ W.T >= 0, 1.0, -1.0)      # (m, N)
    return jnp.mean(pred != y_test[:, None])


def run_weighted_bagging(X, y, X_test, y_test, *, n_models: int,
                         cycles: int, lam: float = 1e-4, seed: int = 0,
                         eval_every: int = 10) -> BaggingResult:
    n, d = X.shape
    key = jax.random.key(seed)
    m = init_model(d, n_models)
    W, t = m.w, m.t
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    X_test = jnp.asarray(X_test, jnp.float32)
    y_test = jnp.asarray(y_test, jnp.float32)

    res = BaggingResult([], [], [], [])
    for c in range(cycles):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (n_models,), 0, n)
        new = _bagging_update(W, t, X, y, idx, lam)
        W, t = new.w, new.t
        if (c + 1) % eval_every == 0 or c == cycles - 1:
            res.cycles.append(c + 1)
            res.err_wb1.append(float(_weighted_vote_err(W, X_test, y_test)))
            k = min(2 ** (c + 1), n_models)
            res.err_wb2.append(float(_weighted_vote_err(W[:k], X_test, y_test)))
            res.err_single.append(float(_mean_single_err(W, X_test, y_test)))
    return res


def run_sequential_pegasos(X, y, X_test, y_test, *, iters: int,
                           lam: float = 1e-4, seed: int = 0,
                           eval_every: int = 1000):
    """Table I's 'Pegasos 20,000 iter.' baseline: one model, random stream."""
    n, d = X.shape
    key = jax.random.key(seed)
    m = init_model(d)
    upd = make_update("pegasos", lam=lam)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    X_test = jnp.asarray(X_test, jnp.float32)
    y_test = jnp.asarray(y_test, jnp.float32)

    @jax.jit
    def body(m, idx):
        return upd(m, X[idx], y[idx]), None

    points = []
    done = 0
    while done < iters:
        step = min(eval_every, iters - done)
        key, sub = jax.random.split(key)
        idxs = jax.random.randint(sub, (step,), 0, n)
        m, _ = jax.lax.scan(body, m, idxs)
        done += step
        pred = jnp.where(X_test @ m.w >= 0, 1.0, -1.0)
        points.append((done, float(jnp.mean(pred != y_test))))
    return m, points
