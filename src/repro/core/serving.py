"""Live serving tier: query snapshots + batched voted prediction (Eq. 8).

The paper's headline object is the *virtual weighted vote* over an
exponential ensemble (Eq. 8, Algorithm 4) — this module serves predictions
from it while the protocol runs. A :class:`QuerySnapshot` is a pure read
of the live engine state (node-local freshest model + the cache ring
buffer), taken without stopping the protocol: jax arrays are immutable, so
snapshotting perturbs nothing — the post-serve error curves are bitwise
identical to a no-serving run (pinned by tests/test_serving.py).

Query flow (docs/SERVING.md has the full diagram):

    engine eval point ── take_snapshot / snapshot_from_carry
                              │
    incoming queries ── assign_queries (node-assignment policy, host rng)
                              │
    batched answer   ── serve_voted (jnp einsum path) or
                        serve_voted_kernel (fused Pallas
                        voted_predict_batched) / serve_fresh (PREDICT)

Both engines hand snapshots to a ``serve_hook(cycle, snapshot)`` passed to
``run_simulation`` — at each eval point, built from the reference engine's
``SimState`` or the sharded engine's scan carry, so a snapshot is bitwise
identical across engines for the same seed (the serving-tier extension of
the parity contract). Consume the snapshot before the engine's next chunk:
the sharded scan donates its carry buffers, so a snapshot held across
chunk boundaries must be copied out (``np.asarray``) first.

Node assignment draws from a host-side ``numpy.random.default_rng`` stream
— deliberately NOT ``jax.random``: the protocol's pinned per-cycle
threefry split sequence (tools/lint/rng_allowlist.py) stays untouched, so
serving cannot shift a draw counter and break cross-engine parity.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_mod
from repro.core.cache import ModelCache
from repro.kernels.voted_predict import voted_predict_batched

ASSIGN_POLICIES = ("uniform", "round_robin")


class QuerySnapshot(NamedTuple):
    """Read-only view of the serving-relevant protocol state at one cycle:
    the cache ring buffer (VOTEDPREDICT state) and the freshest model per
    node (PREDICT state), plus the engine clock for answer attribution."""
    w: jnp.ndarray        # (N, C, d) cache ring-buffer weights
    t: jnp.ndarray        # (N, C) int32 per-slot update counters
    count: jnp.ndarray    # (N,) int32 valid slots per node
    fresh_w: jnp.ndarray  # (N, d) freshest model per node
    fresh_t: jnp.ndarray  # (N,) int32
    clock: jnp.ndarray    # () int32 engine clock at snapshot time


def _snapshot(cache: ModelCache, clock) -> QuerySnapshot:
    fresh_w, fresh_t = cache_mod.freshest(cache)
    return QuerySnapshot(cache.w, cache.t, cache.count, fresh_w, fresh_t,
                         clock)


def take_snapshot(state) -> QuerySnapshot:
    """Snapshot from the reference engine's live ``SimState`` (anything
    with ``.cache`` and ``.clock``) — a pure read, no protocol mutation."""
    return _snapshot(state.cache, state.clock)


def snapshot_from_carry(carry) -> QuerySnapshot:
    """Snapshot from the sharded engine's scan carry (the 14-lane tuple:
    cache lanes 4–7, clock lane 13) — bitwise identical to
    :func:`take_snapshot` of the reference engine at the same cycle."""
    cache = ModelCache(carry[4], carry[5], carry[6], carry[7])
    return _snapshot(cache, carry[13])


def assign_queries(n_queries: int, n_nodes: int, *,
                   policy: str = "uniform", seed: int = 0,
                   offset: int = 0) -> np.ndarray:
    """Node assignment for a query batch: which node answers each query.

    ``"uniform"`` models users landing on arbitrary nodes (the Floating
    Gossip service picture); ``"round_robin"`` is the deterministic
    load-balanced front end (``offset`` carries the position across
    batches). Host-side numpy stream — see the module docstring for why
    this is not ``jax.random``."""
    if policy == "uniform":
        rng = np.random.default_rng((seed, offset))
        return rng.integers(0, n_nodes, n_queries).astype(np.int32)
    if policy == "round_robin":
        return ((offset + np.arange(n_queries)) % n_nodes).astype(np.int32)
    raise ValueError(f"unknown assignment policy {policy!r} "
                     f"(expected one of {ASSIGN_POLICIES})")


@jax.jit
def serve_fresh(fresh_w, X, assign):
    """PREDICT for a query batch: sign of <w_freshest, x> at the assigned
    node — op-for-op the gathered form of ``cache.predict_fresh``."""
    w = fresh_w[assign]                          # (M, d)
    return jnp.where(jnp.einsum("md,md->m", w, X) >= 0, 1.0, -1.0)


@jax.jit
def serve_voted(w, count, X, assign):
    """VOTEDPREDICT for a query batch — the jnp einsum path.

    Mirrors ``cache.voted_predict`` op for op on the gathered (query,
    assigned node) pairs: same ``score >= 0`` sign convention, same
    valid-slot mask, same ``p_ratio - 0.5 >= 0`` tie-break. ``w``:
    (N, C, d) snapshot cache; ``count``: (N,); ``X``: (M, d); ``assign``:
    (M,) int32. Returns (M,) ±1 predictions."""
    c = w.shape[1]
    w_sel = w[assign]                            # (M, C, d)
    cnt = count[assign]                          # (M,)
    scores = jnp.einsum("mcd,md->mc", w_sel, X)
    votes = (scores >= 0).astype(jnp.float32)
    valid = (jnp.arange(c)[None, :] < cnt[:, None]).astype(jnp.float32)
    p_ratio = jnp.einsum("mc,mc->m", votes, valid) / cnt
    return jnp.where(p_ratio - 0.5 >= 0, 1.0, -1.0)


@jax.jit
def serve_voted_kernel(w, count, X, assign):
    """VOTEDPREDICT for a query batch — the fused Pallas path
    (``repro.kernels.voted_predict.voted_predict_batched``, interpret mode
    on CPU backends): gathers the assigned cache rows, then one VMEM pass
    scores, votes and reduces. Predictions are bitwise equal to
    :func:`serve_voted` (tests/test_serving.py + the BENCH_serving.json
    parity probes)."""
    interpret = jax.default_backend() != "tpu"
    return voted_predict_batched(w[assign], count[assign], X,
                                 interpret=interpret)
