"""Event-driven protocol simulator for gossip learning (Algorithm 1).

Faithful to the paper's PeerSim setup (Section VI-A):

* one data record per node; models random-walk via ``selectPeer()``;
* message **drop** with probability ``drop_prob`` (extreme scenario: 0.5);
* message **delay** uniform in [Δ, delay_max·Δ] (extreme: 10Δ), quantized to
  whole gossip cycles (the paper's Δ-loop makes sub-cycle timing immaterial
  to the per-cycle error curves — the same quantization PeerSim plots use);
* **churn**: lognormal online-session lengths (Stutzbach-Rejaie model; the
  paper fits the FileList.org trace, unavailable offline — we match the 90%
  online-at-any-time operating point and the lognormal shape), offline nodes
  neither send nor receive, and resume with retained state;
* per-node model cache of ``cache_size`` for local (voted) prediction.

The per-cycle dynamics are one fused, jitted JAX program over the whole
population: the in-flight message buffer is a (delay_max, N) slot array
(slot = sending cycle mod delay_max; a sender's slot is provably delivered
before it is overwritten), and simultaneous arrivals at one node are applied
sequentially in K winner-per-destination rounds — matching the event-by-event
semantics of the paper's simulator while staying fully vectorized.

Beyond-paper: ``GossipLinearConfig.wire_dtype`` names a wire *codec* from
``repro.core.wire_codec`` — the representation of the transmitted model
(bf16/f16 cast, per-message affine int8 with optional stochastic rounding,
packed int4 or base-3 ternary, the latter two optionally with sender-side
error-feedback residuals held in ``SimState.ef``); merge arithmetic is
always f32. This module is the *reference engine*;
``repro.core.sharded_engine`` runs the identical protocol at
mega-population scale (the engines' parity contract is documented in
docs/ENGINES.md, the paper-to-code map in docs/ARCHITECTURE.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core import cache as cache_mod
from repro.core import faults as faults_mod
from repro.core import peer_sampling
from repro.core import telemetry as telemetry_mod
from repro.core.cache import ModelCache
from repro.core.learners import LinearModel, make_update
from repro.core.wire_codec import get_codec
from repro.core.merge import create_model
from repro.utils.metrics import cosine_similarity


class SimState(NamedTuple):
    last_w: jnp.ndarray     # (N, d)  lastModel
    last_t: jnp.ndarray     # (N,)
    cache: ModelCache
    buf_w: jnp.ndarray      # (D, N, P) in-flight payloads, slot = cycle % D
    #                         (P = codec.payload_cols(d): d for byte-or-wider
    #                         codecs, ceil(d/2) packed int4, ceil(d/5) ternary)
    buf_t: jnp.ndarray      # (D, N)
    buf_scale: jnp.ndarray  # (D, N) f16 per-message quant scale  ((0, 0)
    buf_zp: jnp.ndarray     # (D, N) f16 per-message zero-point    when the
    #                         codec does not carry the lane)
    buf_dst: jnp.ndarray    # (D, N) int32 destination
    buf_arrival: jnp.ndarray  # (D, N) int32 absolute arrival cycle, -1 = none
    ef: jnp.ndarray         # (N, d) f32 sender error-feedback residual
    #                         ((0, 0) for codecs without EF state)
    clock: jnp.ndarray      # () int32


def init_state(n: int, d: int, cache_size: int, delay_max: int,
               wire_dtype=None) -> SimState:
    """``wire_dtype`` (codec name or None): wire representation of the
    in-flight payload buffer — the bytes a real deployment would put on the
    wire. The quantized codecs additionally allocate the (D, N) f16 scale
    lane (and zero-point lane for the affine int8 family) that rides
    alongside each message, and the ``_ef`` codecs the (N, d) f32
    error-feedback residual; lanes a codec does not declare are empty
    (0, 0) arrays, so the float hot path carries nothing extra."""
    codec = get_codec(wire_dtype)
    sc_shape = (delay_max, n) if codec.has_scale else (0, 0)
    zp_shape = (delay_max, n) if codec.has_zp else (0, 0)
    return SimState(
        last_w=jnp.zeros((n, d), jnp.float32),
        last_t=jnp.zeros((n,), jnp.int32),
        cache=cache_mod.init_cache(n, cache_size, d),
        buf_w=jnp.zeros((delay_max, n, codec.payload_cols(d)),
                        codec.payload_dtype),
        buf_t=jnp.zeros((delay_max, n), jnp.int32),
        buf_scale=jnp.zeros(sc_shape, jnp.float16),
        buf_zp=jnp.zeros(zp_shape, jnp.float16),
        buf_dst=jnp.zeros((delay_max, n), jnp.int32),
        buf_arrival=jnp.full((delay_max, n), -1, jnp.int32),
        ef=jnp.zeros((n, d) if codec.ef else (0, 0), jnp.float32),
        clock=jnp.zeros((), jnp.int32),
    )


def select_receivers(buf_dst, buf_arrival, online, clock, k_rounds: int):
    """Winner-per-destination selection for up to ``k_rounds`` receives.

    Integer-only (no payload touched): resolves which in-flight buffer slot
    each node accepts in each of the K sequential receive rounds, exactly
    reproducing the event-by-event winner semantics of ``simulate_cycle``.
    Splitting this out lets the sharded engine run the cheap global scatter
    here and the heavy per-node payload math in a sharded/fused pass.

    Returns ``(src_slot, valid, delivered, overflow, lost)`` where
    ``src_slot`` is (K, N) int32 into the flattened buffer, ``valid`` is
    (K, N) bool, and ``lost`` counts messages due this cycle whose
    destination is offline (they leave the system undelivered)."""
    D, n = buf_dst.shape
    flat_dst = buf_dst.reshape(-1)
    flat_arr = buf_arrival.reshape(-1)
    due = flat_arr == clock
    arriving = due & online[flat_dst]
    lost = (due & ~online[flat_dst]).sum()
    slot_ids = jnp.arange(D * n, dtype=jnp.int32) + 1

    remaining = arriving
    delivered = jnp.zeros((), jnp.int32)
    slots, valids = [], []
    for _ in range(k_rounds):
        tag = jnp.where(remaining, slot_ids, 0)
        taken = jnp.zeros((n,), jnp.int32).at[flat_dst].max(tag)
        valids.append(taken > 0)                # node receives this round
        slots.append(jnp.maximum(taken - 1, 0))
        win = remaining & (tag == taken[flat_dst]) & (taken[flat_dst] > 0)
        remaining = remaining & ~win
        delivered = delivered + win.sum()
    overflow = remaining.sum()                  # arrivals beyond K rounds
    return (jnp.stack(slots), jnp.stack(valids), delivered, overflow,
            lost.astype(jnp.int32))


def apply_receives(last_w, last_t, cache: ModelCache, msg_w, msg_t, valid,
                   X, y, *, variant: str, update, defense: str = "none"):
    """Apply up to K sequential receives per node (Algorithm 1 ON RECEIVE).

    For each valid (node, round): ``modelCache.add(createModel(m, lastModel));
    lastModel <- m``. Purely per-node — no cross-node communication — and the
    parity oracle for the sharded engine's scatter-free ``_vector_apply``
    and the Pallas ``gossip_cycle`` kernel.

    ``defense`` screens each round's payload against the receiver's
    CURRENT lastModel (``repro.core.faults.apply_defense``) before the
    merge: a rejected message is treated as never received (no cache add,
    no lastModel update), a clipped one is merged and stored rescaled.
    The screen runs inside the round loop because ``lastModel <- m``
    makes round k's reference model depend on round k-1's verdict.

    msg_w: (K, N, d); msg_t, valid: (K, N). Returns
    ``(last_w, last_t, cache, gated, clipped)`` with per-node int32
    counts of rejected/rescaled messages (zeros under ``"none"``)."""
    gated = jnp.zeros(last_t.shape, jnp.int32)
    clipped = jnp.zeros(last_t.shape, jnp.int32)
    for k in range(msg_w.shape[0]):
        mw, has, g, c = faults_mod.apply_defense(
            defense, msg_w[k], valid[k], last_w)
        gated = gated + g.astype(jnp.int32)
        clipped = clipped + c.astype(jnp.int32)
        m1 = LinearModel(mw, msg_t[k])
        m2 = LinearModel(last_w, last_t)
        new = create_model(variant, update, m1, m2, X, y)
        cache = cache_mod.cache_add(cache, has, new.w, new.t)
        last_w = jnp.where(has[:, None], m1.w, last_w)
        last_t = jnp.where(has, m1.t, last_t)
    return last_w, last_t, cache, gated, clipped


def cycle_core(state: SimState, X, y, online, key, byz=None, *,
               variant: str, learner: str, lam: float, eta: float,
               drop: float, delay_max: int, k_rounds: int, sampler: str,
               wire_dtype: Optional[str] = None,
               fault_model: Optional[str] = None, defense: str = "none",
               emit_streams: bool = False):
    """One gossip cycle for the whole population (traceable core).

    ``wire_dtype`` is the wire-codec *name* (static): quantized codecs
    encode at send time and decode before the f32 merge; ``k_recv`` — the
    first slot of the per-cycle 4-way threefry split, unused by the float
    wire dtypes — seeds the stochastic-rounding noise, so "int8_sr" stays
    bitwise-reproducible and both engines draw identical noise. The
    ``_ef`` codecs transmit ``fresh + ef`` and update the per-sender
    residual — only on cycles the node actually sends (``send_ok``), which
    is what keeps the sharded engine's sender-subset compaction exact.

    ``fault_model``/``defense`` (static) + ``byz`` (the (N,) Byzantine
    mask, ``None`` when faults are off) enable ``repro.core.faults``:
    model-kind faults rewrite the transmitted model before the encode,
    the wire-kind "bitflip" rewrites the encoded payload after it (and
    after the EF-residual update — the honest sender's bookkeeping is
    computed from what it *encoded*, not what the channel delivered).
    Fault draws use ``fault_key`` (``fold_in`` from the cycle key), so
    the pinned 4-way split below — and every fault-free run — is
    untouched."""
    n, d = state.last_w.shape
    D = delay_max
    codec = get_codec(wire_dtype)
    fault = faults_mod.get_fault(fault_model)
    update = make_update(learner, lam=lam, eta=eta)
    k_recv, k_dst, k_delay, k_drop = jax.random.split(key, 4)

    # multi-record nodes (Section II: the approach also applies when a node
    # holds k records — its advantage over local learning then shrinks):
    # X may be (N, k, d); each cycle streams the clock-th record round-robin.
    if X.ndim == 3:
        rec = state.clock % X.shape[1]
        X = X[:, rec, :]
        y = y[:, rec]

    # ---- 1) deliveries -----------------------------------------------------
    src_slot, valid, delivered, overflow, lost = select_receivers(
        state.buf_dst, state.buf_arrival, online, state.clock, k_rounds)
    flat_w = state.buf_w.reshape(-1, state.buf_w.shape[-1])
    flat_t = state.buf_t.reshape(-1)
    # payloads were encoded to the wire codec at send time; the merge
    # arithmetic runs in f32 (same contract as gossip_merge exchange_dtype)
    if codec.quantized:
        msg_w = codec.decode(
            flat_w[src_slot], state.buf_scale.reshape(-1)[src_slot],
            state.buf_zp.reshape(-1)[src_slot] if codec.has_zp else None, d)
    else:
        msg_w = flat_w[src_slot].astype(jnp.float32)  # (K, N, d) winners
    msg_t = flat_t[src_slot]
    last_w, last_t, cache, gated, clipped = apply_receives(
        state.last_w, state.last_t, state.cache, msg_w, msg_t, valid, X, y,
        variant=variant, update=update, defense=defense)

    # ---- 2) sends ----------------------------------------------------------
    fresh_w, fresh_t = cache_mod.freshest(cache)
    send_w, send_t = fresh_w, fresh_t
    if fault is not None and fault.kind == "model":
        old_w = old_t = None
        if fault.name == "stale_replay":
            old_w, old_t = cache_mod.cache_oldest(cache)
        send_w, send_t = faults_mod.corrupt_model(
            fault, byz, faults_mod.fault_key(key), fresh_w, fresh_t,
            old_w, old_t)
    if sampler == "matching":
        dst = peer_sampling.perfect_matching(k_dst, n)
    else:
        dst = peer_sampling.uniform_peers(k_dst, n)
    delay = jax.random.randint(k_delay, (n,), 1, D + 1) if D > 1 else jnp.ones((n,), jnp.int32)
    dropped = jax.random.bernoulli(k_drop, drop, (n,)) if drop > 0 else jnp.zeros((n,), bool)
    # dst == self marks a node that idles this cycle (odd-N perfect matching
    # leaves one node unpaired); it neither sends nor self-delivers.
    idle = dst == jnp.arange(n, dtype=dst.dtype)
    send_ok = online & ~dropped & ~idle
    arrival = jnp.where(send_ok, state.clock + delay, -1)

    slot = state.clock % D
    # error feedback: transmit fresh + residual; the residual refreshes
    # only where the node actually sends (a non-sender encoded nothing,
    # and its stale buffer slot is provably never routed)
    x_send = send_w + state.ef if codec.ef else send_w
    payload, sc, zp = codec.encode(
        x_send, key=k_recv if codec.stochastic else None)
    ef = state.ef
    if codec.ef:
        ef = jnp.where(send_ok[:, None],
                       x_send - codec.decode(payload, sc, zp, d), ef)
    if fault is not None and fault.kind == "wire":
        payload = faults_mod.bitflip_payload(
            byz, faults_mod.fault_key(key), payload)
    buf_w = state.buf_w.at[slot].set(payload)
    buf_scale = (state.buf_scale.at[slot].set(sc) if codec.has_scale
                 else state.buf_scale)
    buf_zp = state.buf_zp.at[slot].set(zp) if codec.has_zp else state.buf_zp
    buf_t = state.buf_t.at[slot].set(send_t)
    buf_dst = state.buf_dst.at[slot].set(dst)
    buf_arrival = state.buf_arrival.at[slot].set(arrival)

    corrupted = ((byz & send_ok).sum().astype(jnp.int32)
                 if fault is not None else jnp.zeros((), jnp.int32))
    stats = {"delivered": delivered, "overflow": overflow,
             "sent": send_ok.sum(), "lost": lost, "corrupted": corrupted,
             "gated": gated.sum(), "clipped": clipped.sum()}
    if emit_streams:
        # armed-only (static flag) receiver-occupancy reads for the
        # telemetry streams: round-1 winners and multi-round receivers —
        # the numbers the sharded router observes as recv/multi sizes.
        # Extra int reductions on existing masks; the protocol state above
        # is untouched (the pure-read contract, docs/CONTRACTS.md)
        stats["recv_nodes"] = valid[0].sum().astype(jnp.int32)
        stats["multi_nodes"] = (valid[1].sum().astype(jnp.int32)
                                if k_rounds > 1 else jnp.zeros((), jnp.int32))
    return SimState(last_w, last_t, cache, buf_w, buf_t, buf_scale, buf_zp,
                    buf_dst, buf_arrival, ef, state.clock + 1), stats


@functools.partial(jax.jit, static_argnames=("variant", "learner", "lam",
                                             "eta", "drop", "delay_max",
                                             "k_rounds", "sampler",
                                             "wire_dtype", "fault_model",
                                             "defense", "emit_streams"))
def simulate_cycle(state: SimState, X, y, online, key, byz=None, *,
                   variant: str, learner: str, lam: float, eta: float,
                   drop: float, delay_max: int, k_rounds: int, sampler: str,
                   wire_dtype: Optional[str] = None,
                   fault_model: Optional[str] = None, defense: str = "none",
                   emit_streams: bool = False):
    """One gossip cycle for the whole population. Returns (state, stats).

    ``stats`` message economy (per cycle): every message sent at cycle c is
    eventually exactly one of ``delivered`` (accepted by an online node),
    ``lost`` (destination offline at the arrival cycle), or ``overflow``
    (arrived beyond the K winner rounds) — so over a run,
    ``sum(sent) == sum(delivered + lost + overflow) + in-flight``.
    (A defense-gated message still counts ``delivered`` — it reached its
    destination; ``gated``/``clipped`` account the screen separately.)

    ``emit_streams`` (static; set by an armed ``telemetry=``) adds the
    receiver-occupancy stats the metric streams need. The default False
    compiles the exact pre-telemetry program — a fault-free unarmed run's
    trace is byte-for-byte what it was before telemetry existed."""
    return cycle_core(state, X, y, online, key, byz, variant=variant,
                      learner=learner, lam=lam, eta=eta, drop=drop,
                      delay_max=delay_max, k_rounds=k_rounds, sampler=sampler,
                      wire_dtype=wire_dtype, fault_model=fault_model,
                      defense=defense, emit_streams=emit_streams)


# ---------------------------------------------------------------------------
# churn traces
# ---------------------------------------------------------------------------


CHURN_TRACE_VERSION = 2


def churn_trace(rng: np.random.Generator, n: int, cycles: int,
                online_fraction: float, mean_online: float = 50.0,
                sigma: float = 1.5) -> np.ndarray:
    """(cycles, N) boolean online matrix from alternating lognormal sessions.

    Lognormal online-session lengths (the Stutzbach-Rejaie churn model the
    paper uses); offline durations scaled so the stationary online fraction
    matches ``online_fraction`` (the paper's 90%).

    Trace version 2 (``CHURN_TRACE_VERSION``): the per-node Python session
    loop of v1 became a vectorized numpy sampler — sessions are batch-drawn
    per node (redrawing only the not-yet-covered tail until every node's
    alternating session chain covers the horizon), the session-end
    boundaries are scattered into a per-(node, cycle) count matrix, and the
    online matrix falls out as ``state0 ^ parity(cumsum(counts))`` — a node
    is in session j at cycle c iff j boundaries are <= c, and its state
    flips with the parity of j. The session model and stationary fraction
    are unchanged, but the rng consumption *order* is not: for a given seed
    a v2 trace differs bitwise from v1. Both engines draw one shared trace
    per run (``sim_setup``), so cross-engine parity is unaffected;
    generating a 10^6-node × 50-cycle trace drops from multi-second Python
    looping to ~1 s."""
    if online_fraction >= 1.0:
        return np.ones((cycles, n), dtype=bool)
    if cycles == 0:
        return np.zeros((0, n), dtype=bool)
    mean_off = mean_online * (1.0 - online_fraction) / online_fraction
    mu_on = np.log(mean_online) - sigma ** 2 / 2
    mu_off = np.log(max(mean_off, 1e-9)) - sigma ** 2 / 2
    phase = rng.integers(0, max(int(mean_online), 1), size=n)
    state0 = rng.random(n) < online_fraction

    # the lognormal median (not the mean — sigma=1.5 is heavy-tailed) sets
    # the typical sessions-per-horizon; the redraw loop covers the tail
    med_pair = np.exp(mu_on) + np.exp(mu_off)
    horizon = cycles + int(mean_online)
    step = int(np.clip(np.ceil(horizon / max(med_pair, 1.0)) + 2, 4, 4096))

    def draw_sessions(cols_done: int, m: int, init_state) -> np.ndarray:
        # session j has state init ^ (j odd); durations = max(1, int(lognormal))
        # — drawn in f32 (the truncation to whole cycles makes f64 moot)
        j = cols_done + np.arange(m)
        on = init_state[:, None] ^ (j[None, :] % 2 == 1)
        mu = np.where(on, np.float32(mu_on), np.float32(mu_off))
        z = rng.standard_normal((init_state.size, m), dtype=np.float32)
        return np.maximum(np.exp(mu + np.float32(sigma) * z).astype(np.int32), 1)

    # counts[c, i] = #session boundaries of node i at cycle c, cycle-major
    # so the output needs no transpose; boundaries at or before cycle 0 flip
    # ALL in-range cycles alike, so only their parity matters — it is folded
    # into the cycle-0 state (``flip0``) instead of scattered. int16 is
    # ample: a node has at most ``cols`` in-range boundaries
    counts = np.zeros((cycles, n), np.int16)
    flip0 = np.zeros(n, bool)

    def scatter_boundaries(node_ids, bounds):
        r, c = np.nonzero((bounds > 0) & (bounds < cycles))
        np.add.at(counts, (bounds[r, c], node_ids[r]), 1)
        flip0[node_ids] ^= ((bounds <= 0).sum(axis=1) & 1).astype(bool)

    bounds = draw_sessions(0, step, state0).cumsum(axis=1) - phase[:, None]
    scatter_boundaries(np.arange(n), bounds)
    last = bounds[:, -1]
    sub = np.flatnonzero(last < cycles)         # nodes not yet covered
    lsub = last[sub]
    cols = step
    while sub.size:
        bounds = (lsub[:, None]
                  + draw_sessions(cols, step, state0[sub]).cumsum(axis=1))
        scatter_boundaries(sub, bounds)
        cols += step
        lsub = bounds[:, -1]
        keep = lsub < cycles
        sub, lsub = sub[keep], lsub[keep]

    parity = counts.cumsum(axis=0, dtype=np.int16) & 1  # (cycles, n)
    return (state0 ^ flip0)[None, :] ^ parity.astype(bool)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    cycles: List[int]
    err_fresh: List[float]      # PREDICT, mean over eval nodes
    err_voted: List[float]      # VOTEDPREDICT, mean over eval nodes
    similarity: List[float]     # mean pairwise cosine over eval-node models
    overflow_total: int
    config: GossipLinearConfig
    sent_total: int = 0
    delivered_total: int = 0
    lost_total: int = 0         # arrived while destination offline
    # bandwidth economy (the paper's central cost axis): bytes actually put
    # on the wire (sent messages × per-message payload), and the footprint
    # of the dominant in-flight (D, N, d) payload buffer — both scale with
    # the wire dtype (GossipLinearConfig.wire_dtype)
    wire_bytes_total: int = 0
    buf_payload_bytes: int = 0
    # delivery observability (the sparse-delivery regimes of Fig. 5-7 are
    # exactly where per-cycle cost should track deliveries, not N): the
    # per-cycle delivered-message counts, and — sharded engine only — the
    # compaction telemetry: chunk-mode counts (dense / compact /
    # compact_all) and the per-cycle receiver-occupancy stats the router
    # observed (round-1 receivers and multi-receivers as fractions of N)
    delivered_per_cycle: List[int] = field(default_factory=list)
    compaction: Dict[str, object] = field(default_factory=dict)
    # terminal sender-state telemetry of the error-feedback codecs: the
    # root-mean L2 norm of the per-node EF residual at the end of the run
    # (0.0 for codecs without EF state) — bounded (property-tested) because
    # each refresh leaves at most one quantization step behind
    ef_residual_norm: float = 0.0
    # adversarial-fault telemetry (repro.core.faults): run totals of
    # messages corrupted at send (Byzantine sender, send_ok cycles),
    # rejected by the receive-side defense ("gated"), and rescaled by
    # norm_clip ("clipped") — all zero on fault-free / defense-off runs
    fault_stats: Dict[str, int] = field(default_factory=dict)


def ef_residual_norm(ef) -> float:
    """Root-mean-square per-node L2 norm of the EF residual lane — the
    one number both engines report as ``SimResult.ef_residual_norm``."""
    if ef.size == 0:
        return 0.0
    return float(jnp.sqrt(jnp.mean(jnp.sum(ef.astype(jnp.float32) ** 2,
                                           axis=-1))))


def message_wire_bytes(d: int, wire_dtype_name) -> int:
    """Bytes per transmitted model: the codec's packed coefficient payload
    + the int32 counter + the codec's metadata overhead (f16 scale, and a
    zero-point for the affine int8 family)."""
    codec = get_codec(wire_dtype_name)
    return codec.payload_bytes(d) + 4 + codec.overhead_bytes


def payload_buffer_bytes(delay_max: int, n: int, d: int,
                         wire_dtype_name) -> int:
    """Footprint of the in-flight (D, N, P) payload buffer in the wire
    codec's packed representation, including the (D, N) f16 scale (and
    zero-point) lanes when the codec carries them — the number both
    engines report as ``SimResult.buf_payload_bytes``. (The EF residual is
    *sender* state, not in-flight payload, and is excluded.)"""
    codec = get_codec(wire_dtype_name)
    return delay_max * n * (codec.payload_bytes(d) + codec.overhead_bytes)


@functools.lru_cache(maxsize=2)
def _host_scenario(seed: int, n: int, cycles: int, online_fraction: float,
                   eval_nodes: int):
    """Memoized host-side scenario draw: the churn trace and eval subset
    are pure functions of these arguments, and a mega-population trace
    costs ~1 s to regenerate (50 × 10^6 lognormal sessions) — benchmark
    sweeps and warm-up/measure pairs re-enter with identical arguments, so
    the second run should pay nothing. Callers treat the returned arrays
    as read-only (both engines only index them). maxsize stays tiny: one
    10^6-node × 50-cycle trace is ~50 MB."""
    rng = np.random.default_rng(seed)
    online_mat = churn_trace(rng, n, cycles, online_fraction)
    eval_idx = rng.choice(n, size=min(eval_nodes, n), replace=False)
    return online_mat, eval_idx


def sim_setup(cfg: GossipLinearConfig, X, y, X_test, y_test, *, cycles: int,
              seed: int, eval_nodes: int):
    """Shared host-side setup for both engines.

    Draws the churn trace and the eval-node subset from ONE ``default_rng``
    stream in a fixed order, so ``engine="reference"`` and
    ``engine="sharded"`` see identical scenarios for the same seed."""
    n = X.shape[0]
    online_mat, eval_idx = _host_scenario(seed, n, cycles,
                                          cfg.online_fraction, eval_nodes)
    return (online_mat, jnp.asarray(eval_idx),
            jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
            jnp.asarray(X_test, jnp.float32), jnp.asarray(y_test, jnp.float32))


def eval_points(cycles: int, eval_every: int) -> List[int]:
    """The cycle counts after which both engines evaluate the population."""
    return [c + 1 for c in range(cycles)
            if (c + 1) % eval_every == 0 or c == cycles - 1]


@functools.partial(jax.jit, static_argnames=())
def _eval(cache: ModelCache, eval_idx, X_test, y_test):
    sub = ModelCache(cache.w[eval_idx], cache.t[eval_idx],
                     cache.ptr[eval_idx], cache.count[eval_idx])
    fresh = cache_mod.predict_fresh(sub, X_test)         # (E, m)
    voted = cache_mod.voted_predict(sub, X_test)
    err_f = jnp.mean(fresh != y_test[None, :], axis=1).mean()
    err_v = jnp.mean(voted != y_test[None, :], axis=1).mean()
    w, _ = cache_mod.freshest(sub)
    sim = cosine_similarity(w)
    return err_f, err_v, sim


def run_simulation(cfg: GossipLinearConfig, X, y, X_test, y_test, *,
                   cycles: int = 200, eval_every: int = 10, seed: int = 0,
                   eval_nodes: int = 100, sampler: str = "uniform",
                   k_rounds: int = 4, engine: str = "reference",
                   serve_hook=None, telemetry=None,
                   **engine_kwargs) -> SimResult:
    """Run the full protocol for ``cycles`` gossip cycles.

    The one entry point for both execution engines. Inputs: ``cfg`` fixes
    the *protocol* (learner, CREATEMODEL variant, failure model, wire
    dtype); the keyword arguments fix the *run* (length, eval cadence,
    seed, peer sampler, receive rounds) and the *execution backend* —
    none of which may change the simulated protocol.

    ``X`` may be (N, d) — the paper's one-record-per-node model — or
    (N, k, d) for k local records per node (Section II's generalization).

    Returns a :class:`SimResult`: per-eval-point error curves
    (``err_fresh`` = PREDICT, ``err_voted`` = VOTEDPREDICT, over
    ``eval_nodes`` random nodes), the pairwise model ``similarity``
    diagnostic, the exact message economy (``sent_total`` ==
    ``delivered_total + lost_total + overflow_total`` + in-flight), and
    the bandwidth account (``wire_bytes_total``, ``buf_payload_bytes``)
    under ``cfg.wire_dtype``.

    ``engine`` selects the execution backend:

    * ``"reference"`` (default) — one jitted ``simulate_cycle`` call per
      cycle with a host-Python driver loop; simple, and the parity oracle.
    * ``"sharded"`` — the mega-population engine
      (:mod:`repro.core.sharded_engine`): ``lax.scan`` over chunks of
      cycles between eval points (no host round-trip per cycle), the node
      axis optionally sharded over a device mesh with ``shard_map``, and
      the deliver→merge→update→cache-write step optionally fused into the
      Pallas ``gossip_cycle`` kernel on TPU. Same random streams — for a
      given seed it reproduces the reference error curves. Extra keyword
      arguments (``mesh=``, ``use_pallas=``, ``interpret=``) are forwarded
      to :func:`repro.core.sharded_engine.run_sharded_simulation`.

    ``serve_hook``: optional ``hook(cycle, snapshot)`` — the live serving
    surface (:mod:`repro.core.serving`). At every eval point both engines
    call it with a :class:`repro.core.serving.QuerySnapshot` of the live
    state (cache ring buffer + freshest models), a pure read that cannot
    perturb the run: with or without a hook, the curves are bitwise
    identical (tests/test_serving.py).

    ``telemetry``: optional :class:`repro.core.telemetry.Telemetry`. When
    armed, both engines emit the registered per-cycle metric streams
    (``METRIC_STREAMS``: message economy, wire bytes, occupancy, fault
    counters, EF residual RMS, online fraction) and record host spans
    around the cycle dispatch, eval and snapshot phases. Same discipline
    as ``serve_hook``: a pure read — armed and unarmed runs are bitwise
    identical (tests/test_telemetry.py), and ``telemetry=None`` compiles
    the exact pre-telemetry programs (docs/OBSERVABILITY.md).
    """
    if engine == "sharded":
        from repro.core.sharded_engine import run_sharded_simulation
        return run_sharded_simulation(
            cfg, X, y, X_test, y_test, cycles=cycles, eval_every=eval_every,
            seed=seed, eval_nodes=eval_nodes, sampler=sampler,
            k_rounds=k_rounds, serve_hook=serve_hook, telemetry=telemetry,
            **engine_kwargs)
    if engine != "reference":
        raise ValueError(f"unknown engine {engine!r} "
                         "(expected 'reference' or 'sharded')")
    if engine_kwargs:
        raise TypeError("unexpected keyword arguments for the reference "
                        f"engine: {sorted(engine_kwargs)}")

    n, d = X.shape[0], X.shape[-1]
    online_mat, eval_idx, X, y, X_test, y_test = sim_setup(
        cfg, X, y, X_test, y_test, cycles=cycles, seed=seed,
        eval_nodes=eval_nodes)

    D = max(cfg.delay_max_cycles, 1)
    state = init_state(n, d, cfg.cache_size, D, wire_dtype=cfg.wire_dtype)
    key = jax.random.key(seed)

    faults_mod.check_defense(cfg.defense)
    byz = None
    if cfg.fault_model is not None:
        faults_mod.get_fault(cfg.fault_model)    # fail fast on unknown names
        byz = jnp.asarray(
            faults_mod.byzantine_mask(seed, n, cfg.byzantine_frac))

    res = SimResult([], [], [], [], 0, cfg)
    res.buf_payload_bytes = payload_buffer_bytes(D, n, d, cfg.wire_dtype)
    res.fault_stats = {"corrupted": 0, "gated": 0, "clipped": 0}
    tel = telemetry
    armed = tel is not None
    msg_bytes = message_wire_bytes(d, cfg.wire_dtype)
    in_flight = 0
    for c in range(cycles):
        key, sub = jax.random.split(key)
        with telemetry_mod.maybe_span(tel, "cycle", track="device", cycle=c):
            state, stats = simulate_cycle(
                state, X, y, jnp.asarray(online_mat[c]), sub, byz,
                variant=cfg.variant, learner=cfg.learner, lam=cfg.lam,
                eta=cfg.eta, drop=cfg.drop_prob,
                delay_max=D, k_rounds=k_rounds,
                sampler=sampler, wire_dtype=cfg.wire_dtype,
                fault_model=cfg.fault_model, defense=cfg.defense,
                emit_streams=armed)
        sent = int(stats["sent"])
        delivered = int(stats["delivered"])
        lost = int(stats["lost"])
        overflow = int(stats["overflow"])
        res.overflow_total += overflow
        res.sent_total += sent
        res.delivered_total += delivered
        res.delivered_per_cycle.append(delivered)
        res.lost_total += lost
        for k in ("corrupted", "gated", "clipped"):
            res.fault_stats[k] += int(stats[k])
        if armed:
            # pure reads of the stats the driver fetched anyway: the armed
            # run's protocol state is bitwise identical to the unarmed run
            in_flight += sent - delivered - lost - overflow
            tel.emit_row(
                sent=sent, delivered=delivered, lost=lost,
                overflow=overflow, in_flight=in_flight,
                wire_bytes=sent * msg_bytes,
                recv_nodes=int(stats["recv_nodes"]),
                multi_nodes=int(stats["multi_nodes"]),
                online_nodes=int(online_mat[c].sum()),
                corrupted=int(stats["corrupted"]),
                gated=int(stats["gated"]), clipped=int(stats["clipped"]))
        if (c + 1) % eval_every == 0 or c == cycles - 1:
            with telemetry_mod.maybe_span(tel, "eval", track="eval",
                                          cycle=c + 1):
                err_f, err_v, sim = _eval(state.cache, eval_idx, X_test,
                                          y_test)
                res.cycles.append(c + 1)
                res.err_fresh.append(float(err_f))
                res.err_voted.append(float(err_v))
                res.similarity.append(float(sim))
            if armed:
                tel.emit("ef_residual_rms", ef_residual_norm(state.ef))
            if serve_hook is not None:
                from repro.core import serving
                with telemetry_mod.maybe_span(tel, "snapshot",
                                              track="serving", cycle=c + 1):
                    serve_hook(c + 1, serving.take_snapshot(state))
    res.wire_bytes_total = res.sent_total * message_wire_bytes(d, cfg.wire_dtype)
    res.ef_residual_norm = ef_residual_norm(state.ef)
    if armed:
        tel.annotations.setdefault("runs", []).append(dict(
            engine="reference", n_nodes=n, cycles=cycles,
            wire_dtype=cfg.wire_dtype or "f32", message_bytes=msg_bytes))
    return res
