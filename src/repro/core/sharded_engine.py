"""Sharded mega-population gossip engine (``run_simulation(engine="sharded")``).

The reference driver tops out around N ~ 10^4: it re-enters Python every
cycle (one jitted call + host syncs for the stats scalars), and — worse on
CPU backends — every cycle pays XLA scatter ops (the winner-per-destination
scatter-max and the cache ring-buffer scatter-writes) that lower to serial
per-element loops. This engine splits the protocol the way a router splits a
network:

* **control plane on the host** — which message reaches which node in which
  round is *payload-independent* (it depends only on the PRNG draws, the
  churn matrix and the delay/drop outcomes). Per chunk, the engine draws the
  per-cycle destinations/delays/drops on-device with the *same* threefry
  calls as the reference engine (bitwise-identical), pulls the integer
  tables to the host, and resolves the K winner rounds with vectorized
  numpy fancy-index assignments (no XLA scatters); routing for the next
  chunk overlaps the in-flight device scan. The message economy stats
  (sent/delivered/lost/overflow) fall out of the same pass.
* **data plane in one ``lax.scan``** — all cycles between two eval points
  run as ONE XLA program over the precomputed routing tables: gather the
  winning payloads, apply the K receives (merge + update + cache-write,
  scatter-free one-hot ring-buffer writes), refresh the in-flight payload
  buffer. Population error is evaluated on-device at each ``eval_every``
  boundary; host round-trips drop from O(cycles) to O(cycles/eval_every).
* **node-axis sharding** — the receive application (everything that scales
  with N·d) runs under ``shard_map`` with the node axis split over a device
  mesh, reusing the peer-axis machinery proven in
  ``gossip_optimizer.gossip_merge``.
* **fused cycle kernel** — on TPU the receive application lowers to the
  Pallas ``kernels/gossip_cycle.py`` kernel: deliver→merge→update→
  cache-write in one VMEM-resident pass per node block (interpret mode on
  CPU for the parity tests).
* **compacted multi-receive rounds** — the first winner round touches most
  nodes and stays dense, but rounds ≥ 2 touch only the multi-receivers
  (~a quarter of the population in the extreme scenario, and winner rounds
  nest: round-k receivers ⊆ round-(k-1) receivers). The router emits capped
  compacted index lists and the data plane gathers / applies the remaining
  chain / scatters back just those nodes, so K-round apply cost tracks the
  delivered-message count instead of K·N (dense fallback per chunk when the
  multi round is near-full).
* **wire-dtype payloads** — ``cfg.wire_dtype="bf16"/"f16"/"int8"/
  "int8_sr"`` stores the in-flight ``buf_w`` (the engine's dominant memory:
  ``(D, N, d)``) in the wire dtype; messages are quantized at send time and
  all merge math runs in f32, the exact contract of ``gossip_merge``'s
  ``exchange_dtype``. The affine int8 dtypes carry per-message f16
  scale/zero-point lanes (``buf_scale``/``buf_zp``) and dequantize at
  delivery — in-kernel for the Pallas path; "int8_sr" rounds stochastically
  with the same per-cycle ``k_recv`` threefry slot as the reference engine.
  ``SimResult`` reports ``wire_bytes_total``/``buf_payload_bytes``.

Determinism contract: for a given seed the engine consumes the *same* host
RNG stream (churn trace, eval subset) and the *same* per-cycle threefry
draws as the reference engine, and resolves winners with the same
descending-slot-id semantics — so the error curves reproduce the reference
engine's (bitwise, up to XLA fusion-level float reassociation).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core import cache as cache_mod
from repro.core import peer_sampling
from repro.core.cache import ModelCache
from repro.core.gossip_optimizer import (dequantize_wire, is_quantized_wire,
                                         is_stochastic_wire, quantize_wire,
                                         resolve_wire_dtype)
from repro.core.learners import LinearModel, make_update
from repro.core.merge import create_model
from repro.core.simulation import (SimResult, _eval, eval_points,
                                   message_wire_bytes, payload_buffer_bytes,
                                   sim_setup)
from repro.sharding.compat import shard_map_compat


def key_schedule(seed: int, cycles: int):
    """The reference driver's per-cycle subkeys, as one stacked array.

    Bitwise-identical to ``for c: key, sub = split(key)`` — the sharded
    engine scans over this array instead of splitting on the host."""
    def body(k, _):
        k, sub = jax.random.split(k)
        return k, sub
    _, subs = lax.scan(body, jax.random.key(seed), None, length=max(cycles, 1))
    return subs[:cycles]


# ---------------------------------------------------------------------------
# control plane: per-cycle draws (device, bitwise = reference) + host routing
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "drop", "delay_max",
                                             "sampler"))
def _draw_chunk(keys, onlines, clock0, *, n: int, drop: float,
                delay_max: int, sampler: str):
    """(T,) keys -> (T, n) destination / arrival tables.

    Scans the exact per-cycle draw sequence of ``cycle_core`` (split into 4,
    then dst / delay / drop in that order), so every threefry value matches
    the reference engine bit for bit."""
    D = delay_max

    def body(clock, inp):
        key, online = inp
        k_recv, k_dst, k_delay, k_drop = jax.random.split(key, 4)
        if sampler == "matching":
            dst = peer_sampling.perfect_matching(k_dst, n)
        else:
            dst = peer_sampling.uniform_peers(k_dst, n)
        delay = (jax.random.randint(k_delay, (n,), 1, D + 1)
                 if D > 1 else jnp.ones((n,), jnp.int32))
        dropped = (jax.random.bernoulli(k_drop, drop, (n,))
                   if drop > 0 else jnp.zeros((n,), bool))
        idle = dst == jnp.arange(n, dtype=dst.dtype)
        send_ok = online & ~dropped & ~idle
        arrival = jnp.where(send_ok, clock + delay, -1)
        return clock + 1, (dst.astype(jnp.int32), arrival.astype(jnp.int32))

    _, (dsts, arrivals) = lax.scan(body, clock0, (keys, onlines))
    return dsts, arrivals


class _HostRouter:
    """Host-side control-plane state: which flat buffer slot holds a message
    for which destination, bucketed by arrival cycle.

    The router is the "control plane" half of the engine split (diagrammed
    in docs/ARCHITECTURE.md): routing is *payload-independent* — it depends
    only on the PRNG draws, the churn matrix and the delay/drop outcomes —
    so it runs on the host in numpy while the device scans the previous
    chunk's payload math. Payload-blindness is also why every wire dtype
    (f32 through int8_sr) sees the identical delivery schedule, which the
    accounting tests pin via ``sent_total`` equality across dtypes.

    ``pending[a]`` collects the flat slot ids (row*n + sender) of messages
    arriving at cycle ``a``; ``dst[row]`` mirrors the destination lane of
    the device buffer. Bucketing at send time means delivery never scans
    the full (D·N) buffer — per cycle the router touches only the ~N
    messages actually due."""

    def __init__(self, n: int, delay_max: int):
        self.n = n
        self.delay_max = delay_max
        self.dst = np.zeros((delay_max, n), np.int32)
        self.pending: dict = {}

    def route_chunk(self, dsts, arrivals, online_rows, clock0: int,
                    k_rounds: int):
        """Resolve winner-per-destination rounds for a chunk of cycles.

        Reproduces ``select_receivers``'s semantics exactly: in round k a
        node accepts the due message with the k-th largest flat slot id.
        The K scatter-max rounds become K numpy fancy-index assignments
        (ascending index order => last write wins => max slot id), which
        run at memcpy-like speed instead of XLA:CPU's serial scatters.

        Returns ``(src_slot, stats, multi)``: ``src_slot`` (T, K, n) int32
        with -1 marking "no receive this round" (the data plane derives the
        valid mask from the sign, so only one integer table crosses to the
        device), and ``multi`` — one int32 array per cycle listing the nodes
        that receive in round 2 or later (ascending). Winner rounds fill in
        order, so round-k receivers are a subset of round-(k-1) receivers:
        ``multi[t]`` indexes *every* receive beyond round 1, which is what
        the compacted data-plane path gathers/scatters."""
        T, n = dsts.shape
        D, K = self.delay_max, k_rounds
        src_slot = np.full((T, K, n), -1, np.int32)
        multi = [_EMPTY_I32] * T
        sent = delivered = lost = overflow = 0
        flat_dst = self.dst.reshape(-1)

        for t in range(T):
            clock = clock0 + t
            due = self.pending.pop(clock, [])
            if due:
                # ascending flat slot id => fancy-assign keeps the max
                cand = np.sort(np.concatenate(due))
                dst_c = flat_dst[cand]
                on = online_rows[t][dst_c]
                lost += int(cand.size - on.sum())
                rem = cand[on]
                rem_dst = dst_c[on]
                for k in range(K):
                    if rem.size == 0:
                        break
                    win = src_slot[t, k]
                    win[rem_dst] = rem            # last (= max sid) wins
                    delivered += int((win >= 0).sum())
                    keep = win[rem_dst] != rem    # not this round's winner
                    rem = rem[keep]
                    rem_dst = rem_dst[keep]
                overflow += int(rem.size)
                if K > 1:
                    multi[t] = np.flatnonzero(
                        src_slot[t, 1] >= 0).astype(np.int32)
            # sends happen after deliveries: overwrite this cycle's slot row
            row = clock % D
            self.dst[row] = dsts[t]
            arr = arrivals[t]
            base = row * n
            sel = np.flatnonzero(arr >= 0)        # one pass over the sends
            sent += int(sel.size)
            if sel.size:
                # stable sort groups by arrival cycle, ascending sender
                # index within each group (ascending flat slot id)
                order = np.argsort(arr[sel], kind="stable")
                sorted_arr = arr[sel][order]
                sorted_idx = sel[order]
                edges = np.searchsorted(
                    sorted_arr, np.arange(clock + 1, clock + D + 2))
                for j in range(D):
                    lo, hi = edges[j], edges[j + 1]
                    if hi > lo:
                        self.pending.setdefault(clock + 1 + j, []).append(
                            (base + sorted_idx[lo:hi]).astype(np.int32))

        stats = dict(sent=sent, delivered=delivered, lost=lost,
                     overflow=overflow)
        return src_slot, stats, multi


_EMPTY_I32 = np.empty(0, np.int32)


def pack_compact_rounds(src_slot: np.ndarray, multi, width: int):
    """Compact the dense (T, K, n) routing table for rounds >= 2.

    Rounds beyond the first touch only the ``multi`` nodes (about a quarter
    of the population in the paper's extreme scenario) — the dense table
    makes the data plane compute them over all N anyway. This packs them
    into fixed-width tables the scan can gather/scatter:

    * ``src0``  (T, n)        round-1 slots (dense — most nodes receive);
    * ``ridx``  (T, M)        receiver node ids, -1 padded;
    * ``rslot`` (T, K-1, M)   per-round slots for those nodes, -1 = none.

    ``width`` caps M; the caller buckets it (powers of two) so the jitted
    chunk fn recompiles O(log n) times, and falls back to the dense table
    when a round is near-full (see ``run_sharded_simulation``)."""
    T, K, n = src_slot.shape
    ridx = np.full((T, width), -1, np.int32)
    rslot = np.full((T, K - 1, width), -1, np.int32)
    for t, r in enumerate(multi):
        ridx[t, :r.size] = r
        if r.size:
            rslot[t, :, :r.size] = src_slot[t, 1:, r].T
    return src_slot[:, 0], ridx, rslot


# ---------------------------------------------------------------------------
# data plane: scatter-free K-receive application
# ---------------------------------------------------------------------------


def _vector_apply(last_w, last_t, fresh_w, fresh_t, cache: ModelCache,
                  msg_w, msg_t, valid, X, y, *, variant: str, update):
    """Scatter-free receive application (Algorithm 1 ON RECEIVE, K rounds).

    Bitwise-equal to ``simulation.apply_receives`` but restructured for
    dense backends: the K CREATEMODEL calls run as ONE batched update over
    (K·N, d) — the merge partner of round k is the round-(k-1) message
    (``lastModel <- m`` stores the *received* model, so the chain is known
    upfront) — and the K ring-buffer writes collapse into one one-hot
    combine instead of K scatter row-writes. Tracks the freshest model in
    the carry so the send step needs no cache gather.

    Payloads arrive in the wire dtype (bf16/f16 when ``cfg.wire_dtype`` is
    set); all merge/update arithmetic runs in f32 — the same contract as
    ``gossip_merge``'s ``exchange_dtype``. A no-op for f32 payloads."""
    msg_w = msg_w.astype(jnp.float32)
    K, n, d = msg_w.shape
    C = cache.w.shape[1]
    rows = jnp.arange(n)
    iota_c = jnp.arange(C, dtype=jnp.int32)[None, :]

    # The round chain: CREATEMODEL(m_k, lastModel) then lastModel <- m_k —
    # the merge partner of round k is the round-(k-1) *message*, so the
    # chain advances with cheap wheres (no gathers, no scatter).
    prev_w, prev_t = last_w, last_t
    off = jnp.zeros((n,), jnp.int32)
    sel = jnp.full((n, C), -1, jnp.int32)
    last_k = jnp.zeros((n,), jnp.int32)
    new_ws, new_ts = [], []
    for k in range(K):
        vm = valid[k]
        new = create_model(variant, update, LinearModel(msg_w[k], msg_t[k]),
                           LinearModel(prev_w, prev_t), X, y)
        new_ws.append(new.w)
        new_ts.append(new.t)
        # round k writes slot (ptr + #valid rounds before k) % C; later
        # rounds win on collision (only when K > C), like sequential adds
        slot_k = (cache.ptr + off) % C
        sel = jnp.where((iota_c == slot_k[:, None]) & vm[:, None], k, sel)
        off = off + vm.astype(jnp.int32)
        last_k = jnp.where(vm, k, last_k)
        prev_w = jnp.where(vm[:, None], msg_w[k], prev_w)
        prev_t = jnp.where(vm, msg_t[k], prev_t)

    new_w = jnp.stack(new_ws)                           # (K, n, d)
    new_t = jnp.stack(new_ts)
    hit = sel >= 0
    selc = jnp.maximum(sel, 0)
    cw = jnp.where(hit[:, :, None], new_w[selc, rows[:, None]], cache.w)
    ct = jnp.where(hit, new_t[selc, rows[:, None]], cache.t)
    new_cache = ModelCache(cw, ct, cache.ptr + off,
                           jnp.minimum(cache.count + off, C))

    got_any = off > 0
    fw = jnp.where(got_any[:, None], new_w[last_k, rows], fresh_w)
    ft = jnp.where(got_any, new_t[last_k, rows], fresh_t)
    return prev_w, prev_t, fw, ft, new_cache


def _pallas_apply(lam: float, interpret: bool):
    """Receive application backed by the fused Pallas gossip-cycle kernel.

    Affine-int8 wire payloads pass straight through: ``msg_w`` stays int8
    and the per-message f16 ``msg_scale``/``msg_zp`` ride along — the kernel
    dequantizes in VMEM, so HBM message traffic is paid at one byte per
    coefficient."""
    from repro.kernels.gossip_cycle import fused_receive_apply

    def apply_fn(last_w, last_t, fresh_w, fresh_t, cache, msg_w, msg_t,
                 valid, X, y, *, variant, update, msg_scale=None,
                 msg_zp=None):
        del update  # the kernel implements the Pegasos step itself
        lw, lt, cw, ct, ptr, cnt = fused_receive_apply(
            last_w, last_t, cache.w, cache.t, cache.ptr, cache.count,
            msg_w, msg_t, valid.astype(jnp.int32), X, y,
            msg_scale=msg_scale, msg_zp=msg_zp,
            variant=variant, lam=lam, interpret=interpret)
        new_cache = ModelCache(cw, ct, ptr, cnt)
        fw, ft = cache_mod.freshest(new_cache)
        return lw, lt, fw, ft, new_cache

    return apply_fn


def _shard_apply(base_apply, mesh, axis: str):
    """Wrap a receive application in shard_map over the node axis.

    Every operand carries the node dimension (leading for state/example
    arrays, second for the (K, N, ...) message stack) and the computation is
    purely per-node, so the body needs no collectives. The optional
    ``msg_scale``/``msg_zp`` metadata of the int8-Pallas path shards like
    the message stack."""
    ps_n, ps_kn = PS(axis), PS(None, axis)

    def apply_fn(last_w, last_t, fresh_w, fresh_t, cache, msg_w, msg_t,
                 valid, X, y, *, variant, update, msg_scale=None,
                 msg_zp=None):
        quantized = msg_scale is not None

        def inner(lw, lt, fw, ft, cw, ct, cp, cc, mw, mt, vl, Xs, ys,
                  *meta):
            kw = dict(msg_scale=meta[0], msg_zp=meta[1]) if quantized else {}
            lw2, lt2, fw2, ft2, c2 = base_apply(
                lw, lt, fw, ft, ModelCache(cw, ct, cp, cc), mw, mt, vl,
                Xs, ys, variant=variant, update=update, **kw)
            return lw2, lt2, fw2, ft2, c2.w, c2.t, c2.ptr, c2.count

        in_specs = (ps_n,) * 8 + (ps_kn,) * 3 + (ps_n,) * 2
        args = [last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                cache.ptr, cache.count, msg_w, msg_t, valid, X, y]
        if quantized:
            in_specs = in_specs + (ps_kn,) * 2
            args = args + [msg_scale, msg_zp]
        f = shard_map_compat(inner, mesh=mesh, in_specs=in_specs,
                             out_specs=(ps_n,) * 8)
        lw2, lt2, fw2, ft2, cw, ct, cp, cc = f(*args)
        return lw2, lt2, fw2, ft2, ModelCache(cw, ct, cp, cc)

    return apply_fn


@functools.lru_cache(maxsize=64)
def _build_chunk_fn(variant: str, learner: str, lam: float, eta: float,
                    delay_max: int, use_pallas: bool, interpret: bool,
                    mesh, axis: Optional[str], compact: bool,
                    wire: Optional[str]):
    """Jitted data-plane chunk runner, cached per configuration.

    Caching the jitted callable (rather than rebuilding the closure per
    ``run_sharded_simulation`` call) lets XLA's compile cache hit across
    runs — a benchmark sweep compiles each (chunk-length, N) combination
    once, not once per call.

    ``compact`` selects the compacted multi-receive path: round 1 is applied
    densely (most receiving nodes receive exactly once), rounds >= 2 run
    only on the gathered multi-receiver subset and scatter back — the
    K-round apply cost tracks the delivered-message count instead of K·N.
    Requires the plain ``_vector_apply`` (no mesh sharding, no Pallas).

    ``wire`` is the wire-dtype name. The affine int8 dtypes quantize at
    send (per-message f16 scale/zero-point written into the buf_scale/
    buf_zp carry lanes) and dequantize at delivery — in the scan body for
    the jnp paths, in VMEM for the Pallas kernel. "int8_sr" derives its
    per-cycle stochastic-rounding key from the scanned key stream exactly
    like the reference engine's ``k_recv`` (first slot of the 4-way split),
    so cross-engine parity stays bitwise."""
    update = make_update(learner, lam=lam, eta=eta)
    apply_fn = _pallas_apply(lam, interpret) if use_pallas else _vector_apply
    if mesh is not None and axis is not None:
        apply_fn = _shard_apply(apply_fn, mesh, axis)
    if compact and (use_pallas or mesh is not None):
        raise ValueError("compacted rounds require the plain vector apply")
    D = delay_max
    quantized = is_quantized_wire(wire)
    stochastic = is_stochastic_wire(wire)

    def chunk_fn(carry, tables, keydata, X, y, X_test, y_test, eval_idx):
        def records(clock):
            if X.ndim == 3:                   # multi-record nodes
                rec = clock % X.shape[1]
                return X[:, rec, :], y[:, rec]
            return X, y

        def gather(buf_w, buf_scale, buf_zp, idx, d):
            """Winning payloads for slot table ``idx``, dequantized for the
            jnp apply paths; the Pallas path gets the raw int8 codes plus
            their scale/zero-point as kwargs (in-kernel dequant)."""
            msg_w = buf_w.reshape(-1, d)[idx]
            if not quantized:
                return msg_w, {}
            msc = buf_scale.reshape(-1)[idx]
            mzp = buf_zp.reshape(-1)[idx]
            if use_pallas:
                return msg_w, dict(msg_scale=msc, msg_zp=mzp)
            return dequantize_wire(msg_w, msc, mzp), {}

        def send(buf_w, buf_scale, buf_zp, fresh_w, clock, kd):
            """Refresh this cycle's buffer row (quantizing on the way in)."""
            if not quantized:
                return (buf_w.at[clock % D].set(fresh_w.astype(buf_w.dtype)),
                        buf_scale, buf_zp)
            key = None
            if stochastic:
                # k_recv: slot 0 of the reference engine's per-cycle split
                key = jax.random.split(jax.random.wrap_key_data(kd), 4)[0]
            q, sc, zp = quantize_wire(fresh_w, wire, key=key)
            return (buf_w.at[clock % D].set(q),
                    buf_scale.at[clock % D].set(sc),
                    buf_zp.at[clock % D].set(zp))

        def dense_body(carry, inp):
            (last_w, last_t, fresh_w, fresh_t, cw, ct, ptr, cnt,
             buf_w, buf_t, buf_scale, buf_zp, clock) = carry
            (src_slot,), kd = inp
            valid = src_slot >= 0             # (K, n); -1 = no receive
            idx = jnp.maximum(src_slot, 0)
            n, d = last_w.shape
            Xc, yc = records(clock)
            msg_w, extra = gather(buf_w, buf_scale, buf_zp, idx, d)
            msg_t = buf_t.reshape(-1)[idx]
            last_w, last_t, fresh_w, fresh_t, cache = apply_fn(
                last_w, last_t, fresh_w, fresh_t,
                ModelCache(cw, ct, ptr, cnt), msg_w, msg_t, valid, Xc, yc,
                variant=variant, update=update, **extra)
            buf_w, buf_scale, buf_zp = send(buf_w, buf_scale, buf_zp,
                                            fresh_w, clock, kd)
            buf_t = buf_t.at[clock % D].set(fresh_t)
            return (last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                    cache.ptr, cache.count, buf_w, buf_t, buf_scale, buf_zp,
                    clock + 1), None

        def compact_body(carry, inp):
            (last_w, last_t, fresh_w, fresh_t, cw, ct, ptr, cnt,
             buf_w, buf_t, buf_scale, buf_zp, clock) = carry
            (src0, ridx, rslot), kd = inp
            n, d = last_w.shape
            Xc, yc = records(clock)
            flat_t = buf_t.reshape(-1)
            # round 1, dense over all nodes (same math as a K=1 dense apply)
            i0 = jnp.maximum(src0, 0)
            msg_w0, _ = gather(buf_w, buf_scale, buf_zp, i0[None], d)
            last_w, last_t, fresh_w, fresh_t, cache = apply_fn(
                last_w, last_t, fresh_w, fresh_t,
                ModelCache(cw, ct, ptr, cnt), msg_w0,
                flat_t[i0][None], (src0 >= 0)[None], Xc, yc,
                variant=variant, update=update)
            # rounds >= 2: gather the multi-receiver subset, continue the
            # chain (their lastModel already holds the round-1 message),
            # scatter back — work tracks delivered messages, not K·N
            pad = ridx < 0
            gi = jnp.maximum(ridx, 0)
            vc = (rslot >= 0) & (~pad)[None, :]
            sc = jnp.maximum(rslot, 0)
            sub = ModelCache(cache.w[gi], cache.t[gi], cache.ptr[gi],
                             cache.count[gi])
            msg_w2, _ = gather(buf_w, buf_scale, buf_zp, sc, d)
            lw2, lt2, fw2, ft2, sub2 = apply_fn(
                last_w[gi], last_t[gi], fresh_w[gi], fresh_t[gi], sub,
                msg_w2, flat_t[sc], vc, Xc[gi], yc[gi],
                variant=variant, update=update)
            si = jnp.where(pad, n, gi)        # out of bounds => dropped
            last_w = last_w.at[si].set(lw2, mode="drop")
            last_t = last_t.at[si].set(lt2, mode="drop")
            fresh_w = fresh_w.at[si].set(fw2, mode="drop")
            fresh_t = fresh_t.at[si].set(ft2, mode="drop")
            cache = ModelCache(cache.w.at[si].set(sub2.w, mode="drop"),
                               cache.t.at[si].set(sub2.t, mode="drop"),
                               cache.ptr.at[si].set(sub2.ptr, mode="drop"),
                               cache.count.at[si].set(sub2.count, mode="drop"))
            buf_w, buf_scale, buf_zp = send(buf_w, buf_scale, buf_zp,
                                            fresh_w, clock, kd)
            buf_t = buf_t.at[clock % D].set(fresh_t)
            return (last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                    cache.ptr, cache.count, buf_w, buf_t, buf_scale, buf_zp,
                    clock + 1), None

        body = compact_body if compact else dense_body
        carry, _ = lax.scan(body, carry, (tables, keydata))
        cache = ModelCache(carry[4], carry[5], carry[6], carry[7])
        errs = _eval(cache, eval_idx, X_test, y_test)
        return carry, errs

    return jax.jit(chunk_fn, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_sharded_simulation(cfg: GossipLinearConfig, X, y, X_test, y_test, *,
                           cycles: int = 200, eval_every: int = 10,
                           seed: int = 0, eval_nodes: int = 100,
                           sampler: str = "uniform", k_rounds: int = 4,
                           mesh=None, node_axis: Optional[str] = None,
                           use_pallas: Optional[bool] = None,
                           interpret: Optional[bool] = None,
                           compact_rounds: Optional[bool] = None) -> SimResult:
    """Run the protocol with the sharded mega-population engine.

    ``mesh``: optional ``jax.sharding.Mesh``; the node axis is split over
    ``node_axis`` (default: the mesh's first axis) — N must be divisible by
    that axis size. ``use_pallas`` selects the fused cycle kernel (default:
    only on TPU; requires the Pegasos learner); ``interpret`` forces Pallas
    interpret mode (default: on for non-TPU backends, for CPU testing).
    ``compact_rounds`` selects the compacted multi-receive path (rounds >= 2
    gather/apply/scatter only the receiving nodes); default: on whenever the
    plain vector apply runs (no mesh, no Pallas) and k_rounds > 1. A chunk
    whose multi-receiver round is near-full (> N/2) falls back to the dense
    table. ``cfg.wire_dtype`` ("bf16"/"f16"/"int8"/"int8_sr") stores the
    in-flight payload buffer — the engine's dominant memory — in the wire
    dtype (the int8 dtypes add (D, N) f16 scale/zero-point lanes); merge
    math stays f32 and the identical quantization is applied by the
    reference engine, so cross-engine parity holds under quantization too,
    including the stochastic-rounding noise (both engines draw it from the
    same per-cycle ``k_recv`` threefry slot)."""
    n, d = X.shape[0], X.shape[-1]
    D = max(cfg.delay_max_cycles, 1)
    wdt = resolve_wire_dtype(cfg.wire_dtype)
    buf_dtype = wdt or jnp.float32
    online_mat, eval_idx, X, y, X_test, y_test = sim_setup(
        cfg, X, y, X_test, y_test, cycles=cycles, seed=seed,
        eval_nodes=eval_nodes)

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas and cfg.learner != "pegasos":
        use_pallas = False            # kernel covers the P2Pegasos hot path

    node_sharding = None
    axis = None
    if mesh is not None:
        axis = node_axis or mesh.axis_names[0]
        axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        if axis_size > 1:
            if n % axis_size != 0:
                raise ValueError(
                    f"sharded engine needs N divisible by the '{axis}' mesh "
                    f"axis ({n} % {axis_size} != 0)")
            node_sharding = NamedSharding(mesh, PS(axis))
        else:
            mesh = axis = None

    if compact_rounds is None:
        compact_rounds = (mesh is None and not use_pallas)
    compact_rounds = compact_rounds and k_rounds > 1  # K=1 has no rounds >= 2

    def get_chunk_fn(compact: bool):
        return _build_chunk_fn(cfg.variant, cfg.learner, cfg.lam, cfg.eta,
                               D, use_pallas, interpret, mesh, axis, compact,
                               cfg.wire_dtype)

    # data-plane carry: models + cache + payload lanes of the buffer (the
    # int8 wire dtypes add the (D, N) f16 scale/zero-point lanes; empty
    # (0, 0) arrays otherwise so the float paths carry nothing extra)
    meta_shape = (D, n) if is_quantized_wire(cfg.wire_dtype) else (0, 0)
    carry = (jnp.zeros((n, d), jnp.float32), jnp.zeros((n,), jnp.int32),
             jnp.zeros((n, d), jnp.float32), jnp.zeros((n,), jnp.int32),
             *cache_mod.init_cache(n, cfg.cache_size, d),
             jnp.zeros((D, n, d), buf_dtype), jnp.zeros((D, n), jnp.int32),
             jnp.zeros(meta_shape, jnp.float16),
             jnp.zeros(meta_shape, jnp.float16),
             jnp.zeros((), jnp.int32))
    if node_sharding is not None:
        put_n = lambda a: jax.device_put(a, node_sharding)
        put_dn = lambda a: (jax.device_put(
            a, NamedSharding(mesh, PS(None, axis))) if a.size else a)
        carry = tuple(put_n(a) for a in carry[:8]) + (
            put_dn(carry[8]), put_dn(carry[9]), put_dn(carry[10]),
            put_dn(carry[11]), carry[12])
        X, y = put_n(X), put_n(y)

    res = SimResult([], [], [], [], 0, cfg)
    res.buf_payload_bytes = payload_buffer_bytes(D, n, d, cfg.wire_dtype)
    pts = eval_points(cycles, eval_every)
    if not pts:                       # cycles == 0: nothing to simulate
        return res

    keys = key_schedule(seed, cycles)
    # raw uint32 key data for the scan body (the SR quantizer re-derives the
    # reference engine's per-cycle k_recv from it; DCE'd when not needed)
    keydata = jnp.asarray(jax.random.key_data(keys))
    router = _HostRouter(n, D)
    bounds = list(zip([0] + pts[:-1], pts))

    def draw(lo, hi):
        dsts, arrivals = _draw_chunk(
            keys[lo:hi], jnp.asarray(online_mat[lo:hi]), jnp.int32(lo), n=n,
            drop=cfg.drop_prob, delay_max=D, sampler=sampler)
        return np.asarray(dsts), np.asarray(arrivals)

    # With all integer draws staged upfront (bounded: 8 bytes/node-cycle),
    # chunk i+1's host routing overlaps chunk i's device scan — the scan is
    # dispatched asynchronously and only the eval results are fetched, once,
    # after the last chunk. Each staged entry is released right after it is
    # routed, so host memory stays bounded by ~one chunk of draw tables.
    prefetch = cycles * n <= 250_000_000
    if prefetch:
        staged = [draw(lo, hi) for lo, hi in bounds]

    # compacted-table width, sticky across chunks (monotone powers of two)
    # so the jitted chunk fn compiles O(log n) times per run, not per chunk
    compact_width = 8

    def route(i):
        nonlocal compact_width
        lo, hi = bounds[i]
        if prefetch:
            dn, an = staged[i]
            staged[i] = None          # satellite fix: bound prefetch memory
        else:
            dn, an = draw(lo, hi)
        src_slot, stats, multi = router.route_chunk(
            dn, an, online_mat[lo:hi], lo, k_rounds)
        m_raw = max((r.size for r in multi), default=0)
        if compact_rounds and m_raw <= n // 2:
            while compact_width < m_raw:
                compact_width *= 2
            return True, pack_compact_rounds(src_slot, multi,
                                             compact_width), stats
        return False, (src_slot,), stats

    errs_pending = []
    pending = route(0)
    for i, p in enumerate(pts):
        lo, hi = bounds[i]
        is_compact, tables, stats = pending
        carry, errs = get_chunk_fn(is_compact)(
            carry, tuple(jnp.asarray(a) for a in tables), keydata[lo:hi],
            X, y, X_test, y_test, eval_idx)
        if i + 1 < len(pts):
            pending = route(i + 1)    # overlaps the in-flight device scan
        res.sent_total += stats["sent"]
        res.delivered_total += stats["delivered"]
        res.lost_total += stats["lost"]
        res.overflow_total += stats["overflow"]
        res.cycles.append(p)
        errs_pending.append(errs)
    for err_f, err_v, sim in errs_pending:
        res.err_fresh.append(float(err_f))
        res.err_voted.append(float(err_v))
        res.similarity.append(float(sim))
    res.wire_bytes_total = res.sent_total * message_wire_bytes(d, cfg.wire_dtype)
    return res
