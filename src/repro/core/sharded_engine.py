"""Sharded mega-population gossip engine (``run_simulation(engine="sharded")``).

The reference driver tops out around N ~ 10^4: it re-enters Python every
cycle (one jitted call + host syncs for the stats scalars), and — worse on
CPU backends — every cycle pays XLA scatter ops (the winner-per-destination
scatter-max and the cache ring-buffer scatter-writes) that lower to serial
per-element loops. This engine splits the protocol the way a router splits a
network:

* **control plane on the host** — which message reaches which node in which
  round is *payload-independent* (it depends only on the PRNG draws, the
  churn matrix and the delay/drop outcomes). Per chunk, the engine draws the
  per-cycle destinations/delays/drops on-device with the *same* threefry
  calls as the reference engine (bitwise-identical), pulls the integer
  tables to the host, and resolves the K winner rounds with vectorized
  numpy fancy-index assignments (no XLA scatters); routing for the next
  chunk overlaps the in-flight device scan. The message economy stats
  (sent/delivered/lost/overflow) fall out of the same pass.
* **data plane in one ``lax.scan``** — all cycles between two eval points
  run as ONE XLA program over the precomputed routing tables: gather the
  winning payloads, apply the K receives (merge + update + cache-write,
  scatter-free one-hot ring-buffer writes), refresh the in-flight payload
  buffer. Population error is evaluated on-device at each ``eval_every``
  boundary; host round-trips drop from O(cycles) to O(cycles/eval_every).
* **node-axis sharding** — the receive application (everything that scales
  with N·d) runs under ``shard_map`` with the node axis split over a device
  mesh, reusing the peer-axis machinery proven in
  ``gossip_optimizer.gossip_merge``.
* **fused cycle kernel** — on TPU the receive application lowers to the
  Pallas ``kernels/gossip_cycle.py`` kernel: deliver→merge→update→
  cache-write in one VMEM-resident pass per node block (interpret mode on
  CPU for the parity tests).
* **delivery-proportional compacted rounds** — winner rounds nest
  (round-k receivers ⊆ round-(k-1) receivers), so the router's per-cycle
  receiver lists bound the real work. Per chunk the driver picks the
  cheapest packing from the observed occupancy: ``dense`` (K rounds over
  all N), ``compact`` (round 1 dense, rounds ≥ 2 gathered/applied/
  scattered over the multi-receivers — ~a quarter of the population in the
  extreme scenario) or ``compact_all`` (every round over the round-1
  receiver subset — in sparse-delivery regimes a few percent of N, the
  regime of the paper's Fig. 5–7). Under a node mesh the tables are packed
  per shard, so the compact paths run inside ``shard_map`` too; the dense
  fallback is kept for near-full subsets.
* **wire-codec payloads** — ``cfg.wire_dtype`` names a codec from
  ``repro.core.wire_codec`` and stores the in-flight ``buf_w`` (the
  engine's dominant memory: ``(D, N, P)`` with P the codec's packed width)
  in the codec's payload representation; messages are encoded at send time
  and all merge math runs in f32, the exact contract of ``gossip_merge``'s
  ``exchange_dtype``. Quantized codecs carry a per-message f16 scale lane
  (``buf_scale``; the affine int8 family adds ``buf_zp``) and decode at
  delivery — in-kernel for the Pallas path (including the packed int4/
  ternary unpack); "int8_sr" rounds stochastically with the same per-cycle
  ``k_recv`` threefry slot as the reference engine. The ``_ef`` codecs
  (int4_ef/ternary_ef) add the (N, d) f32 error-feedback residual to the
  carry: senders transmit ``fresh + ef`` and refresh the residual only on
  cycles they actually send — the dense/compact bodies scan the router's
  per-cycle send mask, ``compact_all`` refreshes the sender subset — which
  keeps all packings bitwise-equal to the reference engine. With
  ``use_send_kernel`` the send-side quantization runs as the fused Pallas
  ``quantize_send`` kernel (in-kernel threefry for the SR draw; in-kernel
  pack + fused EF-residual output for the sub-4-bit codecs), closing the
  last full-population f32 pass per cycle. ``SimResult`` reports
  ``wire_bytes_total``/``buf_payload_bytes``/``ef_residual_norm``.

Determinism contract: for a given seed the engine consumes the *same* host
RNG stream (churn trace, eval subset) and the *same* per-cycle threefry
draws as the reference engine, and resolves winners with the same
descending-slot-id semantics — so the error curves reproduce the reference
engine's (bitwise, up to XLA fusion-level float reassociation).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core import cache as cache_mod
from repro.core import faults as faults_mod
from repro.core import peer_sampling
from repro.core.cache import ModelCache
from repro.core.learners import LinearModel, make_update
from repro.core.merge import create_model
from repro.core import telemetry as telemetry_mod
from repro.core.simulation import (SimResult, _eval, ef_residual_norm,
                                   eval_points, message_wire_bytes,
                                   payload_buffer_bytes, sim_setup)
from repro.core.wire_codec import get_codec, sr_noise_for_rows
from repro.sharding.compat import shard_map_compat


def key_schedule(seed: int, cycles: int):
    """The reference driver's per-cycle subkeys, as one stacked array.

    Bitwise-identical to ``for c: key, sub = split(key)`` — the sharded
    engine scans over this array instead of splitting on the host."""
    def body(k, _):
        k, sub = jax.random.split(k)
        return k, sub
    _, subs = lax.scan(body, jax.random.key(seed), None, length=max(cycles, 1))
    return subs[:cycles]


# ---------------------------------------------------------------------------
# control plane: per-cycle draws (device, bitwise = reference) + host routing
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "drop", "delay_max",
                                             "sampler"))
def _draw_chunk(keys, onlines, clock0, *, n: int, drop: float,
                delay_max: int, sampler: str):
    """(T,) keys -> (T, n) destination / arrival tables.

    Scans the exact per-cycle draw sequence of ``cycle_core`` (split into 4,
    then dst / delay / drop in that order), so every threefry value matches
    the reference engine bit for bit."""
    D = delay_max

    def body(clock, inp):
        key, online = inp
        k_recv, k_dst, k_delay, k_drop = jax.random.split(key, 4)
        if sampler == "matching":
            dst = peer_sampling.perfect_matching(k_dst, n)
        else:
            dst = peer_sampling.uniform_peers(k_dst, n)
        delay = (jax.random.randint(k_delay, (n,), 1, D + 1)
                 if D > 1 else jnp.ones((n,), jnp.int32))
        dropped = (jax.random.bernoulli(k_drop, drop, (n,))
                   if drop > 0 else jnp.zeros((n,), bool))
        idle = dst == jnp.arange(n, dtype=dst.dtype)
        send_ok = online & ~dropped & ~idle
        arrival = jnp.where(send_ok, clock + delay, -1)
        return clock + 1, (dst.astype(jnp.int32), arrival.astype(jnp.int32))

    _, (dsts, arrivals) = lax.scan(body, clock0, (keys, onlines))
    return dsts, arrivals


class _HostRouter:
    """Host-side control-plane state: which flat buffer slot holds a message
    for which destination, carried between chunks as flat pending arrays.

    The router is the "control plane" half of the engine split (diagrammed
    in docs/ARCHITECTURE.md): routing is *payload-independent* — it depends
    only on the PRNG draws, the churn matrix and the delay/drop outcomes —
    so it runs on the host in numpy while the device scans the previous
    chunk's payload math. Payload-blindness is also why every wire dtype
    (f32 through int8_sr) sees the identical delivery schedule, which the
    accounting tests pin via ``sent_total`` equality across dtypes.

    The pending set is three parallel int32 arrays — flat slot id
    (row*n + sender), destination, absolute arrival cycle — snapshotted at
    send time. Storing the destination with the message (instead of reading
    the buffer's dst lane at delivery, as the device-side oracle does) is
    equivalent: the slot row a message occupies is provably not overwritten
    before its arrival cycle's deliveries run."""

    def __init__(self, n: int, delay_max: int):
        self.n = n
        self.delay_max = delay_max
        self.p_slot = _EMPTY_I32
        self.p_dst = _EMPTY_I32
        self.p_arr = _EMPTY_I32

    def route_chunk(self, dsts, arrivals, online_rows, clock0: int,
                    k_rounds: int, per_cycle_stats: bool = False):
        """Resolve winner-per-destination rounds for a chunk of cycles.

        Reproduces ``select_receivers``'s semantics exactly: in round k a
        node accepts the due message with the k-th largest flat slot id.
        The whole chunk is resolved in ONE batched numpy pass (no per-cycle
        Python loop): every candidate message arriving inside the chunk is
        ranked within its (cycle, destination) group by descending flat
        slot id — one lexsort — and rank r < K receives in round r.
        Distinct candidates in a group never share a slot id (a slot row is
        delivered before it is reused), so the ranking is total.

        Returns ``(win, stats, multi, recv)``:

        * ``win`` — the winner tuple ``(t, round, dst, slot)`` of parallel
          int32 arrays, ascending in (t, dst). The router deliberately does
          NOT materialize the dense (T, K, n) table: at N=10^6 that is a
          ~320 MB memset per chunk, pure waste whenever a compact packing
          is chosen. ``dense_table``/``pack_compact_rounds``/
          ``pack_compact_all`` build exactly the representation the chosen
          chunk fn consumes;
        * ``stats`` — the chunk message economy, plus ``delivered_cycles``
          (T,) per-cycle delivered counts for ``SimResult`` observability;
        * ``multi`` — one ascending int32 array per cycle listing the nodes
          that receive in round 2 or later (winner rounds fill in order, so
          round-k receivers ⊆ round-(k-1) receivers);
        * ``recv`` — one ascending int32 array per cycle listing ALL
          receiving nodes (the round-1 winners), which is what the fully
          compacted data-plane path gathers/scatters in sparse-delivery
          regimes.

        ``per_cycle_stats`` (armed telemetry only) adds ``lost_cycles`` and
        ``overflow_cycles`` (T,) bincounts to ``stats`` — the per-cycle
        message-economy streams. Both count at the ARRIVAL cycle, exactly
        like the reference engine's per-cycle stats. Off by default so the
        unarmed hot path pays nothing."""
        T, n = dsts.shape
        D, K = self.delay_max, k_rounds

        # sends of this chunk -> (slot, dst, arrival) triples, merged with
        # the pending carry; arrivals beyond the chunk become the new carry
        t_send, senders = np.nonzero(arrivals >= 0)
        slot = (((clock0 + t_send) % D) * n + senders).astype(np.int32)
        sent = int(senders.size)
        cand_slot = np.concatenate([self.p_slot, slot])
        cand_dst = np.concatenate([self.p_dst,
                                   dsts[t_send, senders].astype(np.int32)])
        cand_arr = np.concatenate([self.p_arr,
                                   arrivals[t_send, senders].astype(np.int32)])
        future = cand_arr >= clock0 + T
        self.p_slot = cand_slot[future]
        self.p_dst = cand_dst[future]
        self.p_arr = cand_arr[future]
        due = ~future
        c_slot = cand_slot[due]
        c_dst = cand_dst[due]
        c_t = cand_arr[due] - clock0

        # a message due while its destination is offline leaves the system
        on = online_rows[c_t, c_dst]
        lost = int(c_slot.size - int(on.sum()))
        lost_t = c_t[~on] if per_cycle_stats else None
        c_slot, c_dst, c_t = c_slot[on], c_dst[on], c_t[on]

        # winner ranks: sort by (cycle, dst) group, ascending slot id inside
        # each group => rank-from-group-end r is the r-th largest slot id
        group = c_t.astype(np.int64) * n + c_dst
        order = np.lexsort((c_slot, group))
        g_s = group[order]
        slot_s = c_slot[order]
        t_s = c_t[order]
        dst_s = c_dst[order]
        rank = np.searchsorted(g_s, g_s, side="right") - 1 \
            - np.arange(g_s.size)
        wm = rank < K
        win = (t_s[wm].astype(np.int32), rank[wm].astype(np.int32),
               dst_s[wm], slot_s[wm])
        delivered = int(wm.sum())
        overflow = int(g_s.size - delivered)

        def per_cycle(mask):
            # group order is (cycle, dst) ascending => each selected list is
            # ascending in node id; split at the cycle boundaries
            tm, dm = t_s[mask], dst_s[mask]
            return [a.astype(np.int32, copy=False) for a in
                    np.split(dm, np.searchsorted(tm, np.arange(1, T)))]

        recv = per_cycle(rank == 0)               # every receiver (round 1)
        multi = per_cycle(rank == 1) if K > 1 else [_EMPTY_I32] * T
        stats = dict(sent=sent, delivered=delivered, lost=lost,
                     overflow=overflow,
                     delivered_cycles=np.bincount(
                         win[0], minlength=T).astype(np.int64))
        if per_cycle_stats:
            stats["lost_cycles"] = np.bincount(
                lost_t, minlength=T).astype(np.int64)
            stats["overflow_cycles"] = np.bincount(
                t_s[~wm], minlength=T).astype(np.int64)
        return win, stats, multi, recv


_EMPTY_I32 = np.empty(0, np.int32)


def shard_list_width(lists, n: int, shards: int) -> int:
    """Smallest per-shard width that fits every per-cycle index list.

    With ``shards == 1`` this is just the longest list. With a node mesh the
    compacted tables must stay node-sharded, so receivers are packed
    per shard of the node axis (shard s owns nodes [s*n/S, (s+1)*n/S)) and
    the width is the largest per-shard receiver count over the chunk."""
    if shards == 1:
        return max((r.size for r in lists), default=0)
    bounds = np.arange(1, shards) * (n // shards)
    w = 0
    for r in lists:
        if r.size:
            w = max(w, int(np.max(np.diff(np.searchsorted(
                r, np.concatenate([[0], bounds, [n]]))))))
    return w


def _pack_index_lists(lists, n: int, width: int, shards: int):
    """(T,) ascending index lists -> (T, shards*width) int32, -1 padded.

    Shard s's entries land in columns [s*width, (s+1)*width): under a node
    mesh the packed axis is sharded like the node axis, and each device's
    slice references only its own nodes — the gather/apply/scatter of the
    compact path stays shard-local."""
    T = len(lists)
    ridx = np.full((T, shards * width), -1, np.int32)
    if shards == 1:
        for t, r in enumerate(lists):
            ridx[t, :r.size] = r
        return ridx
    bounds = np.arange(1, shards) * (n // shards)
    for t, r in enumerate(lists):
        cuts = np.searchsorted(r, np.concatenate([[0], bounds, [n]]))
        for s in range(shards):
            seg = r[cuts[s]:cuts[s + 1]]
            ridx[t, s * width:s * width + seg.size] = seg
    return ridx


def dense_table(win, T: int, K: int, n: int) -> np.ndarray:
    """The dense (T, K, n) routing table from a winner tuple: entry
    [t, r, dst] holds the flat slot id of dst's round-r receive at cycle
    t, -1 = no receive. Built only when the dense chunk fn actually runs —
    at N=10^6 this is the router's single biggest allocation."""
    t_w, r_w, dst_w, slot_w = win
    src_slot = np.full((T, K, n), -1, np.int32)
    src_slot[t_w, r_w, dst_w] = slot_w
    return src_slot


def _packed_columns(lists, t_w, dst_w, n: int, width: int, shards: int):
    """Packed-table column of each winner: the position of ``dst_w[i]``
    inside its cycle's (shard-grouped) index list. ``t_w`` must be
    ascending; every dst must be present in its cycle's list (winner
    rounds nest, so receiver lists cover all deeper rounds)."""
    cols = np.empty(t_w.size, np.int64)
    bounds = np.searchsorted(t_w, np.arange(len(lists) + 1))
    shard_size = n // shards
    for t, r in enumerate(lists):
        lo, hi = bounds[t], bounds[t + 1]
        if hi == lo:
            continue
        d = dst_w[lo:hi]
        pos = np.searchsorted(r, d)
        if shards == 1:
            cols[lo:hi] = pos
        else:
            s = d // shard_size
            cuts = np.searchsorted(r, np.arange(shards) * shard_size)
            cols[lo:hi] = s * width + (pos - cuts[s])
    return cols


def pack_compact_rounds(win, multi, T: int, K: int, n: int, width: int,
                        shards: int = 1):
    """Compact the routing of rounds >= 2 (round 1 stays dense).

    Rounds beyond the first touch only the ``multi`` nodes (about a quarter
    of the population in the paper's extreme scenario) — the dense table
    makes the data plane compute them over all N anyway. This builds:

    * ``src0``  (T, n)        round-1 slots (dense — most nodes receive);
    * ``ridx``  (T, S*M)      receiver node ids, -1 padded, grouped by node
                              shard (S = ``shards``) so meshes stay local;
    * ``rslot`` (T, K-1, S*M) per-round slots for those nodes, -1 = none.

    ``width`` caps the per-shard M; the caller buckets it (powers of two)
    so the jitted chunk fn recompiles O(log n) times, and falls back to the
    dense table when a round is near-full (see ``run_sharded_simulation``)."""
    t_w, r_w, dst_w, slot_w = win
    m0 = r_w == 0
    src0 = np.full((T, n), -1, np.int32)
    src0[t_w[m0], dst_w[m0]] = slot_w[m0]
    ridx = _pack_index_lists(multi, n, width, shards)
    rslot = np.full((T, K - 1, ridx.shape[1]), -1, np.int32)
    mk = ~m0
    cols = _packed_columns(multi, t_w[mk], dst_w[mk], n, width, shards)
    rslot[t_w[mk], r_w[mk] - 1, cols] = slot_w[mk]
    return src0, ridx, rslot


def pack_compact_all(win, recv, T: int, K: int, n: int, width: int,
                     shards: int = 1):
    """Compact ALL receive rounds over the round-1 receiver set.

    In sparse-delivery regimes (high drop, low online fraction, long
    delays) even round 1 touches only a few percent of the population —
    the ``compact`` packing still pays a dense O(N) round-1 apply. Winner
    rounds nest, so the round-1 receiver list ``recv`` covers every round;
    this packs the full K-round chain for just those nodes:

    * ``ridx``  (T, S*M)     receiving node ids, -1 padded, shard-grouped;
    * ``rslot`` (T, K, S*M)  per-round slots for those nodes, -1 = none.

    The data plane gathers the subset state, runs the same K-round apply
    the dense path runs on all N, and scatters back — per-cycle apply cost
    tracks delivered messages instead of population size."""
    t_w, r_w, dst_w, slot_w = win
    ridx = _pack_index_lists(recv, n, width, shards)
    rslot = np.full((T, K, ridx.shape[1]), -1, np.int32)
    cols = _packed_columns(recv, t_w, dst_w, n, width, shards)
    rslot[t_w, r_w, cols] = slot_w
    return ridx, rslot


# ---------------------------------------------------------------------------
# data plane: scatter-free K-receive application
# ---------------------------------------------------------------------------


def _vector_apply(last_w, last_t, fresh_w, fresh_t, cache: ModelCache,
                  msg_w, msg_t, valid, X, y, *, variant: str, update,
                  defense: str = "none"):
    """Scatter-free receive application (Algorithm 1 ON RECEIVE, K rounds).

    Bitwise-equal to ``simulation.apply_receives`` but restructured for
    dense backends: the K CREATEMODEL calls run as ONE batched update over
    (K·N, d) — the merge partner of round k is the round-(k-1) message
    (``lastModel <- m`` stores the *received* model, so the chain is known
    upfront) — and the K ring-buffer writes collapse into one one-hot
    combine instead of K scatter row-writes. Tracks the freshest model in
    the carry so the send step needs no cache gather.

    Payloads arrive in the wire dtype (bf16/f16 when ``cfg.wire_dtype`` is
    set); all merge/update arithmetic runs in f32 — the same contract as
    ``gossip_merge``'s ``exchange_dtype``. A no-op for f32 payloads.

    ``defense`` screens each round against the receiver's current chain
    model (``faults.apply_defense``, same semantics and op order as the
    reference ``apply_receives``): a rejected message drops out of the
    round's ``vm`` mask, a clipped one continues the chain rescaled.
    Returns ``(last_w, last_t, fresh_w, fresh_t, cache, gated, clipped)``
    with (N,) int32 per-node screen counts (zeros under ``"none"``)."""
    msg_w = msg_w.astype(jnp.float32)
    K, n, d = msg_w.shape
    C = cache.w.shape[1]
    rows = jnp.arange(n)
    iota_c = jnp.arange(C, dtype=jnp.int32)[None, :]

    # The round chain: CREATEMODEL(m_k, lastModel) then lastModel <- m_k —
    # the merge partner of round k is the round-(k-1) *message*, so the
    # chain advances with cheap wheres (no gathers, no scatter).
    prev_w, prev_t = last_w, last_t
    off = jnp.zeros((n,), jnp.int32)
    sel = jnp.full((n, C), -1, jnp.int32)
    last_k = jnp.zeros((n,), jnp.int32)
    gated = jnp.zeros((n,), jnp.int32)
    clipped = jnp.zeros((n,), jnp.int32)
    new_ws, new_ts = [], []
    for k in range(K):
        mw, vm, g, cl = faults_mod.apply_defense(
            defense, msg_w[k], valid[k], prev_w)
        gated = gated + g.astype(jnp.int32)
        clipped = clipped + cl.astype(jnp.int32)
        new = create_model(variant, update, LinearModel(mw, msg_t[k]),
                           LinearModel(prev_w, prev_t), X, y)
        new_ws.append(new.w)
        new_ts.append(new.t)
        # round k writes slot (ptr + #valid rounds before k) % C; later
        # rounds win on collision (only when K > C), like sequential adds
        slot_k = (cache.ptr + off) % C
        sel = jnp.where((iota_c == slot_k[:, None]) & vm[:, None], k, sel)
        off = off + vm.astype(jnp.int32)
        last_k = jnp.where(vm, k, last_k)
        prev_w = jnp.where(vm[:, None], mw, prev_w)
        prev_t = jnp.where(vm, msg_t[k], prev_t)

    new_w = jnp.stack(new_ws)                           # (K, n, d)
    new_t = jnp.stack(new_ts)
    hit = sel >= 0
    selc = jnp.maximum(sel, 0)
    cw = jnp.where(hit[:, :, None], new_w[selc, rows[:, None]], cache.w)
    ct = jnp.where(hit, new_t[selc, rows[:, None]], cache.t)
    new_cache = ModelCache(cw, ct, cache.ptr + off,
                           jnp.minimum(cache.count + off, C))

    got_any = off > 0
    fw = jnp.where(got_any[:, None], new_w[last_k, rows], fresh_w)
    ft = jnp.where(got_any, new_t[last_k, rows], fresh_t)
    return prev_w, prev_t, fw, ft, new_cache, gated, clipped


def _pallas_apply(lam: float, interpret: bool, wire, defense: str = "none"):
    """Receive application backed by the fused Pallas gossip-cycle kernel.

    Quantized wire payloads pass straight through: ``msg_w`` stays in the
    codec's packed representation and the per-message f16 ``msg_scale``
    (plus ``msg_zp`` for the affine int8 family) ride along — the kernel
    decodes in VMEM (affine dequant, int4 nibble unpack, base-3 ternary
    unpack), so HBM message traffic is paid at wire precision. The
    ``defense`` screen runs in-kernel between the decode and the merge
    (same round-chain placement as the jnp paths)."""
    from repro.kernels.gossip_cycle import fused_receive_apply

    def apply_fn(last_w, last_t, fresh_w, fresh_t, cache, msg_w, msg_t,
                 valid, X, y, *, variant, update, msg_scale=None,
                 msg_zp=None):
        del update  # the kernel implements the Pegasos step itself
        lw, lt, cw, ct, ptr, cnt, gated, clipped = fused_receive_apply(
            last_w, last_t, cache.w, cache.t, cache.ptr, cache.count,
            msg_w, msg_t, valid.astype(jnp.int32), X, y,
            msg_scale=msg_scale, msg_zp=msg_zp, wire=wire,
            variant=variant, lam=lam, interpret=interpret, defense=defense)
        new_cache = ModelCache(cw, ct, ptr, cnt)
        fw, ft = cache_mod.freshest(new_cache)
        return lw, lt, fw, ft, new_cache, gated, clipped

    return apply_fn


def _shard_apply(base_apply, mesh, axis: str):
    """Wrap a receive application in shard_map over the node axis.

    Every operand carries the node dimension (leading for state/example
    arrays, second for the (K, N, ...) message stack) and the computation is
    purely per-node, so the body needs no collectives. The optional
    ``msg_scale``/``msg_zp`` metadata of the quantized Pallas path shards
    like the message stack (scale-only codecs pass no ``msg_zp``)."""
    ps_n, ps_kn = PS(axis), PS(None, axis)

    def apply_fn(last_w, last_t, fresh_w, fresh_t, cache, msg_w, msg_t,
                 valid, X, y, *, variant, update, msg_scale=None,
                 msg_zp=None):
        meta = [(k, v) for k, v in (("msg_scale", msg_scale),
                                    ("msg_zp", msg_zp)) if v is not None]

        def inner(lw, lt, fw, ft, cw, ct, cp, cc, mw, mt, vl, Xs, ys,
                  *meta_vals):
            kw = dict(zip((k for k, _ in meta), meta_vals))
            lw2, lt2, fw2, ft2, c2, g2, cl2 = base_apply(
                lw, lt, fw, ft, ModelCache(cw, ct, cp, cc), mw, mt, vl,
                Xs, ys, variant=variant, update=update, **kw)
            return (lw2, lt2, fw2, ft2, c2.w, c2.t, c2.ptr, c2.count,
                    g2, cl2)

        in_specs = (ps_n,) * 8 + (ps_kn,) * 3 + (ps_n,) * 2 \
            + (ps_kn,) * len(meta)
        args = [last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                cache.ptr, cache.count, msg_w, msg_t, valid, X, y] \
            + [v for _, v in meta]
        f = shard_map_compat(inner, mesh=mesh, in_specs=in_specs,
                             out_specs=(ps_n,) * 10)
        lw2, lt2, fw2, ft2, cw, ct, cp, cc, g2, cl2 = f(*args)
        return lw2, lt2, fw2, ft2, ModelCache(cw, ct, cp, cc), g2, cl2

    return apply_fn


# jitted chunk runners by config label, for compile-count accounting:
# tools/lint/retrace_guard.py reads these via retrace_counts() and fails
# when a run compiles more signatures than its pinned budget
_CHUNK_FNS: Dict[str, Any] = {}


def retrace_counts() -> Dict[str, int]:
    """Compile-cache entry counts of the engine's jitted hot-path fns.

    One entry per distinct traced signature (shape/dtype/static-arg
    combination); a run that keeps compiling — chunk-length churn, packed
    widths that never go sticky, a dtype flapping between chunks — shows up
    here long before it shows up as a bench rate."""
    counts = {"sharded_engine._draw_chunk": _draw_chunk._cache_size()}
    for label, fn in _CHUNK_FNS.items():
        counts[f"sharded_engine.chunk_fn[{label}]"] = fn._cache_size()
    return counts


@functools.lru_cache(maxsize=64)
def _build_chunk_fn(variant: str, learner: str, lam: float, eta: float,
                    delay_max: int, use_pallas: bool, interpret: bool,
                    mesh, axis: Optional[str], mode: str,
                    wire: Optional[str], use_send_kernel: bool,
                    fault_model: Optional[str] = None,
                    defense: str = "none", emit_streams: bool = False):
    """Jitted data-plane chunk runner, cached per configuration.

    Caching the jitted callable (rather than rebuilding the closure per
    ``run_sharded_simulation`` call) lets XLA's compile cache hit across
    runs — a benchmark sweep compiles each (chunk-length, N) combination
    once, not once per call.

    ``mode`` selects the receive-apply packing (chosen per chunk by the
    driver from the router's observed occupancy — see
    ``run_sharded_simulation``):

    * ``"dense"``       — the (T, K, n) table, K-round apply over all N;
    * ``"compact"``     — round 1 dense, rounds >= 2 gathered/applied/
                          scattered over the multi-receiver subset;
    * ``"compact_all"`` — ALL rounds over the gathered round-1 receiver
                          subset: per-cycle apply cost tracks delivered
                          messages, the sparse-delivery hot path.

    Both compact modes run under a node mesh too: the router packs the
    subset tables per node shard (``pack_compact_rounds``/
    ``pack_compact_all`` with ``shards`` = mesh axis size), so the packed
    axis shards like the node axis and the subset apply stays inside
    ``shard_map``. Only the Pallas *receive* kernel still requires the
    dense table (its grid covers all node blocks).

    ``wire`` is the wire-codec name. Quantized codecs encode at send
    (per-message f16 scale — plus a zero-point for the affine int8 family —
    written into the buf_scale/buf_zp carry lanes) and decode at delivery —
    in the scan body for the jnp paths, in VMEM for the Pallas kernel.
    "int8_sr" derives its per-cycle stochastic-rounding key from the
    scanned key stream exactly like the reference engine's ``k_recv``
    (first slot of the 4-way split), so cross-engine parity stays bitwise.
    The ``_ef`` codecs carry the (N, d) f32 error-feedback residual and
    scan the router's per-cycle send mask (dense/compact modes) or refresh
    the sender subset (``compact_all``) — the residual updates exactly
    where the reference engine's ``send_ok`` holds. ``use_send_kernel``
    routes the send-side quantization through the fused Pallas
    ``quantize_send`` kernel (in-kernel threefry for the SR draw; fused
    pack + EF-residual output for the sub-4-bit codecs) instead of the jnp
    codec ops — bitwise-identical by contract.

    ``fault_model``/``defense`` (static, from ``cfg``) thread
    ``repro.core.faults`` through the data plane: model-kind faults
    corrupt the Byzantine rows' ``(send_w, send_t)`` before the wire
    encode (``compact_all`` corrupts only the sender subset,
    ``rows=``-exact), the wire-kind "bitflip" rewrites the encoded
    payload after the EF-residual update, and the defense screen runs
    per round inside every apply path. The fault key is the reference
    engine's ``fault_key`` fold-in from the scanned cycle key, so both
    engines draw identical corruption — and fault-free chunk fns are
    built with ``fault_model=None``, leaving their traces unchanged.

    ``emit_streams`` (static, set by an armed ``telemetry=``) makes the
    chunk fn return the scan's per-cycle (T,) gated/clipped int32 arrays
    instead of their jitted sums — the driver sums on the host (exact for
    integers) and emits them as per-cycle streams. Unarmed runs keep the
    pre-telemetry program byte for byte; armed fns get a distinct
    "/telem" label (and their own retrace budget)."""
    update = make_update(learner, lam=lam, eta=eta)
    fault = faults_mod.get_fault(fault_model)
    apply_fn = (_pallas_apply(lam, interpret, wire, defense) if use_pallas
                else functools.partial(_vector_apply, defense=defense))
    if mesh is not None and axis is not None:
        apply_fn = _shard_apply(apply_fn, mesh, axis)
    if mode != "dense" and use_pallas:
        raise ValueError("compacted rounds require the vector apply "
                         "(the Pallas receive kernel is dense)")
    D = delay_max
    codec = get_codec(wire)
    if use_send_kernel:
        from repro.kernels.gossip_cycle import quantize_send

    def chunk_fn(carry, tables, keydata, X, y, X_test, y_test, eval_idx,
                 byz):
        def records(clock):
            if X.ndim == 3:                   # multi-record nodes
                rec = clock % X.shape[1]
                return X[:, rec, :], y[:, rec]
            return X, y

        def gather(buf_w, buf_scale, buf_zp, idx, d):
            """Winning payloads for slot table ``idx``, decoded for the
            jnp apply paths; the Pallas path gets the raw packed codes plus
            their scale (and zero-point when the codec carries one) as
            kwargs — in-kernel decode."""
            msg_w = buf_w.reshape(-1, buf_w.shape[-1])[idx]
            if not codec.quantized:
                return msg_w, {}
            msc = buf_scale.reshape(-1)[idx]
            mzp = buf_zp.reshape(-1)[idx] if codec.has_zp else None
            if use_pallas:
                extra = dict(msg_scale=msc)
                if codec.has_zp:
                    extra["msg_zp"] = mzp
                return msg_w, extra
            return codec.decode(msg_w, msc, mzp, d), {}

        def send(buf_w, buf_scale, buf_zp, ef, send_w, clock, kd, smask):
            """Refresh this cycle's buffer row (encoding on the way in).

            ``send_w`` is the (possibly fault-corrupted) transmitted model;
            ``smask`` (the router's per-cycle ``arrival >= 0`` == the
            reference engine's ``send_ok``) gates the EF-residual refresh;
            it is only scanned when the codec keeps EF state. The
            wire-kind "bitflip" fault rewrites the payload AFTER the
            EF-residual update — the honest sender's bookkeeping is
            computed from what it encoded, not what the channel delivers
            (same ordering as ``cycle_core``)."""
            row = clock % D
            x = send_w + ef if codec.ef else send_w
            if not codec.quantized:
                q, sc, zp = x.astype(buf_w.dtype), None, None
            else:
                key = None
                if codec.stochastic:
                    # k_recv: slot 0 of the reference per-cycle split
                    key = jax.random.split(
                        jax.random.wrap_key_data(kd), 4)[0]
                if use_send_kernel:
                    outs = quantize_send(
                        send_w, wire,
                        key_data=(jax.random.key_data(key)
                                  if codec.stochastic else None),
                        ef=ef if codec.ef else None, interpret=interpret)
                    if codec.has_zp:
                        q, sc, zp = outs
                    elif codec.ef:
                        (q, sc), zp = outs[:2], None
                        resid = outs[2]
                    else:
                        (q, sc), zp = outs, None
                else:
                    q, sc, zp = codec.encode(x, key=key)
                    if codec.ef:
                        resid = x - codec.decode(q, sc, zp,
                                                 send_w.shape[-1])
                if codec.ef:
                    ef = jnp.where(smask[:, None], resid, ef)
            if fault is not None and fault.kind == "wire":
                q = faults_mod.bitflip_payload(
                    byz, faults_mod.fault_key(jax.random.wrap_key_data(kd)),
                    q)
            if not codec.quantized:
                return buf_w.at[row].set(q), buf_scale, buf_zp, ef
            return (buf_w.at[row].set(q), buf_scale.at[row].set(sc),
                    buf_zp.at[row].set(zp) if codec.has_zp else buf_zp, ef)

        def corrupt_send(fresh_w, fresh_t, cache, kd):
            """Model-kind fault: the Byzantine rows' transmitted model —
            a static no-op when faults are off or wire-kind."""
            if fault is None or fault.kind != "model":
                return fresh_w, fresh_t
            old_w = old_t = None
            if fault.name == "stale_replay":
                old_w, old_t = cache_mod.cache_oldest(cache)
            return faults_mod.corrupt_model(
                fault, byz, faults_mod.fault_key(
                    jax.random.wrap_key_data(kd)),
                fresh_w, fresh_t, old_w, old_t)

        def dense_body(carry, inp):
            (last_w, last_t, fresh_w, fresh_t, cw, ct, ptr, cnt,
             buf_w, buf_t, buf_scale, buf_zp, ef, clock) = carry
            (src_slot, *sm), kd = inp
            valid = src_slot >= 0             # (K, n); -1 = no receive
            idx = jnp.maximum(src_slot, 0)
            n, d = last_w.shape
            Xc, yc = records(clock)
            msg_w, extra = gather(buf_w, buf_scale, buf_zp, idx, d)
            msg_t = buf_t.reshape(-1)[idx]
            last_w, last_t, fresh_w, fresh_t, cache, gated, clipped = \
                apply_fn(
                    last_w, last_t, fresh_w, fresh_t,
                    ModelCache(cw, ct, ptr, cnt), msg_w, msg_t, valid,
                    Xc, yc, variant=variant, update=update, **extra)
            send_w, send_t = corrupt_send(fresh_w, fresh_t, cache, kd)
            buf_w, buf_scale, buf_zp, ef = send(
                buf_w, buf_scale, buf_zp, ef, send_w, clock, kd,
                sm[0] if sm else None)
            buf_t = buf_t.at[clock % D].set(send_t)
            return (last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                    cache.ptr, cache.count, buf_w, buf_t, buf_scale, buf_zp,
                    ef, clock + 1), (jnp.sum(gated), jnp.sum(clipped))

        def subset_apply(state, ridx, rslot, Xc, yc, buf_w, buf_scale,
                         buf_zp, flat_t):
            """Gather the ``ridx`` subset, run the (K', W)-round chain on
            it, scatter back — the shared core of both compact modes. Work
            tracks the packed width, not N; padding (-1) gathers node 0
            with an all-False valid mask and scatters out of bounds
            (dropped), so it is inert."""
            last_w, last_t, fresh_w, fresh_t, cache = state
            n, d = last_w.shape
            pad = ridx < 0
            gi = jnp.maximum(ridx, 0)
            vc = (rslot >= 0) & (~pad)[None, :]
            sc = jnp.maximum(rslot, 0)
            sub = ModelCache(cache.w[gi], cache.t[gi], cache.ptr[gi],
                             cache.count[gi])
            msg_w, _ = gather(buf_w, buf_scale, buf_zp, sc, d)
            lw2, lt2, fw2, ft2, sub2, g2, cl2 = apply_fn(
                last_w[gi], last_t[gi], fresh_w[gi], fresh_t[gi], sub,
                msg_w, flat_t[sc], vc, Xc[gi], yc[gi],
                variant=variant, update=update)
            si = jnp.where(pad, n, gi)        # out of bounds => dropped
            last_w = last_w.at[si].set(lw2, mode="drop")
            last_t = last_t.at[si].set(lt2, mode="drop")
            fresh_w = fresh_w.at[si].set(fw2, mode="drop")
            fresh_t = fresh_t.at[si].set(ft2, mode="drop")
            cache = ModelCache(cache.w.at[si].set(sub2.w, mode="drop"),
                               cache.t.at[si].set(sub2.t, mode="drop"),
                               cache.ptr.at[si].set(sub2.ptr, mode="drop"),
                               cache.count.at[si].set(sub2.count,
                                                      mode="drop"))
            # pad rows carry valid=False everywhere, so their screen
            # counts are structurally zero — a plain sum is exact
            return (last_w, last_t, fresh_w, fresh_t, cache,
                    jnp.sum(g2), jnp.sum(cl2))

        def compact_body(carry, inp):
            (last_w, last_t, fresh_w, fresh_t, cw, ct, ptr, cnt,
             buf_w, buf_t, buf_scale, buf_zp, ef, clock) = carry
            (src0, ridx, rslot, *sm), kd = inp
            n, d = last_w.shape
            Xc, yc = records(clock)
            flat_t = buf_t.reshape(-1)
            # round 1, dense over all nodes (same math as a K=1 dense apply)
            i0 = jnp.maximum(src0, 0)
            msg_w0, _ = gather(buf_w, buf_scale, buf_zp, i0[None], d)
            last_w, last_t, fresh_w, fresh_t, cache, g0, cl0 = apply_fn(
                last_w, last_t, fresh_w, fresh_t,
                ModelCache(cw, ct, ptr, cnt), msg_w0,
                flat_t[i0][None], (src0 >= 0)[None], Xc, yc,
                variant=variant, update=update)
            # rounds >= 2: continue the chain on the multi-receiver subset
            # (their lastModel already holds the round-1 message)
            last_w, last_t, fresh_w, fresh_t, cache, g2, cl2 = subset_apply(
                (last_w, last_t, fresh_w, fresh_t, cache), ridx, rslot,
                Xc, yc, buf_w, buf_scale, buf_zp, flat_t)
            send_w, send_t = corrupt_send(fresh_w, fresh_t, cache, kd)
            buf_w, buf_scale, buf_zp, ef = send(
                buf_w, buf_scale, buf_zp, ef, send_w, clock, kd,
                sm[0] if sm else None)
            buf_t = buf_t.at[clock % D].set(send_t)
            return (last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                    cache.ptr, cache.count, buf_w, buf_t, buf_scale, buf_zp,
                    ef, clock + 1), (jnp.sum(g0) + g2, jnp.sum(cl0) + cl2)

        def send_compact(buf_w, buf_t, buf_scale, buf_zp, ef, fresh_w,
                         fresh_t, clock, kd, sidx, cache):
            """Refresh only the SENDERS' slots of this cycle's buffer row.

            In sparse regimes most nodes are offline or drop their send;
            their slots keep stale payloads that the router provably never
            routes (only ``arrival >= 0`` messages enter the pending set),
            so writing — and for the quantized codecs, encoding — just the
            ``sidx`` subset is exact. The "int8_sr" noise is regenerated at
            the senders' positions (``sr_noise_for_rows``), bitwise-equal
            to the dense ``jax.random.uniform`` draw at those rows; the
            ``_ef`` codecs gather/refresh/scatter only the senders'
            residual rows — exactly the rows the reference engine's
            ``send_ok`` mask refreshes. Fault corruption stays
            sender-proportional too: model-kind faults corrupt the
            gathered subset (``rows=gi`` regenerates the dense
            ``random_payload`` draw at the senders' global rows), the
            wire-kind bitflip flips the subset's encoded payloads — both
            bitwise-equal to the reference engine at the routed rows."""
            n, d = fresh_w.shape
            pad = sidx < 0
            gi = jnp.maximum(sidx, 0)
            si = jnp.where(pad, n, gi)        # out of bounds => dropped
            row = clock % D
            send_w, send_t = fresh_w[gi], fresh_t[gi]
            if fault is not None and fault.kind == "model":
                old_w = old_t = None
                if fault.name == "stale_replay":
                    old_w, old_t = cache_mod.cache_oldest(ModelCache(
                        cache.w[gi], cache.t[gi], cache.ptr[gi],
                        cache.count[gi]))
                send_w, send_t = faults_mod.corrupt_model(
                    fault, byz[gi], faults_mod.fault_key(
                        jax.random.wrap_key_data(kd)),
                    send_w, send_t, old_w, old_t, rows=gi, n_total=n)
            sub_x = send_w + ef[gi] if codec.ef else send_w
            if not codec.quantized:
                q = sub_x.astype(buf_w.dtype)
            else:
                noise = None
                if codec.stochastic:
                    key = jax.random.split(
                        jax.random.wrap_key_data(kd), 4)[0]
                    noise = sr_noise_for_rows(key, gi, d, n)
                q, sc, zp = codec.encode(sub_x, noise=noise)
                if codec.ef:
                    resid = sub_x - codec.decode(q, sc, zp, d)
                    ef = ef.at[si].set(resid, mode="drop")
            if fault is not None and fault.kind == "wire":
                q = faults_mod.bitflip_payload(
                    byz[gi], faults_mod.fault_key(
                        jax.random.wrap_key_data(kd)),
                    q, rows=gi, n_total=n)
            buf_w = buf_w.at[row, si].set(q, mode="drop")
            if codec.quantized:
                buf_scale = buf_scale.at[row, si].set(sc, mode="drop")
                if codec.has_zp:
                    buf_zp = buf_zp.at[row, si].set(zp, mode="drop")
            buf_t = buf_t.at[row, si].set(send_t, mode="drop")
            return buf_w, buf_t, buf_scale, buf_zp, ef

        def compact_all_body(carry, inp):
            (last_w, last_t, fresh_w, fresh_t, cw, ct, ptr, cnt,
             buf_w, buf_t, buf_scale, buf_zp, ef, clock) = carry
            (ridx, rslot, sidx), kd = inp
            Xc, yc = records(clock)
            flat_t = buf_t.reshape(-1)
            # every round over the round-1 receiver subset: non-receivers
            # are never touched, so per-cycle apply cost is
            # delivery-proportional (the sparse-delivery hot path) — and
            # the send refresh (buffer slots AND EF residuals) is
            # sender-proportional to match
            last_w, last_t, fresh_w, fresh_t, cache, g2, cl2 = subset_apply(
                (last_w, last_t, fresh_w, fresh_t,
                 ModelCache(cw, ct, ptr, cnt)), ridx, rslot,
                Xc, yc, buf_w, buf_scale, buf_zp, flat_t)
            buf_w, buf_t, buf_scale, buf_zp, ef = send_compact(
                buf_w, buf_t, buf_scale, buf_zp, ef, fresh_w, fresh_t,
                clock, kd, sidx, cache)
            return (last_w, last_t, fresh_w, fresh_t, cache.w, cache.t,
                    cache.ptr, cache.count, buf_w, buf_t, buf_scale, buf_zp,
                    ef, clock + 1), (g2, cl2)

        body = {"dense": dense_body, "compact": compact_body,
                "compact_all": compact_all_body}[mode]
        carry, (g_cycles, cl_cycles) = lax.scan(body, carry,
                                                (tables, keydata))
        cache = ModelCache(carry[4], carry[5], carry[6], carry[7])
        errs = _eval(cache, eval_idx, X_test, y_test)
        if emit_streams:
            return carry, (errs, (g_cycles, cl_cycles))
        return carry, (errs, (jnp.sum(g_cycles), jnp.sum(cl_cycles)))

    jitted = jax.jit(chunk_fn, donate_argnums=(0,))
    # the index prefix keeps labels unique when configs differ only in a
    # field the label omits (lam, eta, mesh, ...); the fault/defense
    # suffixes appear only when active, so fault-free runs keep their
    # pre-fault labels (and retrace budgets)
    label = (f"{len(_CHUNK_FNS)}:{variant}/{learner}/{mode}/{wire or 'f32'}"
             + ("/pallas" if use_pallas else "")
             + ("/sendk" if use_send_kernel else "")
             + (f"/fault:{fault_model}" if fault_model else "")
             + (f"/def:{defense}" if defense != "none" else "")
             + ("/telem" if emit_streams else ""))
    _CHUNK_FNS[label] = jitted
    return jitted


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_sharded_simulation(cfg: GossipLinearConfig, X, y, X_test, y_test, *,
                           cycles: int = 200, eval_every: int = 10,
                           seed: int = 0, eval_nodes: int = 100,
                           sampler: str = "uniform", k_rounds: int = 4,
                           mesh=None, node_axis: Optional[str] = None,
                           use_pallas: Optional[bool] = None,
                           interpret: Optional[bool] = None,
                           compact_rounds: Optional[bool] = None,
                           compact_mode: Optional[str] = None,
                           use_send_kernel: Optional[bool] = None,
                           serve_hook=None, telemetry=None
                           ) -> SimResult:
    """Run the protocol with the sharded mega-population engine.

    ``mesh``: optional ``jax.sharding.Mesh``; the node axis is split over
    ``node_axis`` (default: the mesh's first axis) — N must be divisible by
    that axis size. ``use_pallas`` selects the fused cycle kernel (default:
    only on TPU; requires the Pegasos learner); ``interpret`` forces Pallas
    interpret mode (default: on for non-TPU backends, for CPU testing).

    ``compact_rounds`` allows the compacted receive paths (default: on
    whenever the vector apply runs, i.e. no Pallas — meshes included, via
    per-shard packed tables). Per chunk the driver picks the cheapest of
    three packings from the router's observed occupancy — ``"dense"``
    (K rounds over all N), ``"compact"`` (round 1 dense, rounds >= 2 over
    the multi-receiver subset) and ``"compact_all"`` (every round over the
    round-1 receiver subset; in sparse-delivery regimes per-cycle apply
    cost tracks delivered messages instead of N) — falling back to dense
    when a subset is near-full (> N/2). ``compact_mode`` forces one packing
    for every chunk (benchmarks pin the PR 3 behavior with
    ``compact_mode="compact"``).

    ``cfg.wire_dtype`` names a wire codec (``repro.core.wire_codec``:
    "bf16"/"f16"/"int8"/"int8_sr"/"int4"/"int4_ef"/"ternary"/"ternary_ef")
    and stores the in-flight payload buffer — the engine's dominant memory
    — in the codec's packed representation (quantized codecs add the
    (D, N) f16 scale lane, the affine int8 family a zero-point lane, the
    ``_ef`` codecs the (N, d) f32 error-feedback residual); merge math
    stays f32 and the identical encoding is applied by the reference
    engine, so cross-engine parity holds under quantization too, including
    the stochastic-rounding noise (both engines draw it from the same
    per-cycle ``k_recv`` threefry slot) and the EF residual chain (updated
    exactly on the reference engine's ``send_ok`` cycles — the
    dense/compact bodies scan the router's send mask, ``compact_all``
    refreshes the sender subset). ``use_send_kernel`` fuses the send-side
    quantization into the Pallas ``quantize_send`` kernel (default: with
    ``use_pallas`` on quantized codecs, no mesh) — the kernel reproduces
    the jnp codec bitwise, including the in-kernel threefry draw of the
    "int8_sr" noise and the packed codecs' EF residual. Chunks running the
    ``compact_all`` packing go one step further regardless of the flag:
    they encode only the sender subset (``sr_noise_for_rows`` keeps the
    noise positionally identical), which strictly dominates a
    full-population kernel pass.

    ``serve_hook``: optional ``hook(cycle, snapshot)`` — the live serving
    surface. Called at every eval point (chunk boundary) with a
    ``repro.core.serving.QuerySnapshot`` built from the scan carry, a pure
    read of the live cache lanes: bitwise identical to the reference
    engine's snapshot at the same cycle, and provably non-perturbing (the
    scan never observes the hook). The hook must consume the snapshot
    before the next chunk runs — the chunk fn donates its carry.

    ``telemetry``: optional ``repro.core.telemetry.Telemetry`` — same
    pure-read discipline as ``serve_hook``. Armed runs emit the registered
    per-cycle metric streams (the router's per-cycle message economy, the
    scan's per-cycle gated/clipped counts via the "/telem" chunk-fn
    variant) bitwise-equal to the reference engine's streams under every
    packing, and record host spans around routing, chunk dispatch,
    snapshot adoption and the deferred result drain. One armed cost is
    paid eagerly: the ``_ef`` codecs sync the EF-residual RMS at each
    eval point (the float read must happen before the next chunk donates
    the carry); everything else stays pipelined."""
    n, d = X.shape[0], X.shape[-1]
    D = max(cfg.delay_max_cycles, 1)
    codec = get_codec(cfg.wire_dtype)
    online_mat, eval_idx, X, y, X_test, y_test = sim_setup(
        cfg, X, y, X_test, y_test, cycles=cycles, seed=seed,
        eval_nodes=eval_nodes)

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas and cfg.learner != "pegasos":
        use_pallas = False            # kernel covers the P2Pegasos hot path

    node_sharding = None
    axis = None
    shards = 1
    if mesh is not None:
        axis = node_axis or mesh.axis_names[0]
        axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        if axis_size > 1:
            if n % axis_size != 0:
                raise ValueError(
                    f"sharded engine needs N divisible by the '{axis}' mesh "
                    f"axis ({n} % {axis_size} != 0)")
            node_sharding = NamedSharding(mesh, PS(axis))
            shards = axis_size
        else:
            mesh = axis = None

    if compact_rounds is None:
        compact_rounds = not use_pallas
    if compact_mode is not None:
        if compact_mode not in ("dense", "compact", "compact_all"):
            raise ValueError(f"unknown compact_mode {compact_mode!r}")
        if compact_mode == "compact" and k_rounds == 1:
            raise ValueError("compact_mode='compact' needs k_rounds > 1 "
                             "(there are no rounds >= 2 to compact)")
        if compact_mode != "dense" and use_pallas:
            raise ValueError("compacted rounds require the vector apply "
                             "(the Pallas receive kernel is dense)")
        compact_rounds = compact_mode != "dense"
    if use_send_kernel is None:
        use_send_kernel = use_pallas and codec.quantized and mesh is None
    elif use_send_kernel:
        if not codec.quantized:
            raise ValueError("use_send_kernel needs a quantized (int8 or "
                             "sub-4-bit) wire dtype — float wire dtypes "
                             "send a plain cast")
        if mesh is not None:
            raise ValueError("the Pallas send kernel does not run under a "
                             "node mesh")

    faults_mod.check_defense(cfg.defense)
    byz = byz_np = None
    if cfg.fault_model is not None:
        faults_mod.get_fault(cfg.fault_model)   # fail fast on unknown names
        byz_np = faults_mod.byzantine_mask(seed, n, cfg.byzantine_frac)
        byz = jnp.asarray(byz_np)

    tel = telemetry
    armed = tel is not None

    def get_chunk_fn(mode: str):
        return _build_chunk_fn(cfg.variant, cfg.learner, cfg.lam, cfg.eta,
                               D, use_pallas, interpret, mesh, axis, mode,
                               cfg.wire_dtype, use_send_kernel,
                               cfg.fault_model, cfg.defense, armed)

    # data-plane carry: models + cache + payload lanes of the buffer (the
    # quantized codecs add the (D, N) f16 scale lane — plus a zero-point
    # lane for the affine int8 family — and the _ef codecs the (N, d) f32
    # error-feedback residual; empty (0, 0) arrays otherwise so the float
    # paths carry nothing extra)
    sc_shape = (D, n) if codec.has_scale else (0, 0)
    zp_shape = (D, n) if codec.has_zp else (0, 0)
    carry = (jnp.zeros((n, d), jnp.float32), jnp.zeros((n,), jnp.int32),
             jnp.zeros((n, d), jnp.float32), jnp.zeros((n,), jnp.int32),
             *cache_mod.init_cache(n, cfg.cache_size, d),
             jnp.zeros((D, n, codec.payload_cols(d)), codec.payload_dtype),
             jnp.zeros((D, n), jnp.int32),
             jnp.zeros(sc_shape, jnp.float16),
             jnp.zeros(zp_shape, jnp.float16),
             jnp.zeros((n, d) if codec.ef else (0, 0), jnp.float32),
             jnp.zeros((), jnp.int32))
    if node_sharding is not None:
        put_n = lambda a: (jax.device_put(a, node_sharding) if a.size
                           else a)
        put_dn = lambda a: (jax.device_put(
            a, NamedSharding(mesh, PS(None, axis))) if a.size else a)
        carry = tuple(put_n(a) for a in carry[:8]) + (
            put_dn(carry[8]), put_dn(carry[9]), put_dn(carry[10]),
            put_dn(carry[11]), put_n(carry[12]), carry[13])
        X, y = put_n(X), put_n(y)
        if byz is not None:
            byz = put_n(byz)

    res = SimResult([], [], [], [], 0, cfg)
    res.buf_payload_bytes = payload_buffer_bytes(D, n, d, cfg.wire_dtype)
    res.fault_stats = {"corrupted": 0, "gated": 0, "clipped": 0}
    pts = eval_points(cycles, eval_every)
    if not pts:                       # cycles == 0: nothing to simulate
        return res

    keys = key_schedule(seed, cycles)
    # raw uint32 key data for the scan body (the SR quantizer re-derives the
    # reference engine's per-cycle k_recv from it; DCE'd when not needed)
    keydata = jnp.asarray(jax.random.key_data(keys))
    router = _HostRouter(n, D)
    bounds = list(zip([0] + pts[:-1], pts))

    def draw(lo, hi):
        dsts, arrivals = _draw_chunk(
            keys[lo:hi], jnp.asarray(online_mat[lo:hi]), jnp.int32(lo), n=n,
            drop=cfg.drop_prob, delay_max=D, sampler=sampler)
        return np.asarray(dsts), np.asarray(arrivals)

    # With all integer draws staged upfront (bounded: 8 bytes/node-cycle),
    # chunk i+1's host routing overlaps chunk i's device scan — the scan is
    # dispatched asynchronously and only the eval results are fetched, once,
    # after the last chunk. Each staged entry is released right after it is
    # routed, so host memory stays bounded by ~one chunk of draw tables.
    prefetch = cycles * n <= 250_000_000
    if prefetch:
        with telemetry_mod.maybe_span(tel, "stage_draws", track="control",
                                      chunks=len(bounds)):
            staged = [draw(lo, hi) for lo, hi in bounds]

    # compacted-table widths, sticky across chunks (monotone powers of two)
    # so the jitted chunk fn compiles O(log n) times per run, not per chunk
    widths = {"compact": 8, "compact_all": 8, "send": 8}
    mode_counts = {"dense": 0, "compact": 0, "compact_all": 0}
    occ_recv: list = []
    occ_multi: list = []

    def bucket(kind: str, need: int) -> int:
        w = widths[kind]
        while w < need:
            w *= 2
        return w

    def route(i):
        """Route chunk i and pick its packing from the observed occupancy.

        Candidate per-cycle work estimates (in node-row units): dense =
        K·N + N, compact = N + (K+1)·W_multi + N,
        compact_all = (K+4)·W_recv + 5·W_send. The trailing terms charge
        the send-side buffer refresh (dense row write for dense/compact,
        sender-proportional scatter+quantize for compact_all); the +1/+4
        constants charge the subset gather/scatter overhead, calibrated on
        the 2-core bench container so the chooser declines compact_all
        near ~5% occupancy, where the measured crossover sits
        (BENCH_population_scaling.json ``derived`` rows). Estimates use
        the sticky bucketed widths so the choice matches what would
        actually compile; a subset over N/2 disqualifies its packing (the
        dense fallback of PR 2, now per mode)."""
        lo, hi = bounds[i]
        if prefetch:
            dn, an = staged[i]
            staged[i] = None          # satellite fix: bound prefetch memory
        else:
            dn, an = draw(lo, hi)
        win, stats, multi, recv = router.route_chunk(
            dn, an, online_mat[lo:hi], lo, k_rounds, per_cycle_stats=armed)
        stats["recv_sizes"] = np.array([r.size for r in recv], np.int64)
        stats["multi_sizes"] = np.array([r.size for r in multi], np.int64)
        # corrupted = Byzantine senders with send_ok (an >= 0 == the
        # reference engine's send_ok) — pure control-plane info, so the
        # host counts it while the device scan runs payload math
        stats["corrupted"] = (int(byz_np[np.nonzero(an >= 0)[1]].sum())
                              if byz_np is not None else 0)
        T = hi - lo
        if armed:
            # per-cycle sends (and Byzantine sends) straight off the
            # arrival table — armed-only host reductions for the streams
            send_mask = an >= 0
            stats["sent_cycles"] = send_mask.sum(axis=1).astype(np.int64)
            stats["corrupted_cycles"] = (
                (send_mask & byz_np[None, :]).sum(axis=1).astype(np.int64)
                if byz_np is not None else np.zeros(T, np.int64))

        # sender lists cost T flatnonzero passes over (T, N) — build them
        # only when a compact_all packing is actually on the table
        sender_cache: list = []

        def senders():
            if not sender_cache:
                sender_cache.append([np.flatnonzero(an[t] >= 0)
                                     .astype(np.int32) for t in range(T)])
            return sender_cache[0]

        cand = {"dense": k_rounds * n + n}
        wm = w1 = ws = None
        if compact_rounds:
            wm = bucket("compact", shard_list_width(multi, n, shards))
            w1 = bucket("compact_all", shard_list_width(recv, n, shards))
            if k_rounds > 1 and int(stats["multi_sizes"].max(initial=0)) \
                    <= n // 2:
                cand["compact"] = n + (k_rounds + 1) * shards * wm + n
            if int(stats["recv_sizes"].max(initial=0)) <= n // 2:
                ws = bucket("send", shard_list_width(senders(), n, shards))
                cand["compact_all"] = ((k_rounds + 4) * shards * w1
                                       + 5 * shards * ws)
        mode = (compact_mode if compact_mode is not None
                else min(cand, key=cand.get))
        if mode == "compact":
            widths["compact"] = wm
            tables = pack_compact_rounds(win, multi, T, k_rounds, n, wm,
                                         shards)
        elif mode == "compact_all":
            if ws is None:            # forced mode past the near-full gate
                ws = bucket("send", shard_list_width(senders(), n, shards))
            widths["compact_all"] = w1
            widths["send"] = ws
            tables = (*pack_compact_all(win, recv, T, k_rounds, n, w1,
                                        shards),
                      _pack_index_lists(senders(), n, ws, shards))
        else:
            tables = (dense_table(win, T, k_rounds, n),)
        if codec.ef and mode != "compact_all":
            # the EF residual refreshes exactly where the reference
            # engine's send_ok holds == where a message entered the pending
            # set; compact_all carries the same information as the packed
            # sender list instead of a dense mask
            tables = (*tables, an >= 0)
        return mode, tables, stats

    msg_bytes = message_wire_bytes(d, cfg.wire_dtype)
    in_flight = 0
    errs_pending = []
    with telemetry_mod.maybe_span(tel, "route_chunk", track="control",
                                  chunk=0):
        pending = route(0)
    for i, p in enumerate(pts):
        lo, hi = bounds[i]
        mode, tables, stats = pending
        with telemetry_mod.maybe_span(tel, "chunk_dispatch", track="device",
                                      chunk=i, mode=mode, cycles=hi - lo):
            carry, (errs, fstats) = get_chunk_fn(mode)(
                carry, tuple(jnp.asarray(a) for a in tables), keydata[lo:hi],
                X, y, X_test, y_test, eval_idx, byz)
        if serve_hook is not None:
            # pure read of the fresh carry, dispatched before the next
            # chunk donates it; the scan never observes the hook, so the
            # run is bitwise identical with or without serving
            from repro.core import serving
            with telemetry_mod.maybe_span(tel, "snapshot", track="serving",
                                          cycle=p):
                serve_hook(p, serving.snapshot_from_carry(carry))
        if armed:
            # the one eager armed read: the EF-residual RMS must be taken
            # before the next chunk donates the carry (a no-op float for
            # non-EF codecs — carry[12] is the empty (0, 0) lane)
            tel.emit("ef_residual_rms", ef_residual_norm(carry[12]))
        if i + 1 < len(pts):
            with telemetry_mod.maybe_span(tel, "route_chunk",
                                          track="control", chunk=i + 1):
                pending = route(i + 1)   # overlaps the in-flight device scan
        res.sent_total += stats["sent"]
        res.delivered_total += stats["delivered"]
        res.lost_total += stats["lost"]
        res.overflow_total += stats["overflow"]
        res.fault_stats["corrupted"] += stats["corrupted"]
        res.delivered_per_cycle.extend(
            int(x) for x in stats["delivered_cycles"])
        mode_counts[mode] += 1
        occ_recv.append(stats["recv_sizes"])
        occ_multi.append(stats["multi_sizes"])
        res.cycles.append(p)
        errs_pending.append((errs, fstats))
        if armed:
            # per-cycle streams for this chunk, all host-side numpy on the
            # router's tables — identical numbers under every packing, and
            # bitwise-equal to the reference engine's streams
            sc = stats["sent_cycles"]
            dc = stats["delivered_cycles"]
            flow = np.cumsum(sc - dc - stats["lost_cycles"]
                             - stats["overflow_cycles"]) + in_flight
            in_flight = int(flow[-1])
            tel.emit_row(
                sent=sc, delivered=dc, lost=stats["lost_cycles"],
                overflow=stats["overflow_cycles"], in_flight=flow,
                wire_bytes=sc * msg_bytes,
                recv_nodes=stats["recv_sizes"],
                multi_nodes=stats["multi_sizes"],
                online_nodes=online_mat[lo:hi].sum(axis=1),
                corrupted=stats["corrupted_cycles"])
    with telemetry_mod.maybe_span(tel, "collect_results", track="device",
                                  chunks=len(errs_pending)):
        for (err_f, err_v, sim), (g, cl) in errs_pending:
            res.err_fresh.append(float(err_f))
            res.err_voted.append(float(err_v))
            res.similarity.append(float(sim))
            # armed chunk fns return per-cycle (T,) arrays (host-summed —
            # exact for ints); unarmed return the jit-summed scalars
            res.fault_stats["gated"] += int(np.sum(g))
            res.fault_stats["clipped"] += int(np.sum(cl))
            if armed:
                tel.emit("gated", np.asarray(g).reshape(-1))
                tel.emit("clipped", np.asarray(cl).reshape(-1))
    r1 = np.concatenate(occ_recv) / n
    mr = np.concatenate(occ_multi) / n
    res.compaction = dict(
        chunk_modes=dict(mode_counts),
        round1_occupancy_mean=float(r1.mean()),
        round1_occupancy_max=float(r1.max()),
        multi_occupancy_mean=float(mr.mean()),
        multi_occupancy_max=float(mr.max()),
        packed_widths=dict(widths), shards=shards)
    res.wire_bytes_total = res.sent_total * message_wire_bytes(d, cfg.wire_dtype)
    res.ef_residual_norm = ef_residual_norm(carry[12])
    if armed:
        tel.annotations.setdefault("runs", []).append(dict(
            engine="sharded", n_nodes=n, cycles=cycles,
            wire_dtype=cfg.wire_dtype or "f32", message_bytes=msg_bytes,
            chunk_modes=dict(mode_counts)))
    return res
