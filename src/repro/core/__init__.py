from repro.core.learners import (
    LinearModel,
    init_model,
    pegasos_update,
    adaline_update,
    logistic_update,
    make_update,
)
from repro.core.merge import merge, create_model, VARIANTS
from repro.core.cache import ModelCache, init_cache, cache_add, freshest, voted_predict
from repro.core.simulation import SimState, run_simulation, simulate_cycle, churn_trace
from repro.core.sharded_engine import run_sharded_simulation
from repro.core.ensemble import run_weighted_bagging, run_sequential_pegasos
from repro.core.gossip_optimizer import (
    GossipState,
    stack_for_peers,
    unstack_mean,
    gossip_merge,
    peer_disagreement,
    make_gossip_train_step,
    make_allreduce_train_step,
    perms_for_step,
    linear_gossip_mesh_step,
)
from repro.core import peer_sampling, theory

__all__ = [
    "LinearModel", "init_model", "pegasos_update", "adaline_update",
    "logistic_update", "make_update", "merge", "create_model", "VARIANTS",
    "ModelCache", "init_cache", "cache_add", "freshest", "voted_predict",
    "SimState", "run_simulation", "simulate_cycle", "churn_trace",
    "run_sharded_simulation",
    "run_weighted_bagging", "run_sequential_pegasos",
    "GossipState", "stack_for_peers", "unstack_mean", "gossip_merge",
    "peer_disagreement", "make_gossip_train_step", "make_allreduce_train_step",
    "perms_for_step", "linear_gossip_mesh_step", "peer_sampling", "theory",
]
