"""MERGE and the three CREATEMODEL variants (Algorithm 2 + Algorithm 3).

MERGE averages parameters and takes the max step counter — for linear
hypothesis spaces this implements the *virtual weighted voting over an
exponential number of models* (Section V): averaging the parameters equals
weighted voting with weights |<w, x>| (Eq. 7), so each transmitted model
stands for the entire merge-DAG of its ancestors at constant message size.
"""
from __future__ import annotations

from repro.core.learners import LinearModel

import jax.numpy as jnp


def merge(m1: LinearModel, m2: LinearModel) -> LinearModel:
    """MERGE (Algorithm 3, lines 22–26): w = (w1+w2)/2, t = max(t1,t2)."""
    return LinearModel((m1.w + m2.w) / 2.0, jnp.maximum(m1.t, m2.t))


def create_model_rw(update, m1: LinearModel, m2: LinearModel, x, y) -> LinearModel:
    """CREATEMODELRW: independent random walk — update(m1)."""
    del m2
    return update(m1, x, y)


def create_model_mu(update, m1: LinearModel, m2: LinearModel, x, y) -> LinearModel:
    """CREATEMODELMU: merge, then update — update(merge(m1, m2)).

    The favored variant: the two incoming edges of each merge node in the
    history DAG were updated with *independent* samples (Section V-B)."""
    return update(merge(m1, m2), x, y)


def create_model_um(update, m1: LinearModel, m2: LinearModel, x, y) -> LinearModel:
    """CREATEMODELUM: update both with the local example, then merge."""
    return merge(update(m1, x, y), update(m2, x, y))


VARIANTS = {
    "rw": create_model_rw,
    "mu": create_model_mu,
    "um": create_model_um,
}


def create_model(variant: str, update, m1, m2, x, y) -> LinearModel:
    return VARIANTS[variant](update, m1, m2, x, y)
