"""Bounded model cache + local prediction (Algorithm 1 state, Algorithm 4).

Each node keeps the ``cache_size`` most recent models that passed through it
(a ring buffer). Prediction is free locally: PREDICT uses the freshest
model; VOTEDPREDICT majority-votes the cache — the paper's Fig. 3 shows this
significantly accelerates RW and slightly accelerates MU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class ModelCache(NamedTuple):
    w: jnp.ndarray        # (N, C, d)
    t: jnp.ndarray        # (N, C) int32
    ptr: jnp.ndarray      # (N,) int32 — next write slot
    count: jnp.ndarray    # (N,) int32 — number of valid entries


def init_cache(n: int, c: int, d: int) -> ModelCache:
    """Cache initialized with the zero model (INITMODEL adds it)."""
    return ModelCache(
        w=jnp.zeros((n, c, d), jnp.float32),
        t=jnp.zeros((n, c), jnp.int32),
        ptr=jnp.ones((n,), jnp.int32),   # slot 0 holds the init model
        count=jnp.ones((n,), jnp.int32),
    )


def cache_add(cache: ModelCache, node_mask, w_new, t_new) -> ModelCache:
    """Vectorized ``modelCache.add`` on the subset ``node_mask`` of nodes.

    w_new: (N, d); nodes where node_mask is False are untouched."""
    n, c, d = cache.w.shape
    rows = jnp.arange(n)
    slot = cache.ptr % c
    w = cache.w.at[rows, slot].set(
        jnp.where(node_mask[:, None], w_new, cache.w[rows, slot]))
    t = cache.t.at[rows, slot].set(
        jnp.where(node_mask, t_new, cache.t[rows, slot]))
    ptr = jnp.where(node_mask, cache.ptr + 1, cache.ptr)
    count = jnp.where(node_mask, jnp.minimum(cache.count + 1, c), cache.count)
    return ModelCache(w, t, ptr, count)


def freshest(cache: ModelCache):
    """``modelCache.freshest()`` — the most recently added model per node."""
    n, c, d = cache.w.shape
    rows = jnp.arange(n)
    slot = (cache.ptr - 1) % c
    return cache.w[rows, slot], cache.t[rows, slot]


def cache_oldest(cache: ModelCache):
    """The oldest still-valid model per node (slot ``ptr - count``) — what
    a ``stale_replay`` Byzantine node retransmits: its model from
    ~``cache_size`` receives ago, with the stale counter riding along."""
    n, c, d = cache.w.shape
    rows = jnp.arange(n)
    slot = (cache.ptr - cache.count) % c
    return cache.w[rows, slot], cache.t[rows, slot]


def predict_fresh(cache: ModelCache, X):
    """PREDICT for every node over a test matrix X (m, d) -> (N, m) signs."""
    w, _ = freshest(cache)                      # (N, d)
    return jnp.where(X @ w.T >= 0, 1.0, -1.0).T


def voted_predict(cache: ModelCache, X):
    """VOTEDPREDICT (Algorithm 4): majority vote over the valid cache slots.

    Returns (N, m) predictions for every node on test matrix X (m, d)."""
    n, c, d = cache.w.shape
    scores = jnp.einsum("ncd,md->ncm", cache.w, X)
    votes = (scores >= 0).astype(jnp.float32)   # (N, C, m)
    valid = (jnp.arange(c)[None, :] < cache.count[:, None]).astype(jnp.float32)
    p_ratio = jnp.einsum("ncm,nc->nm", votes, valid) / cache.count[:, None]
    return jnp.where(p_ratio - 0.5 >= 0, 1.0, -1.0)
