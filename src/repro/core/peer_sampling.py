"""Peer sampling (Section III-c): uniform gossip sampling + baselines.

* ``uniform``  — each node draws an i.i.d. uniform peer (≠ self): the
  NEWSCAST abstraction the paper assumes (samples available locally, no
  extra messages — NEWSCAST descriptors piggyback the model messages).
* ``matching`` — the PERFECT MATCHING baseline (Section VI-A.e): a random
  perfect matching so every node receives exactly one message per cycle.
* ``hypercube`` / ``ring`` — deterministic schedules used by the on-mesh
  gossip optimizer (Layer B), where collectives need compile-time partner
  graphs; hypercube mixes the population in log2(N) rounds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def uniform_peers(key, n: int):
    """dst[i] ~ Uniform({0..n-1} \\ {i})."""
    r = jax.random.randint(key, (n,), 0, n - 1)
    idx = jnp.arange(n)
    return jnp.where(r >= idx, r + 1, r)


def perfect_matching(key, n: int):
    """Random involution: pairs exchange; odd N leaves one node unpaired.

    For even N this is a fixed-point-free random matching (every node sends
    to and receives from exactly one peer — the paper's PERFECT MATCHING
    baseline). For odd N no perfect matching exists; the leftover node of the
    random permutation maps to *itself*, which the simulator interprets as
    "idle this cycle" (it neither sends nor receives — the natural extension
    of the baseline's one-message-per-node semantics)."""
    perm = jax.random.permutation(key, n)
    # pair consecutive elements of the random permutation; odd N leaves
    # perm[-1] out, and the init value `arange` keeps it a self-map (idle).
    m = n - (n % 2)
    a, b = perm[0:m:2], perm[1:m:2]
    dst = jnp.arange(n, dtype=jnp.int32).at[a].set(b).at[b].set(a)
    return dst


def hypercube_partner(step: int, n: int):
    """partner = rank XOR 2^(step mod log2(n)). Requires n a power of two."""
    bits = int(np.log2(n))
    assert 1 << bits == n, f"hypercube needs power-of-two population, got {n}"
    return np.arange(n) ^ (1 << (step % bits))


def ring_partner(step: int, n: int):
    """Alternating ±1 ring neighbors (one ICI hop on a torus)."""
    shift = 1 if step % 2 == 0 else -1
    return (np.arange(n) + shift) % n


def random_permutation_partner(seed: int, step: int, n: int):
    """PRNG-derived pairing (closest to the paper's uniform sampling that is
    still a compile-time-known permutation for ``ppermute``)."""
    rng = np.random.default_rng((seed, step))
    perm = rng.permutation(n)
    dst = np.empty(n, dtype=np.int64)
    a, b = perm[0::2], perm[1::2]
    dst[a], dst[b] = b, a
    return dst


def partner_schedule(kind: str, step: int, n: int, seed: int = 0):
    if kind == "hypercube":
        return hypercube_partner(step, n)
    if kind == "ring":
        return ring_partner(step, n)
    if kind == "random":
        return random_permutation_partner(seed, step, n)
    raise ValueError(f"unknown schedule {kind!r}")
