"""Pluggable wire-codec subsystem: how a transmitted model rides the wire.

The paper's central cost axis is communication — one model per message,
random walks instead of raw-data movement — so the wire representation of
the transmitted model is a first-class protocol knob. This module owns it:
a registry of :class:`WireCodec` objects, each declaring its payload buffer
lane (dtype + packed width), its per-message wire bytes, its encode/decode
functions, and whether the *sender* keeps error-feedback state.

Registered codecs (``WIRE_CODECS``; ``GossipLinearConfig.wire_dtype`` and
``gossip_merge``'s ``exchange_dtype`` accept any name):

* ``f32`` (alias ``None``) — full precision, 4 B/coefficient;
* ``bf16`` / ``f16`` — plain dtype cast, 2 B/coefficient;
* ``int8`` / ``int8_sr`` — per-message *affine* int8: each message carries
  an f16 (scale, zero-point) pair computed from its coefficient range;
  ``int8_sr`` rounds stochastically (unbiased) from a counter-based
  threefry key so runs stay bitwise-reproducible;
* ``int4`` / ``int4_ef`` — per-message *symmetric* int4: codes in
  [-7, 7] packed two per byte (0.5 B/coefficient), one f16 scale
  (``max|w| / INT4_QMAX``), no zero-point;
* ``ternary`` / ``ternary_ef`` — sign+scale codes in {-1, 0, +1} packed
  five per byte base-3 (0.2 B/coefficient), one f16 scale (``max|w|``).

The ``_ef`` variants enable **sender-side error feedback** (the EF-SGD
residual trick): the sender keeps a per-node f32 residual ``e``, transmits
``encode(w + e)`` and stores ``e' = (w + e) - decode(encode(w + e))`` — the
part the coarse code lost this cycle rides along on the *next* send instead
of being dropped. The residual updates only on cycles the node actually
transmits, which is what lets the sharded engine's sender-subset compaction
stay bitwise-equal to the reference engine. The accumulator is bounded by
one half quantization step of the running scale (property-tested), and the
merge-DAG averaging of the protocol absorbs the remaining bias — measured
per codec in ``BENCH_wire_quantization.json``.

Sub-byte codes change the *protocol state* (packed payload lanes, scale
lanes without zero-points, the EF residual lane), which is why the codec —
not a dtype string — is the unit the engines thread through ``SimState``,
the sharded carry, the ``shard_map`` specs and the Pallas kernels. Merge
arithmetic is always f32 regardless of codec.

This module is dependency-free within ``repro`` (pure jnp), so the
engines, the on-mesh optimizer and the Pallas kernels can all import from
it — the affine/symmetric quantization constants and pack/unpack helpers
live here and nowhere else.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# quantization constants — single home (satellite: the affine logic used to
# be copied across gossip_optimizer / gossip_cycle / gossip_merge)
# ---------------------------------------------------------------------------

# int8 payloads target [-126, 126]: one code of headroom keeps the clip at
# ±127 inert even after the scale is rounded to its f16 wire representation
INT8_QMAX = 126
# int4 codes target [-7, 7] (the symmetric subset of the two's-complement
# nibble range [-8, 7]); f16 scale rounding moves |w|/scale by < 0.1%, far
# inside the 1/14 relative headroom of round-to-nearest, so the clip at ±7
# never distorts
INT4_QMAX = 7
# codes packed per byte: two int4 nibbles, five base-3 trits (3^5 = 243)
INT4_GROUP = 2
TERNARY_GROUP = 5

_F16_MAX = float(jnp.finfo(jnp.float16).max)


def _sat_f16(v):
    """f16 cast that saturates instead of overflowing to inf — a divergent
    learner stays finite on the wire (grossly quantized) rather than
    flooding every downstream merge with NaNs."""
    return jnp.clip(v, -_F16_MAX, _F16_MAX).astype(jnp.float16)


# ---------------------------------------------------------------------------
# reproducible stochastic-rounding noise (threefry, op-exact vs jax.random)
# ---------------------------------------------------------------------------


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32 block cipher on uint32 arrays — op-for-op the unrolled
    lowering of JAX's ``threefry2x32_p`` (jax._src.prng), so the bits are
    identical to what ``jax.random`` produces for the same key/counters.
    Pure jnp integer ops: usable under jit, inside ``lax.scan`` bodies and
    inside Pallas kernels alike."""
    def rotl(v, r):
        return (v << jnp.uint32(r)) | (v >> jnp.uint32(32 - r))

    rot = ((13, 15, 26, 6), (17, 29, 16, 24))
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(0x1BD11BDA))
    x = [x0 + ks[0], x1 + ks[1]]
    for i in range(5):
        for r in rot[i % 2]:
            x[0] = x[0] + x[1]
            x[1] = rotl(x[1], r)
            x[1] = x[0] ^ x[1]
        x[0] = x[0] + ks[(i + 1) % 3]
        x[1] = x[1] + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x[0], x[1]


def uniform_at(k0, k1, p, size: int):
    """``jax.random.uniform(key, shape)`` evaluated at flat positions ``p``
    of an array with ``size`` total elements.

    Reproduces the original (non-partitionable) threefry counter scheme of
    ``jax._src.prng._threefry_random_bits_original`` bit for bit: the iota
    counter array of ``size`` elements is split in half (odd sizes pad one
    zero), element p < half is lane 0 of the block (p, half+p), element
    p >= half is lane 1 of the block (p-half, p) — each element evaluates
    exactly one 20-round block, with no cross-lane communication. The
    uint32 bits map to [0, 1) floats with the same mantissa-fill transform
    ``jax.random.uniform`` applies.

    This is what lets both the Pallas send kernel and the compacted
    send path regenerate the "int8_sr" noise for an arbitrary *subset* of
    messages without a dense (N, d) draw, bitwise-equal to the full-array
    ``jax.random.uniform`` the reference engine consumes."""
    if jax.config.jax_threefry_partitionable:
        # the partitionable PRNG uses a different counter scheme: this
        # helper would silently diverge from jax.random.uniform and break
        # the engines' bitwise int8_sr parity contract — fail loudly
        # instead (supporting it means implementing the partitionable
        # scheme here AND in the Pallas send kernel, both parity-tested)
        raise NotImplementedError(
            "uniform_at implements the original (non-partitionable) "
            "threefry counter scheme; run with "
            "jax_threefry_partitionable=False for the int8_sr wire dtype")
    half = (size + 1) // 2
    is_lo = p < half
    pair = p + half
    x0 = jnp.where(is_lo, p, p - half)
    # the odd-size zero pad sits at padded position `size`
    x1 = jnp.where(is_lo, jnp.where(pair < size, pair, 0), p)
    y0, y1 = threefry2x32(k0, k1, x0.astype(jnp.uint32),
                          x1.astype(jnp.uint32))
    bits = jnp.where(is_lo, y0, y1)
    fbits = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    return jax.lax.bitcast_convert_type(fbits, jnp.float32) - 1.0


def sr_noise_for_rows(key, rows, d: int, n_total: int):
    """The ``jax.random.uniform(key, (n_total, d))`` noise of a full-array
    "int8_sr" quantization, evaluated only at the given ``rows``:
    ``sr_noise_for_rows(key, rows, d, n)`` ==
    ``jax.random.uniform(key, (n, d))[rows]`` bitwise, at O(len(rows)·d)
    threefry work. ``key`` is a typed threefry key (the per-cycle
    ``k_recv`` slot)."""
    kd = jax.random.key_data(key).astype(jnp.uint32)
    p = rows[:, None] * d + jnp.arange(d, dtype=rows.dtype)[None, :]
    return uniform_at(kd[0], kd[1], p, n_total * d)


# ---------------------------------------------------------------------------
# affine int8 quantization (the int8 / int8_sr codecs; also the one
# implementation behind gossip_merge's int8 exchange path)
# ---------------------------------------------------------------------------


def quantize_wire(w, name, key=None, noise=None):
    """Per-message affine int8 quantization of a batch of models.

    ``w``: (..., d) f32 — each slice along the last axis is one transmitted
    model (one message). Returns ``(q, scale, zp)`` with ``q`` int8 of
    ``w.shape`` and ``scale``/``zp`` f16 of ``w.shape[:-1]`` — the f16
    values are exactly what rides the wire, and the SAME rounded values are
    used by the quantizer itself, so the round-trip error is bounded by one
    quantization step of the *transmitted* scale:

      |w - dequantize(q, scale, zp)| <= scale      (per coordinate)

    (<= scale/2 for round-to-nearest; stochastic rounding is unbiased but
    may land a full step away). ``zp`` is the f16-rounded range midpoint and
    ``scale`` covers the residual range ``max(hi-zp, zp-lo)`` over
    ``INT8_QMAX`` codes, so codes stay within ±127 even after f16 rounding —
    the defensive clip never distorts.

    ``name``: "int8" rounds to nearest (deterministic); "int8_sr" adds
    uniform [0, 1) noise before the floor — ``key`` (threefry) is required
    and makes the draw reproducible: both simulator engines feed the same
    per-cycle ``k_recv`` key here, keeping cross-engine parity bitwise.
    ``noise`` (optional, "int8_sr" only) supplies the uniform draw directly
    instead of ``key`` — the compacted send path passes
    :func:`sr_noise_for_rows` values so a subset quantization consumes
    exactly the noise the full-array draw would have given those rows.

    Precondition: coefficients are expected inside the f16-representable
    range (|w| ≲ 6.5e4 — far beyond any non-divergent linear model here;
    Pegasos is bounded by 1/sqrt(lam)). Outside it the f16 scale/zero-point
    SATURATE at the f16 max instead of overflowing to inf, so a divergent
    run stays finite on the wire (grossly quantized) rather than flooding
    every merge with NaNs."""
    w = w.astype(jnp.float32)
    lo = jnp.min(w, axis=-1)
    hi = jnp.max(w, axis=-1)
    zp = _sat_f16((hi + lo) * 0.5)
    zpf = zp.astype(jnp.float32)
    scale = _sat_f16(jnp.maximum(hi - zpf, zpf - lo) / INT8_QMAX)
    # guarded divisor: a constant message (hi == lo, scale 0) maps every
    # coordinate to code 0 and dequantizes to exactly zp
    sf = jnp.where(scale > 0, scale, jnp.float16(1)).astype(jnp.float32)
    u = (w - zpf[..., None]) / sf[..., None]
    if name == "int8_sr":
        if noise is None:
            if key is None:
                raise ValueError("int8_sr quantization needs a PRNG key")
            noise = jax.random.uniform(key, w.shape)
        u = jnp.floor(u + noise)
    else:
        u = jnp.round(u)
    q = jnp.clip(u, -127, 127).astype(jnp.int8)
    return q, scale, zp


def dequantize_wire(q, scale, zp):
    """Inverse of :func:`quantize_wire`: ``q * scale + zp`` in f32.

    The Pallas ``gossip_cycle`` kernel applies this same expression in-VMEM
    (same op order), so kernel and jnp paths agree bitwise."""
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            + zp.astype(jnp.float32)[..., None])


# ---------------------------------------------------------------------------
# sub-4-bit code packing (shared by the jnp codecs and the Pallas kernels —
# integer-exact, so every implementation that uses them agrees bitwise)
# ---------------------------------------------------------------------------


def pack_int4(q):
    """(..., d) int codes in [-8, 7] -> (..., ceil(d/2)) uint8.

    Two's-complement nibbles, low nibble = even coordinate; odd d pads one
    0 code into the final byte's high nibble."""
    d = q.shape[-1]
    pad = -d % INT4_GROUP
    qi = q.astype(jnp.int32)
    if pad:
        qi = jnp.concatenate(
            [qi, jnp.zeros(qi.shape[:-1] + (pad,), jnp.int32)], axis=-1)
    pairs = qi.reshape(qi.shape[:-1] + ((d + pad) // INT4_GROUP, INT4_GROUP))
    return ((pairs[..., 0] & 0xF)
            | ((pairs[..., 1] & 0xF) << 4)).astype(jnp.uint8)


def unpack_int4(b, d: int):
    """(..., P) uint8 -> (..., d) int32 sign-extended nibble codes.

    Exact inverse of :func:`pack_int4` on the first ``d`` coordinates
    (requires 2·P >= d)."""
    bi = b.astype(jnp.int32)
    nib = jnp.stack([bi & 0xF, (bi >> 4) & 0xF], axis=-1)
    nib = nib.reshape(b.shape[:-1] + (b.shape[-1] * INT4_GROUP,))[..., :d]
    return ((nib + 8) & 0xF) - 8


def pack_ternary(q):
    """(..., d) codes in {-1, 0, +1} -> (..., ceil(d/5)) uint8, base-3.

    Byte value = sum of (code+1)·3^k over the five trits it carries
    (0..242); pad trits are code 0 (digit 1), matching the Pallas send
    kernel's padded lanes byte for byte."""
    d = q.shape[-1]
    pad = -d % TERNARY_GROUP
    g = q.astype(jnp.int32) + 1
    if pad:
        g = jnp.concatenate(
            [g, jnp.ones(g.shape[:-1] + (pad,), jnp.int32)], axis=-1)
    g = g.reshape(g.shape[:-1] + ((d + pad) // TERNARY_GROUP, TERNARY_GROUP))
    b = g[..., 0]
    for k in range(1, TERNARY_GROUP):
        b = b + g[..., k] * (3 ** k)
    return b.astype(jnp.uint8)


def unpack_ternary(b, d: int):
    """(..., P) uint8 -> (..., d) int32 codes in {-1, 0, +1}.

    Exact inverse of :func:`pack_ternary` on the first ``d`` coordinates
    (requires 5·P >= d)."""
    bi = b.astype(jnp.int32)
    digs = jnp.stack([(bi // (3 ** k)) % 3 for k in range(TERNARY_GROUP)],
                     axis=-1)
    return digs.reshape(
        b.shape[:-1] + (b.shape[-1] * TERNARY_GROUP,))[..., :d] - 1


def symmetric_scale(w, qmax: int):
    """The shared scale rule of the packed symmetric codecs: one f16
    ``max|w| / qmax`` per message (saturating like the affine path), plus
    the zero-guarded f32 divisor. Returns ``(scale_f16, divisor_f32)``."""
    amax = jnp.max(jnp.abs(w), axis=-1)
    scale = _sat_f16(amax / qmax)
    sf = jnp.where(scale > 0, scale, jnp.float16(1)).astype(jnp.float32)
    return scale, sf


# ---------------------------------------------------------------------------
# codec objects
# ---------------------------------------------------------------------------


class WireCodec:
    """One wire representation of a transmitted model.

    Attributes (fixed per codec):

    * ``name`` — registry key (``GossipLinearConfig.wire_dtype`` value);
    * ``payload_dtype`` — storage dtype of the in-flight payload buffer;
    * ``bits_per_coeff`` — wire bits per model coefficient;
    * ``overhead_bytes`` — per-message metadata beyond the coefficients
      (f16 scale, optionally + f16 zero-point);
    * ``has_scale`` / ``has_zp`` — which metadata lanes the buffer carries
      (``quantized`` is an alias for ``has_scale``);
    * ``ef`` — sender-side error-feedback residual state ((N, d) f32 in
      ``SimState.ef`` / the sharded carry, updated on actual sends only);
    * ``stochastic`` — encode consumes a per-cycle PRNG key (``k_recv``).

    ``encode(w, key=, noise=)`` maps (..., d) f32 models to
    ``(payload, scale, zp)`` (``scale``/``zp`` are None for lanes the codec
    does not carry); ``decode(payload, scale, zp, d)`` inverts it to f32.
    Both are pure jnp and jit/scan/shard_map-safe; the Pallas kernels
    restate them op for op (pinned bitwise in tests)."""

    name: str
    payload_dtype = jnp.float32
    bits_per_coeff = 32
    overhead_bytes = 0
    has_scale = False
    has_zp = False
    ef = False
    stochastic = False

    def __init__(self, name: str):
        self.name = name

    @property
    def quantized(self) -> bool:
        return self.has_scale

    def payload_cols(self, d: int) -> int:
        """Last-axis width of the payload buffer for d-coefficient models."""
        return d

    def payload_bytes(self, d: int) -> int:
        """Wire bytes of the packed coefficients of one message."""
        return self.payload_cols(d) * jnp.dtype(self.payload_dtype).itemsize

    def encode(self, w, key=None, noise=None):
        raise NotImplementedError

    def decode(self, payload, scale, zp, d: int):
        raise NotImplementedError

    def roundtrip(self, w, key=None, noise=None):
        """decode(encode(w)) — the receiver's view of a transmitted model
        (what ``gossip_merge``'s exchange path averages against)."""
        payload, scale, zp = self.encode(w, key=key, noise=noise)
        return self.decode(payload, scale, zp, w.shape[-1])

    def __repr__(self):
        return f"<WireCodec {self.name}>"


class FloatCodec(WireCodec):
    """Plain dtype cast (f32 / bf16 / f16): no metadata, no state."""

    def __init__(self, name: str, dtype, bits: int):
        super().__init__(name)
        self.payload_dtype = dtype
        self.bits_per_coeff = bits

    def encode(self, w, key=None, noise=None):
        return w.astype(self.payload_dtype), None, None

    def decode(self, payload, scale, zp, d: int):
        return payload.astype(jnp.float32)


class AffineInt8Codec(WireCodec):
    """Per-message affine int8 (:func:`quantize_wire`): f16 scale +
    zero-point ride with every message; "int8_sr" rounds stochastically."""

    payload_dtype = jnp.int8
    bits_per_coeff = 8
    overhead_bytes = 4            # f16 scale + f16 zero-point
    has_scale = True
    has_zp = True

    def __init__(self, name: str, stochastic: bool):
        super().__init__(name)
        self.stochastic = stochastic

    def encode(self, w, key=None, noise=None):
        return quantize_wire(w, self.name, key=key, noise=noise)

    def decode(self, payload, scale, zp, d: int):
        return dequantize_wire(payload, scale, zp)


class PackedSymmetricCodec(WireCodec):
    """Sub-4-bit symmetric codes packed several per byte, one f16 scale
    per message, no zero-point. ``int4``/``int4_ef``: codes round(w/scale)
    in [-7, 7], two per byte. ``ternary``/``ternary_ef``: codes in
    {-1, 0, +1} (scale = max|w|), five per byte base-3 — sign+scale on the
    wire. Rounding is deterministic (round-to-nearest): the ``_ef``
    variants rely on the error-feedback residual, not on unbiased noise,
    to kill the quantization bias."""

    payload_dtype = jnp.uint8
    overhead_bytes = 2                      # f16 scale only
    has_scale = True

    def __init__(self, name: str, qmax: int, group: int, pack, unpack,
                 ef: bool):
        super().__init__(name)
        self.qmax = qmax
        self.group = group
        self._pack = pack
        self._unpack = unpack
        self.ef = ef
        self.bits_per_coeff = 8 / group     # 4 for int4, 1.6 for ternary

    def payload_cols(self, d: int) -> int:
        return -(-d // self.group)          # ceil(d / codes-per-byte)

    def quantize_codes(self, w):
        """(codes int32 in [-qmax, qmax], scale f16) before packing — the
        Pallas send kernel re-enters here on its padded block."""
        w = w.astype(jnp.float32)
        scale, sf = symmetric_scale(w, self.qmax)
        q = jnp.clip(jnp.round(w / sf[..., None]),
                     -self.qmax, self.qmax).astype(jnp.int32)
        return q, scale

    def encode(self, w, key=None, noise=None):
        q, scale = self.quantize_codes(w)
        return self._pack(q), scale, None

    def decode(self, payload, scale, zp, d: int):
        q = self._unpack(payload, d)
        return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

WIRE_CODECS: Dict[str, WireCodec] = {}


def _register(codec: WireCodec) -> WireCodec:
    assert codec.name not in WIRE_CODECS, codec.name
    WIRE_CODECS[codec.name] = codec
    return codec


_register(FloatCodec("f32", jnp.float32, 32))
_register(FloatCodec("bf16", jnp.bfloat16, 16))
_register(FloatCodec("f16", jnp.float16, 16))
_register(AffineInt8Codec("int8", stochastic=False))
_register(AffineInt8Codec("int8_sr", stochastic=True))
_register(PackedSymmetricCodec("int4", INT4_QMAX, INT4_GROUP,
                               pack_int4, unpack_int4, ef=False))
_register(PackedSymmetricCodec("int4_ef", INT4_QMAX, INT4_GROUP,
                               pack_int4, unpack_int4, ef=True))
_register(PackedSymmetricCodec("ternary", 1, TERNARY_GROUP,
                               pack_ternary, unpack_ternary, ef=False))
_register(PackedSymmetricCodec("ternary_ef", 1, TERNARY_GROUP,
                               pack_ternary, unpack_ternary, ef=True))


def get_codec(name: Optional[str]) -> WireCodec:
    """Wire-codec registry lookup; ``None``/``""`` alias the f32 codec."""
    if not name:
        return WIRE_CODECS["f32"]
    try:
        return WIRE_CODECS[name]
    except KeyError:
        raise ValueError(f"unknown wire dtype {name!r} "
                         f"(expected one of {sorted(WIRE_CODECS)})") from None


def deterministic_codec(codec: WireCodec) -> WireCodec:
    """The round-to-nearest sibling of a stochastic codec (int8_sr ->
    int8); identity otherwise. The on-mesh optimizer path uses it: a train
    step threads no per-step key for SR noise."""
    if not codec.stochastic:
        return codec
    base = codec.name.replace("_sr", "")
    return WIRE_CODECS[base]


# ---------------------------------------------------------------------------
# legacy helpers (the pre-registry WIRE_DTYPES API, kept for callers/tests)
# ---------------------------------------------------------------------------

WIRE_DTYPES = {name: c.payload_dtype for name, c in WIRE_CODECS.items()}

# wire-dtype names that use per-message affine int8 quantization
INT8_WIRE_DTYPES = frozenset({"int8", "int8_sr"})


def resolve_wire_dtype(name):
    """Wire-dtype name -> payload storage dtype, or None for full precision
    (``None``/``""``/``"f32"``). Packed sub-4-bit codecs store multiple
    codes per uint8 element — per-coefficient accounting must go through
    ``get_codec(name).payload_bytes(d)``, not this dtype's itemsize."""
    if not name or name == "f32":
        return None
    return get_codec(name).payload_dtype


def is_quantized_wire(name) -> bool:
    """True when the codec carries a per-message scale (int8 and below)."""
    return bool(name) and get_codec(name).quantized


def is_stochastic_wire(name) -> bool:
    """True when the wire codec rounds stochastically (needs a PRNG key)."""
    return bool(name) and get_codec(name).stochastic


def wire_itemsize(name) -> int:
    """Bytes per payload *storage element* for a wire-dtype name (1 for
    every sub-byte codec — a uint8 element packs ``group`` codes)."""
    dt = resolve_wire_dtype(name)
    return 4 if dt is None else jnp.dtype(dt).itemsize


def wire_overhead_bytes(name) -> int:
    """Per-message metadata bytes beyond the coefficients: f16 scale +
    zero-point for the affine int8 codecs, f16 scale for the packed
    symmetric codecs, nothing for float casts."""
    return get_codec(name).overhead_bytes if name else 0
