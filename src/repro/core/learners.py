"""Online learners (Algorithm 3): Pegasos, Adaline, logistic regression.

A linear model is the pair ``(w, t)`` — weight vector and update counter —
exactly the paper's model record. All update rules are written point-wise
over a *population*: ``w`` may be ``(d,)`` or ``(N, d)`` with matching ``t``;
everything broadcasts, so the whole network updates in one fused XLA op
(and the Pallas kernel in ``repro.kernels`` implements the fused
merge+update hot path for TPU).

Labels are in {-1, +1}. The bias term is handled the way the paper's Adaline
section does — by ignoring it (a constant-1 feature can be appended by the
data layer instead).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LinearModel(NamedTuple):
    """The message payload of gossip learning: one linear model."""

    w: jnp.ndarray          # (d,) or (N, d)
    t: jnp.ndarray          # () or (N,) int32 update counter


def init_model(d: int, n: int | None = None) -> LinearModel:
    """INITMODEL (Algorithm 3): w = 0, t = 0."""
    if n is None:
        return LinearModel(jnp.zeros((d,), jnp.float32), jnp.zeros((), jnp.int32))
    return LinearModel(jnp.zeros((n, d), jnp.float32), jnp.zeros((n,), jnp.int32))


# ---------------------------------------------------------------------------
# update rules
# ---------------------------------------------------------------------------


def pegasos_update(m: LinearModel, x, y, lam: float) -> LinearModel:
    """UPDATEPEGASOS (Algorithm 3, lines 1–10): primal SVM subgradient step.

    t <- t+1; eta = 1/(lam*t);
    margin violation:  w <- (1 - eta*lam) w + eta*y*x
    otherwise:         w <- (1 - eta*lam) w
    """
    t = m.t + 1
    eta = 1.0 / (lam * t.astype(jnp.float32))
    margin = y * jnp.sum(m.w * x, axis=-1)
    decay = (1.0 - eta * lam)
    if m.w.ndim == 2:
        decay = decay[:, None]
        eta = eta[:, None]
        hinge = (margin < 1.0)[:, None]
        yx = y[:, None] * x if jnp.ndim(y) else y * x
    else:
        hinge = margin < 1.0
        yx = y * x
    w = decay * m.w + jnp.where(hinge, eta * yx, 0.0)
    return LinearModel(w, t)


def adaline_update(m: LinearModel, x, y, eta: float) -> LinearModel:
    """UPDATEADALINE (Algorithm 3, lines 12–15): w += eta (y - <w,x>) x.

    Linear activation => merge/update commute exactly (Eq. 8)."""
    err = (y - jnp.sum(m.w * x, axis=-1))
    if m.w.ndim == 2:
        err = err[:, None]
        yx = x
    else:
        yx = x
    return LinearModel(m.w + eta * err * yx, m.t + 1)


def logistic_update(m: LinearModel, x, y, eta: float, lam: float = 0.0) -> LinearModel:
    """Logistic-loss SGD — a third online learner demonstrating the
    'any online algorithm' genericity claim of Section IV."""
    t = m.t + 1
    z = y * jnp.sum(m.w * x, axis=-1)
    g = -y * jax.nn.sigmoid(-z)             # dL/dscore * y-sign folded
    if m.w.ndim == 2:
        g = g[:, None]
    w = (1.0 - eta * lam) * m.w - eta * g * x
    return LinearModel(w, t)


def make_update(learner: str, *, lam: float = 1e-4, eta: float = 0.01):
    if learner == "pegasos":
        return lambda m, x, y: pegasos_update(m, x, y, lam)
    if learner == "adaline":
        return lambda m, x, y: adaline_update(m, x, y, eta)
    if learner == "logistic":
        return lambda m, x, y: logistic_update(m, x, y, eta, lam)
    raise ValueError(f"unknown learner {learner!r}")


def predict(w, x):
    """PREDICT (Algorithm 4): sign of the inner product."""
    return jnp.sign(jnp.sum(w * x, axis=-1))
