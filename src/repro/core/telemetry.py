"""Protocol telemetry: metric streams, host spans, latency histograms.

The paper's experimental story (Figs. 1-7) is entirely about *observing* a
running protocol — error curves, message economies, convergence under
churn. This module is the one home for that observability, with three
faces:

* **Per-cycle metric streams** — ``METRIC_STREAMS`` is a registered schema
  (same both-ways docs-gate philosophy as ``WIRE_CODECS``/``FAULT_MODELS``:
  the table in docs/OBSERVABILITY.md is cross-checked against this registry
  by ``tools/check_docs.py``) of the series both engines emit identically:
  the message economy (the PR 1 balance invariant, continuously emitted),
  wire bytes, receiver occupancy, fault counters, EF residual RMS and the
  online fraction. Because the reference engine and all three sharded
  packings emit the same numbers, the metric stream itself is a
  cross-engine parity surface (tests/test_telemetry.py).
* **Host spans** — ``telemetry.span("route_chunk")`` wraps the control
  plane, scan dispatch, snapshot adoption and serving batch assembly with
  wall-clock timing plus a jit compile-count delta per span (via the
  engines' compile caches — ``retrace_counts()`` and ``_cache_size()``),
  exported as Chrome trace-event JSON (:meth:`Telemetry.export_chrome_trace`)
  viewable in Perfetto and summarized by ``tools/trace_report.py``.
* **Latency histograms** — :class:`LatencyHistogram` is the fixed-bucket
  log-scale histogram behind every latency percentile in the repo
  (``GossipServer`` batch latency, ``BENCH_serving.json`` p50/p90/p99/p999),
  replacing ad-hoc per-call percentile math.

The hard contract (docs/CONTRACTS.md): **telemetry is a pure read**.
``telemetry=None`` (the default everywhere) compiles to the exact pre-
telemetry engines — the armed collection paths are statically gated, the
same mechanism as the fault machinery — and an armed :class:`Telemetry`
must leave error curves and all protocol state bitwise identical on both
engines. Telemetry never touches ``jax.random``: spans and histograms use
``time.perf_counter`` and streams are integer/float *reads* of state the
engines already computed, so the pinned threefry chain
(tools/lint/rng_allowlist.py) cannot shift.
"""
from __future__ import annotations

import json
import sys
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# metric-stream registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MetricStream:
    """Schema entry for one registered per-run metric series.

    ``cadence`` is "cycle" (one value per gossip cycle) or "eval" (one
    value per eval point). ``parity`` marks the stream as part of the
    cross-engine parity surface: reference and sharded runs at a matched
    seed must emit it bitwise-identically (integers exactly; floats via
    identical op sequences on bitwise-equal state)."""
    name: str
    cadence: str            # "cycle" | "eval"
    dtype: str              # "int" | "float"
    parity: bool
    description: str


def _stream(name, cadence, dtype, parity, description):
    return name, MetricStream(name, cadence, dtype, parity, description)


# The registered schema. Every stream is emitted by BOTH engines (and by
# every sharded packing) when a run is armed; docs/OBSERVABILITY.md mirrors
# this table and tools/check_docs.py fails when either side drifts.
METRIC_STREAMS: Dict[str, MetricStream] = dict([
    _stream("sent", "cycle", "int", True,
            "messages entering the network this cycle (send_ok senders)"),
    _stream("delivered", "cycle", "int", True,
            "messages accepted by an online node within the K rounds"),
    _stream("lost", "cycle", "int", True,
            "messages due this cycle whose destination was offline"),
    _stream("overflow", "cycle", "int", True,
            "arrivals beyond the K winner rounds (truncated receives)"),
    _stream("in_flight", "cycle", "int", True,
            "messages still in the delay buffer after this cycle "
            "(cumulative sent - delivered - lost - overflow; the PR 1 "
            "balance invariant, continuously emitted)"),
    _stream("wire_bytes", "cycle", "int", True,
            "bytes put on the wire this cycle (sent x per-message bytes "
            "of the run's wire codec)"),
    _stream("recv_nodes", "cycle", "int", True,
            "nodes receiving at least one message (round-1 winners; the "
            "numerator of the router's compaction occupancy)"),
    _stream("multi_nodes", "cycle", "int", True,
            "nodes receiving in round 2 or later (the compact packing's "
            "subset)"),
    _stream("online_nodes", "cycle", "int", True,
            "nodes online this cycle (the churn trace row sum)"),
    _stream("corrupted", "cycle", "int", True,
            "Byzantine sends this cycle (fault model armed and send_ok)"),
    _stream("gated", "cycle", "int", True,
            "receives rejected by the defense screen this cycle"),
    _stream("clipped", "cycle", "int", True,
            "receives rescaled by norm_clip this cycle"),
    _stream("ef_residual_rms", "eval", "float", True,
            "RMS per-node L2 norm of the error-feedback residual at each "
            "eval point (0.0 for codecs without EF state)"),
])


# ---------------------------------------------------------------------------
# host spans
# ---------------------------------------------------------------------------

# span tracks become named Perfetto threads; the index is the trace tid
TRACKS: Tuple[str, ...] = ("host", "control", "device", "serving", "eval")

# span naming convention (docs/OBSERVABILITY.md): snake_case verbs naming
# the phase, stable across PRs so trace diffs stay meaningful
SPAN_NAMES = {
    "route_chunk":    "control — host winner routing for one chunk",
    "stage_draws":    "control — upfront device draws for all chunks",
    "chunk_dispatch": "device — dispatch one data-plane scan chunk",
    "cycle":          "device — one reference-engine cycle (dispatch+sync)",
    "eval":           "eval — population error at an eval point",
    "collect_results": "device — drain deferred eval results (sync point)",
    "snapshot":       "serving — snapshot build + serve_hook call",
    "snapshot_adopt": "serving — GossipServer adopts a snapshot (sync)",
    "serve_batch":    "serving — assemble + answer one query batch",
}


def compile_cache_sizes() -> int:
    """Total jit compile-cache entries across the repo's hot-path fns.

    The per-span delta of this number is the span's "compiles" count —
    the same counters ``tools/lint/retrace_guard.py`` budgets. Reads via
    ``sys.modules`` so telemetry never forces an engine import."""
    total = 0
    sim = sys.modules.get("repro.core.simulation")
    if sim is not None:
        total += sim.simulate_cycle._cache_size()
        total += sim._eval._cache_size()
    sh = sys.modules.get("repro.core.sharded_engine")
    if sh is not None:
        total += sum(sh.retrace_counts().values())
    srv = sys.modules.get("repro.core.serving")
    if srv is not None:
        total += (srv.serve_fresh._cache_size()
                  + srv.serve_voted._cache_size()
                  + srv.serve_voted_kernel._cache_size())
    return total


@dataclass
class Span:
    """One finished host span (relative perf_counter seconds)."""
    name: str
    track: str
    t0: float
    t1: float
    compiles: int
    args: Dict[str, object]

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0


class _SpanCtx:
    __slots__ = ("tel", "name", "track", "args", "_t0", "_c0")

    def __init__(self, tel: "Telemetry", name: str, track: str, args):
        self.tel, self.name, self.track, self.args = tel, name, track, args

    def __enter__(self):
        self._c0 = compile_cache_sizes()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.tel.spans.append(Span(
            self.name, self.track, self._t0 - self.tel._origin,
            t1 - self.tel._origin, compile_cache_sizes() - self._c0,
            self.args))
        return False


def maybe_span(tel: Optional["Telemetry"], name: str, track: str = "host",
               **args):
    """``tel.span(...)`` when armed, a free ``nullcontext`` when not — the
    one-liner the engines use so the unarmed hot path stays untouched."""
    if tel is None:
        return nullcontext()
    return tel.span(name, track=track, **args)


# ---------------------------------------------------------------------------
# latency histogram
# ---------------------------------------------------------------------------


class LatencyHistogram:
    """Fixed-bucket log-scale latency histogram (seconds).

    64 buckets, 8 per decade from 1 microsecond to 100 seconds, plus an
    underflow and an overflow bucket — the same fixed edges everywhere, so
    histograms from different runs/servers merge exactly (bucket-wise
    addition) and bucket dumps in BENCH_serving.json stay comparable
    across PRs. Percentiles interpolate linearly inside the owning bucket
    and are clamped to the exact observed [min, max], so single-sample and
    constant-sample histograms report exact values."""

    EDGES = np.logspace(-6.0, 2.0, 8 * 8 + 1)     # 65 edges, 64 buckets

    def __init__(self):
        self.counts = np.zeros(self.EDGES.size + 1, np.int64)
        self.count = 0
        self.total = 0.0
        self.min_value = float("inf")
        self.max_value = 0.0

    def record(self, seconds: float) -> None:
        self.record_many([seconds])

    def record_many(self, seconds) -> None:
        v = np.asarray(seconds, np.float64).ravel()
        if v.size == 0:
            return
        idx = np.searchsorted(self.EDGES, v, side="right")
        np.add.at(self.counts, idx, 1)
        self.count += int(v.size)
        self.total += float(v.sum())
        self.min_value = min(self.min_value, float(v.min()))
        self.max_value = max(self.max_value, float(v.max()))

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.min_value = min(self.min_value, other.min_value)
        self.max_value = max(self.max_value, other.max_value)
        return self

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100] -> seconds (0.0 on an empty histogram)."""
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.EDGES[i - 1] if i > 0 else self.min_value
                hi = (self.EDGES[i] if i < self.EDGES.size
                      else self.max_value)
                frac = (target - cum) / c
                v = lo + frac * (hi - lo)
                return float(min(max(v, self.min_value), self.max_value))
            cum += c
        return self.max_value

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p90(self) -> float:
        return self.percentile(90.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def p999(self) -> float:
        return self.percentile(99.9)

    def to_dict(self) -> dict:
        """JSON-ready dump: only the occupied buckets (sparse — the fixed
        edge grid is implied by ``bucket_le``, each bucket's upper edge)."""
        nz = np.nonzero(self.counts)[0]
        return dict(
            count=self.count,
            mean_s=self.mean,
            min_s=self.min_value if self.count else 0.0,
            max_s=self.max_value,
            p50_s=self.p50, p90_s=self.p90, p99_s=self.p99,
            p999_s=self.p999,
            bucket_le=[(float(self.EDGES[i]) if i < self.EDGES.size
                        else float("inf")) for i in nz],
            bucket_counts=[int(self.counts[i]) for i in nz],
        )


# ---------------------------------------------------------------------------
# shared wall-clock helpers (the one home for bench timing)
# ---------------------------------------------------------------------------


class Timer:
    """Context-manager wall clock; ``.s`` holds elapsed seconds.

    The single Timer the benchmarks use (re-exported by
    ``benchmarks/common.py``) — perf_counter-based, monotonic."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
        return False


def best_of(fn, repeats: int = 2):
    """Min-time estimator: call ``fn()`` ``repeats`` times.

    Returns ``(best_seconds, all_seconds, last_result)``. Shared-container
    noise is strictly additive, so the minimum is the estimator every
    bench uses (previously copy-pasted per bench as a secs list + min)."""
    secs: List[float] = []
    result = None
    for _ in range(max(repeats, 1)):
        with Timer() as t:
            result = fn()
        secs.append(t.s)
    return min(secs), secs, result


# ---------------------------------------------------------------------------
# the Telemetry object
# ---------------------------------------------------------------------------


class Telemetry:
    """Armed observability for one (or several back-to-back) runs.

    Pass as ``run_simulation(..., telemetry=tel)`` (either engine) and/or
    ``GossipServer(telemetry=tel)``. Collects the registered metric
    streams, host spans and latency histograms; export with
    :meth:`export_chrome_trace`, summarize with :meth:`phase_report` or
    ``tools/trace_report.py`` on the exported file.

    Arming one Telemetry across several sequential runs is supported —
    spans share one wall-clock origin and stream segments concatenate in
    run order (each run's ``in_flight`` balance restarts from zero at its
    own first cycle)."""

    def __init__(self, label: str = ""):
        self.label = label
        self.streams: Dict[str, List] = {n: [] for n in METRIC_STREAMS}
        self.spans: List[Span] = []
        self.histograms: Dict[str, LatencyHistogram] = {}
        self.annotations: Dict[str, object] = {}
        self._origin = time.perf_counter()

    # ------------------------------------------------------------- streams
    def emit(self, name: str, values) -> None:
        """Append value(s) to a registered stream (scalar or sequence)."""
        if name not in METRIC_STREAMS:
            raise KeyError(f"unregistered metric stream {name!r} "
                           f"(registered: {sorted(METRIC_STREAMS)})")
        if np.ndim(values) == 0:
            self.streams[name].append(
                float(values) if METRIC_STREAMS[name].dtype == "float"
                else int(values))
        else:
            kind = METRIC_STREAMS[name].dtype
            self.streams[name].extend(
                float(v) if kind == "float" else int(v) for v in values)

    def emit_row(self, **values) -> None:
        """Emit one value into several streams at once."""
        for name, v in values.items():
            self.emit(name, v)

    def stream_array(self, name: str) -> np.ndarray:
        kind = METRIC_STREAMS[name].dtype
        return np.asarray(self.streams[name],
                          np.float64 if kind == "float" else np.int64)

    # --------------------------------------------------------------- spans
    def span(self, name: str, track: str = "host", **args) -> _SpanCtx:
        if track not in TRACKS:
            raise ValueError(f"unknown span track {track!r} "
                             f"(expected one of {TRACKS})")
        return _SpanCtx(self, name, track, args)

    def histogram(self, name: str) -> LatencyHistogram:
        return self.histograms.setdefault(name, LatencyHistogram())

    # ------------------------------------------------------------ reports
    def phase_seconds(self) -> Dict[str, float]:
        """Total span seconds per span name (the per-phase summary)."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.seconds
        return out

    def compile_total(self) -> int:
        return sum(s.compiles for s in self.spans)

    def wall_seconds(self) -> float:
        if not self.spans:
            return 0.0
        return (max(s.t1 for s in self.spans)
                - min(s.t0 for s in self.spans))

    def phase_report(self) -> str:
        """Printable per-phase table (what the ``--trace`` example flags
        show; the standalone equivalent over an exported file is
        ``tools/trace_report.py``)."""
        wall = self.wall_seconds()
        lines = [f"telemetry: {len(self.spans)} spans, "
                 f"{self.compile_total()} jit compiles, "
                 f"{wall:.3f}s spanned wall clock"]
        counts: Dict[str, int] = {}
        compiles: Dict[str, int] = {}
        for s in self.spans:
            counts[s.name] = counts.get(s.name, 0) + 1
            compiles[s.name] = compiles.get(s.name, 0) + s.compiles
        for name, secs in sorted(self.phase_seconds().items(),
                                 key=lambda kv: -kv[1]):
            pct = 100.0 * secs / wall if wall > 0 else 0.0
            lines.append(f"  {name:<16} {secs:>9.3f}s {pct:>5.1f}%  "
                         f"x{counts[name]:<5d} compiles={compiles[name]}")
        sent = self.stream_array("sent")
        wb = self.stream_array("wire_bytes")
        if sent.size:
            lines.append(f"  streams: {sent.size} cycles, "
                         f"{sent.mean():,.0f} msgs/cycle sent, "
                         f"{wb.mean():,.0f} wire B/cycle")
        for name, h in sorted(self.histograms.items()):
            if h.count:
                lines.append(
                    f"  hist {name}: n={h.count} p50={h.p50 * 1e3:.3f}ms "
                    f"p99={h.p99 * 1e3:.3f}ms p999={h.p999 * 1e3:.3f}ms")
        return "\n".join(lines)

    # ------------------------------------------------------- chrome export
    def export_chrome_trace(self, path) -> Path:
        """Write Chrome trace-event JSON (the ``chrome://tracing`` /
        Perfetto "JSON" flavor): one complete ("X") event per span on a
        named thread per track, an instant event per span that triggered
        jit compiles, and the per-cycle metric streams as counter ("C")
        events on a synthetic pid=1 timeline where 1 cycle == 1
        microsecond (protocol time, not wall time — labeled as such).
        Streams, histograms and annotations ride in ``otherData`` so
        ``tools/trace_report.py`` can rebuild the full summary from the
        file alone."""
        events: List[dict] = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": f"gossip host{' ' + self.label if self.label else ''}"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "protocol streams (1 cycle = 1 us)"}},
        ]
        for tid, track in enumerate(TRACKS):
            events.append({"ph": "M", "pid": 0, "tid": tid,
                           "name": "thread_name", "args": {"name": track}})
        for s in self.spans:
            tid = TRACKS.index(s.track)
            args = {k: (v if isinstance(v, (int, float, str, bool))
                        else str(v)) for k, v in s.args.items()}
            args["compiles"] = s.compiles
            events.append({"ph": "X", "pid": 0, "tid": tid, "name": s.name,
                           "ts": s.t0 * 1e6, "dur": s.seconds * 1e6,
                           "args": args, "cat": s.track})
            if s.compiles:
                events.append({"ph": "i", "pid": 0, "tid": tid,
                               "name": f"jit compile x{s.compiles}",
                               "ts": s.t0 * 1e6, "s": "t",
                               "cat": "compile"})
        for name, spec in METRIC_STREAMS.items():
            if spec.cadence != "cycle":
                continue
            vals = self.streams[name]
            for c, v in enumerate(vals):
                events.append({"ph": "C", "pid": 1, "tid": 0, "name": name,
                               "ts": float(c), "args": {name: v}})
        payload = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "label": self.label,
                "annotations": self.annotations,
                "streams": {n: self.streams[n] for n in METRIC_STREAMS},
                "histograms": {n: h.to_dict()
                               for n, h in self.histograms.items()},
                "compile_total": self.compile_total(),
            },
        }
        fp = Path(path)
        fp.write_text(json.dumps(payload) + "\n")
        return fp
