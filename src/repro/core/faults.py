"""Adversarial-node fault injection + robust merge defenses.

The paper's failure model is *honest*: messages vanish (drop), arrive late
(delay) or find their destination offline (churn) — they never lie. This
module adds the Byzantine axis the ROADMAP calls for: a seed-chosen subset
of nodes corrupts every model it transmits, and the merge path may defend.

Three pieces, mirroring the ``WIRE_CODECS`` registry pattern:

* ``FAULT_MODELS`` — named send-side corruptions. The *model-kind* faults
  (``sign_flip``, ``amplify``, ``zero``, ``random_payload``,
  ``stale_replay``) rewrite the transmitted model BEFORE the wire encode
  (a Byzantine node lies about its weights, then encodes the lie honestly);
  the *wire-kind* fault (``bitflip``) corrupts the encoded payload bytes
  AFTER ``WireCodec.encode`` — an honest sender behind a flaky link —
  exercising decode robustness for every registered codec.
* ``DEFENSES`` — receive-side payload screens applied per merge round,
  against the receiver's *current* ``lastModel`` (the Algorithm-1 chain
  ``lastModel <- m`` makes round k's defense depend on round k-1's
  accepted message, so the defense runs inside the K-round apply loop of
  every engine path, including the Pallas kernel). ``norm_clip`` rescales
  an oversized payload's L2 norm down to a multiple of the receiver's own;
  ``cosine_gate`` rejects payloads anti-aligned with the local model. Both
  reject non-finite payloads (the ``bitflip`` fault on float wire codecs).
* the ``k_fault`` key contract — fault draws ride a key derived by
  ``jax.random.fold_in`` from the per-cycle key (``fault_key``). fold_in
  derives without consuming from the parent counter, so the pinned
  ``split(key, 4)`` sequence of docs/CONTRACTS.md — and therefore every
  fault-free run — stays bitwise identical to the pre-fault engines.

Cross-engine bitwise parity: the subset variants (``rows=`` arguments)
regenerate exactly the dense draws at the given global rows via
``sr_noise_for_rows`` (the mechanism proven by the "int8_sr" compacted
send path), so the sharded engine's sender-subset ``compact_all`` packing
corrupts bit-for-bit like the reference engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wire_codec import sr_noise_for_rows

# ``fold_in`` tag of the per-cycle fault key (k_fault): an arbitrary fixed
# constant, pinned forever — changing it changes every faulty run's draws
FAULT_FOLD = 0x0FA17
# host-side stream tag of the Byzantine subset draw: a SEPARATE
# np.random stream from ``_host_scenario``'s (churn trace + eval subset),
# so enabling faults cannot shift the eval-node draw of a fault-free run
BYZANTINE_STREAM_TAG = 0xB12A

SIGN_FLIP_GAMMA = 4.0     # sign_flip transmits -gamma * w: a *scaled* sign
#                           reversal (gradient-reversal attack). The scaling
#                           is deliberate: a pure -w preserves the norm, so
#                           no norm screen could ever catch it — the
#                           amplified variant is both the stronger attack
#                           and the one norm_clip can provably bound.
AMPLIFY_GAMMA = 8.0       # amplify transmits +gamma * w

NORM_CLIP_MULT = 2.0      # clip ||msg|| to MULT * ||recv|| ...
NORM_CLIP_FLOOR = 1.0     # ... but never below FLOOR (the zero-init phase
#                           has ||recv|| = 0; a floor keeps honest early
#                           messages flowing instead of clipping them away)
COSINE_GATE_THRESHOLD = -0.2   # reject when cos(msg, recv) < threshold
COSINE_GATE_MIN_NORM = 1e-3    # ... but only once ||recv|| is established

DEFENSES = ("none", "norm_clip", "cosine_gate")


@dataclass(frozen=True)
class FaultModel:
    """One registered adversarial behavior.

    ``kind`` places the corruption on the send path: ``"model"`` faults
    rewrite ``(send_w, send_t)`` before the wire encode, ``"wire"`` faults
    rewrite the encoded payload bytes after it."""
    name: str
    kind: str                 # "model" | "wire"
    description: str


FAULT_MODELS: Dict[str, FaultModel] = {}


def _register(fault: FaultModel) -> FaultModel:
    assert fault.name not in FAULT_MODELS, fault.name
    assert fault.kind in ("model", "wire"), fault.kind
    FAULT_MODELS[fault.name] = fault
    return fault


_register(FaultModel("sign_flip", "model",
                     f"transmit -{SIGN_FLIP_GAMMA:g}*w (scaled sign "
                     "reversal / gradient-reversal attack)"))
_register(FaultModel("amplify", "model",
                     f"transmit {AMPLIFY_GAMMA:g}*w (model amplification)"))
_register(FaultModel("zero", "model",
                     "transmit the zero model (knowledge erasure)"))
_register(FaultModel("random_payload", "model",
                     "transmit uniform noise at the sender's own "
                     "coefficient scale"))
_register(FaultModel("stale_replay", "model",
                     "retransmit the node's oldest cached model "
                     "(tau ~ cache_size receives ago)"))
_register(FaultModel("bitflip", "wire",
                     "flip one uniform random bit of the encoded wire "
                     "payload (honest fault, post-encode)"))


def get_fault(name: Optional[str]) -> Optional[FaultModel]:
    """Resolve a fault-model name; ``None``/"" = no fault injection."""
    if name is None or name == "":
        return None
    try:
        return FAULT_MODELS[name]
    except KeyError:
        raise ValueError(f"unknown fault model {name!r} "
                         f"(expected one of {sorted(FAULT_MODELS)})"
                         ) from None


def check_defense(name: str) -> str:
    if name not in DEFENSES:
        raise ValueError(f"unknown defense {name!r} "
                         f"(expected one of {list(DEFENSES)})")
    return name


def byzantine_mask(seed: int, n: int, frac: float) -> np.ndarray:
    """The static per-run Byzantine node subset: ``round(frac * n)`` nodes
    chosen without replacement from a host stream keyed by
    ``(seed, BYZANTINE_STREAM_TAG)`` — deliberately NOT the
    ``_host_scenario`` stream, whose churn-trace/eval-subset draws must
    not shift when faults turn on. Shared by both engines."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"byzantine_frac must be in [0, 1], got {frac}")
    mask = np.zeros(n, bool)
    k = int(round(frac * n))
    if k:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, BYZANTINE_STREAM_TAG]))
        mask[rng.choice(n, size=k, replace=False)] = True
    return mask


def fault_key(key):
    """The per-cycle fault key: ``fold_in`` DERIVES a new key from the
    cycle key without consuming from the pinned ``split(key, 4)`` draw
    sequence — the k_fault contract of docs/CONTRACTS.md that keeps
    fault-free runs bitwise identical to the pre-fault engines."""
    return jax.random.fold_in(key, FAULT_FOLD)


def corrupt_model(fault: FaultModel, byz, key, w, t, old_w=None, old_t=None,
                  rows=None, n_total: Optional[int] = None):
    """Apply a model-kind fault on the Byzantine rows of a send batch.

    ``w``: (m, d) f32 models about to be transmitted; ``t``: (m,) int32
    counters; ``byz``: (m,) bool. ``old_w``/``old_t`` are the stale models
    (``cache.cache_oldest``), required by ``stale_replay`` only. ``key`` is
    the per-cycle ``fault_key``; ``random_payload`` draws its noise from it
    — dense callers (m == n_total) leave ``rows=None`` and draw the full
    ``(n_total, d)`` uniform block, subset callers pass the global row ids
    so ``sr_noise_for_rows`` regenerates the identical values at those
    positions (bitwise — the compact_all parity mechanism)."""
    name = fault.name
    if name == "sign_flip":
        cw, ct = -SIGN_FLIP_GAMMA * w, t
    elif name == "amplify":
        cw, ct = AMPLIFY_GAMMA * w, t
    elif name == "zero":
        cw, ct = jnp.zeros_like(w), t
    elif name == "random_payload":
        scale = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
        if rows is None:
            u = jax.random.uniform(key, w.shape)
        else:
            u = sr_noise_for_rows(key, rows, w.shape[-1], n_total)
        cw, ct = (2.0 * u - 1.0) * scale, t
    elif name == "stale_replay":
        cw, ct = old_w, old_t
    else:
        raise ValueError(f"{name!r} is not a model-kind fault")
    return (jnp.where(byz[:, None], cw, w), jnp.where(byz, ct, t))


def bitflip_payload(byz, key, payload, rows=None,
                    n_total: Optional[int] = None):
    """Flip ONE uniformly drawn bit in each Byzantine row of an encoded
    payload block — wire-level corruption applied after
    ``WireCodec.encode`` (the sender's EF residual, computed from the
    pre-flip bytes, stays honest). Works for every registered codec's
    payload dtype by bitcasting to the matching unsigned integer lane.

    The bit position comes from one uniform per message; dense callers
    draw ``uniform(key, (n_total, 1))``, subset callers regenerate the
    same values at their global ``rows`` via ``sr_noise_for_rows`` —
    positionally bitwise-equal, like the "int8_sr" compacted send."""
    m, p = payload.shape
    itemsize = jnp.dtype(payload.dtype).itemsize
    nbits = p * itemsize * 8
    if rows is None:
        u = jax.random.uniform(key, (m, 1))[:, 0]
    else:
        u = sr_noise_for_rows(key, rows, 1, n_total)[:, 0]
    bit = jnp.minimum((u * nbits).astype(jnp.uint32), nbits - 1)
    uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[itemsize]
    col = (bit // (itemsize * 8)).astype(jnp.int32)
    pos = bit % (itemsize * 8)
    lane = jnp.arange(p, dtype=jnp.int32)[None, :]
    flip = jnp.where(lane == col[:, None],
                     jnp.left_shift(jnp.uint32(1), pos)[:, None],
                     jnp.uint32(0)).astype(uint)
    raw = jax.lax.bitcast_convert_type(payload, uint)
    flipped = jax.lax.bitcast_convert_type(raw ^ flip, payload.dtype)
    return jnp.where(byz[:, None], flipped, payload)


def apply_defense(defense: str, msg_w, valid, recv_w, real=None):
    """Screen one receive round's payloads against the receiver's state.

    ``msg_w``: (m, d) decoded f32 payloads; ``valid``: (m,) bool;
    ``recv_w``: (m, d) the receiver's CURRENT lastModel (the round chain's
    ``prev``). ``real`` (optional, (m, d) bool) masks padded lanes out of
    the reductions — the Pallas kernel's padded-width contract; quantized
    decodes leave finite garbage in pad lanes, and zero-masking them keeps
    the in-kernel sums bitwise equal to the unpadded jnp sums (the same
    precedent as the ``_pegasos`` margin reduction).

    Returns ``(msg_w, valid, gated, clipped)``: the (possibly rescaled)
    payloads, the surviving-valid mask, and per-node bool indicators of a
    rejected (``gated``) / rescaled (``clipped``) message. ``"none"`` is a
    static no-op so undefended traces stay structurally identical."""
    if defense == "none":
        zeros = jnp.zeros(valid.shape, bool)
        return msg_w, valid, zeros, zeros
    mm = jnp.where(real, msg_w, 0.0) if real is not None else msg_w
    rm = jnp.where(real, recv_w, 0.0) if real is not None else recv_w
    sq = jnp.sum(mm * mm, axis=-1)
    rn = jnp.sum(rm * rm, axis=-1)
    finite = jnp.isfinite(sq)          # NaN/inf anywhere poisons the sum
    if defense == "norm_clip":
        thr = jnp.maximum(NORM_CLIP_MULT ** 2 * rn, NORM_CLIP_FLOOR ** 2)
        clip = finite & (sq > thr)
        scale = jnp.sqrt(thr / jnp.maximum(sq, 1e-30))
        msg_w = jnp.where(clip[:, None], msg_w * scale[:, None], msg_w)
        return (msg_w, valid & finite, valid & ~finite, valid & clip)
    if defense == "cosine_gate":
        dot = jnp.sum(mm * rm, axis=-1)
        anti = (rn > COSINE_GATE_MIN_NORM ** 2) \
            & (dot < COSINE_GATE_THRESHOLD * jnp.sqrt(sq * rn))
        reject = ~finite | anti
        return (msg_w, valid & ~reject, valid & reject,
                jnp.zeros(valid.shape, bool))
    raise ValueError(f"unknown defense {defense!r} "
                     f"(expected one of {list(DEFENSES)})")
