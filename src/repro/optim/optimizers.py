"""Pytree optimizers (no optax in this environment).

Functional API: ``opt.init(params) -> state``;
``opt.update(grads, state, params, step) -> (new_params, new_state)``.
All element-wise, so they broadcast transparently over the gossip peer axis
(the leading stacked dim of per-peer parameters).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_zeros_like


class Optimizer(NamedTuple):
    init: Callable
    update: Callable
    name: str


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _clip_by_global_norm(grads, max_norm):
    if max_norm <= 0:
        return grads
    g = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads)


def sgd(lr_schedule, grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        grads = _clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(step)
        new = jax.tree.map(lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update, "sgd")


def sgd_momentum(lr_schedule, momentum: float = 0.9, grad_clip: float = 0.0,
                 momentum_dtype=jnp.bfloat16) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params)}

    def update(grads, state, params, step):
        grads = _clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(step)
        m = jax.tree.map(lambda mo, g: (momentum * mo.astype(jnp.float32)
                                        + g.astype(jnp.float32)).astype(momentum_dtype),
                         state["m"], grads)
        new = jax.tree.map(lambda p, mo: (p - lr * mo.astype(jnp.float32)).astype(p.dtype),
                           params, m)
        return new, {"m": m}

    return Optimizer(init, update, "sgdm")


def adamw(lr_schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": tree_zeros_like(jax.tree.map(lambda p: p.astype(jnp.float32), params)),
                "v": tree_zeros_like(jax.tree.map(lambda p: p.astype(jnp.float32), params))}

    def update(grads, state, params, step):
        grads = _clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(step)
        t = step.astype(jnp.float32) + 1.0 if hasattr(step, "astype") else float(step) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        def upd(p, mo, vo):
            u = (mo / bc1) / (jnp.sqrt(vo / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return jax.tree.map(upd, params, m, v), {"m": m, "v": v}

    return Optimizer(init, update, "adamw")


def make_optimizer(name: str, lr_schedule, *, grad_clip: float = 1.0,
                   weight_decay: float = 0.1) -> Optimizer:
    if name == "sgd":
        return sgd(lr_schedule, grad_clip)
    if name == "sgdm":
        return sgd_momentum(lr_schedule, grad_clip=grad_clip)
    if name == "adamw":
        return adamw(lr_schedule, grad_clip=grad_clip, weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
