from repro.optim.optimizers import Optimizer, sgd, sgd_momentum, adamw, make_optimizer
from repro.optim.schedules import warmup_cosine, constant, pegasos_schedule

__all__ = ["Optimizer", "sgd", "sgd_momentum", "adamw", "make_optimizer",
           "warmup_cosine", "constant", "pegasos_schedule"]
