"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def pegasos_schedule(lam: float):
    """η_t = 1/(λ t) — the Pegasos step size the paper's learner uses."""
    def sched(step):
        t = jnp.maximum(step.astype(jnp.float32) if hasattr(step, "astype")
                        else jnp.float32(step), 1.0)
        return 1.0 / (lam * t)
    return sched
