"""Evaluation metrics used by the paper's experimental section.

- 0-1 error (misclassification ratio) — the paper's primary metric.
- pairwise cosine similarity of the model population — Fig. 2 bottom row.
- Welford online mean/variance for streaming bench statistics.
"""
from __future__ import annotations

import jax.numpy as jnp


def zero_one_error(w, X, y, bias=None):
    """Misclassification ratio of linear model(s) ``w`` on test set (X, y).

    ``w`` may be a single (d,) model or a (m, d) population; returns a scalar
    or an (m,) vector respectively. Labels are in {-1, +1}.
    """
    scores = X @ w.T if w.ndim == 2 else X @ w
    if bias is not None:
        scores = scores + bias
    preds = jnp.where(scores >= 0, 1.0, -1.0)
    if w.ndim == 2:
        return jnp.mean(preds != y[:, None], axis=0)
    return jnp.mean(preds != y)


def voted_error(W, X, y):
    """0-1 error of majority voting over a model cache ``W`` of shape (c, d).

    Implements VOTEDPREDICT (Algorithm 4): each cached model votes by the
    sign of its score; prediction is the majority sign.
    """
    votes = jnp.where(X @ W.T >= 0, 1.0, 0.0)       # (n, c) in {0,1}
    p_ratio = votes.mean(axis=1)                     # fraction of + votes
    preds = jnp.where(p_ratio - 0.5 >= 0, 1.0, -1.0)
    return jnp.mean(preds != y)


def weighted_vote_error(W, X, y):
    """0-1 error of the *weighted* vote sgn(Σ⟨w_i, x⟩) — Eqs. (7), (18), (19)."""
    scores = X @ W.T                                  # (n, m)
    preds = jnp.where(scores.sum(axis=1) >= 0, 1.0, -1.0)
    return jnp.mean(preds != y)


def cosine_similarity(W):
    """Mean pairwise cosine similarity across the model population (m, d).

    The paper tracks this to study convergence of the population (Fig. 2).
    """
    norms = jnp.linalg.norm(W, axis=1, keepdims=True)
    Wn = W / jnp.maximum(norms, 1e-12)
    G = Wn @ Wn.T                                     # (m, m)
    m = W.shape[0]
    off = (G.sum() - jnp.trace(G)) / (m * (m - 1))
    return off


class Welford:
    """Streaming mean/std (host-side, used by the benchmark harness)."""

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0

    def add(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    @property
    def std(self) -> float:
        return (self.m2 / self.n) ** 0.5 if self.n > 1 else 0.0
