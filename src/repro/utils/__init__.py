from repro.utils.tree import (
    tree_axpy,
    tree_add,
    tree_scale,
    tree_average,
    tree_dot,
    tree_norm,
    tree_zeros_like,
    tree_cast,
    tree_size,
    tree_bytes,
)
from repro.utils.metrics import Welford, cosine_similarity, zero_one_error

__all__ = [
    "tree_axpy",
    "tree_add",
    "tree_scale",
    "tree_average",
    "tree_dot",
    "tree_norm",
    "tree_zeros_like",
    "tree_cast",
    "tree_size",
    "tree_bytes",
    "Welford",
    "cosine_similarity",
    "zero_one_error",
]
