"""Pytree arithmetic helpers used by optimizers and the gossip merge ops.

All helpers are jit-friendly (pure jax) and operate leaf-wise. They are the
pytree generalization of the paper's vector operations on linear models: the
gossip ``merge`` (Algorithm 3) is :func:`tree_average`, the SGD steps are
:func:`tree_axpy`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_leaves_with_path(tree):
    """Compat shim for ``jax.tree.leaves_with_path``.

    The ``jax.tree`` alias namespace gained ``leaves_with_path`` only in
    newer JAX releases; ``jax.tree_util.tree_leaves_with_path`` is the
    stable spelling that exists on every version this repo supports."""
    fn = getattr(jax.tree, "leaves_with_path", None)
    if fn is not None:
        return fn(tree)
    return jax.tree_util.tree_leaves_with_path(tree)


def tree_add(a, b):
    """Leaf-wise ``a + b``."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Leaf-wise ``a - b``."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    """Leaf-wise ``s * a`` for a scalar ``s``."""
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(alpha, x, y):
    """Leaf-wise ``alpha * x + y`` (the SGD update shape)."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_average(*trees, weights=None):
    """Average of a list of pytrees — the paper's MERGE for arbitrary models.

    ``merge(m1, m2).w = (m1.w + m2.w) / 2`` (Algorithm 3, line 24) generalized
    to n-way, optionally weighted, averaging over parameter pytrees.
    """
    n = len(trees)
    if weights is None:
        return jax.tree.map(lambda *xs: sum(xs) / n, *trees)
    wsum = sum(weights)
    return jax.tree.map(lambda *xs: sum(w * x for w, x in zip(weights, xs)) / wsum, *trees)


def tree_dot(a, b):
    """Inner product over all leaves (float32 accumulation)."""
    parts = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(parts)) if parts else jnp.float32(0.0)


def tree_norm(a):
    """Global L2 norm over all leaves."""
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_size(a):
    """Total number of elements across all leaves."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a):
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree.leaves(a):
        total += int(jnp.prod(jnp.array(x.shape))) * jnp.dtype(x.dtype).itemsize if x.shape else jnp.dtype(x.dtype).itemsize
    return total


def tree_random_like(key, a, scale=1.0):
    """Random-normal pytree with the same structure/shapes as ``a``."""
    leaves, treedef = jax.tree.flatten(a)
    keys = jax.random.split(key, len(leaves))
    new = [scale * jax.random.normal(k, x.shape, x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32)
           for k, x in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, new)
