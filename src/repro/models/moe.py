"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch.

TPU-native adaptation notes (vs the common GPU megablocks path):
* dispatch/combine are scatter/gather over an (E, C, D) expert buffer rather
  than giant one-hot einsums — fixed shapes, fits VMEM-tiled matmuls, and the
  buffer's expert axis is shardable over the mesh `model` axis so the
  token->expert movement lowers to an all-to-all (expert parallelism);
* capacity C = ceil(tokens_per_device * capacity_factor * top_k / E) keeps
  HLO FLOPs proportional to *active* params (roofline-faithful); overflow
  tokens are dropped (standard GShard behavior) and counted in aux stats.

Sharding profiles (config.moe.sharding):
  'expert' — expert weight dim 0 on the model axis (requires E % model == 0
             or model % E == 0); dispatch shows up as all-to-all.
  'tensor' — expert d_ff on the model axis (E indivisible by mesh, e.g.
             mixtral's 8 experts on a 16-way axis).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MoEConfig
from repro.models.layers import P


def moe_spec(d_model: int, m: MoEConfig, act: str, dtype=jnp.float32) -> Dict:
    e, f = m.num_experts, m.d_ff_expert
    ax0 = "expert"
    s = {
        "router": P((d_model, e), ("embed", "expert_router"), init="fan_in",
                    dtype=jnp.float32),
        "w_up": P((e, d_model, f), (ax0, "embed", "expert_ffn"), init="fan_in", dtype=dtype),
        "w_down": P((e, f, d_model), (ax0, "expert_ffn", "embed"), init="fan_in", dtype=dtype),
    }
    if act == "swiglu":
        s["w_gate"] = P((e, d_model, f), (ax0, "embed", "expert_ffn"), init="fan_in", dtype=dtype)
    return s


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = int(tokens * m.capacity_factor * m.top_k / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _reduce_combine_ctx(m: MoEConfig):
    """(ctx, model_axis, batch_shards) when the manual combine-before-reduce
    path can run: 'tensor' sharding, an active mesh context with non-empty
    batch axes (the group dim must be shardable over them — the gossip
    vmapped path keeps the gather combine), model axis size > 1."""
    if m.sharding != "tensor":
        return None
    from repro.sharding.act import current_ctx
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None or not ctx.batch_axes:
        return None
    if ctx.mesh_sizes.get(ctx.model_axis, 1) <= 1:
        return None
    import numpy as _np
    bsz = int(_np.prod([ctx.mesh_sizes.get(a, 1) for a in ctx.batch_axes]))
    if bsz <= 0:
        return None
    return ctx, ctx.model_axis, bsz


def moe_ffn(params, m: MoEConfig, x, act: str) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) -> (B, S, D), aux stats (load-balance loss etc.).

    Grouped (GShard-style) dispatch: tokens are split into
    ``m.dispatch_groups`` groups — the step builders set this to the
    batch-shard count, so each data shard dispatches into its OWN
    (E, C_group, D) buffer with capacity computed from the group's token
    count. Under pjit the group dim is batch-sharded, which removes the
    full-size buffer + scatter-add all-reduce that global capacity causes
    (the mixtral prefill hillclimb in EXPERIMENTS.md §Perf)."""
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    G = m.dispatch_groups if m.dispatch_groups > 0 and T % m.dispatch_groups == 0 else 1
    Tg = T // G
    C = _capacity(Tg, m)
    xt = x.reshape(G, Tg, D)

    from repro.sharding.act import shard_expert_buffer, shard_group_tokens
    xt = shard_group_tokens(xt)

    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                # (G, Tg, K)
    if K > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- position-in-expert via per-group cumsum over (Tg*K,) assignments --
    flat_expert = expert_idx.reshape(G, Tg * K)                    # (G, Tg*K)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)       # (G, Tg*K, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                           # (G, Tg*K, E)
    pos_in_expert = jnp.take_along_axis(pos, flat_expert[..., None],
                                        axis=2)[..., 0]
    keep = pos_in_expert < C                                       # (G, Tg*K)
    safe_pos = jnp.where(keep, pos_in_expert, C - 1)
    token_idx = jnp.repeat(jnp.arange(Tg), K)                      # (Tg*K,)

    def _dispatch(xg, fe, sp, kp):
        """One group's scatter into its (E, C, D) buffer."""
        contrib = jnp.where(kp[:, None], xg[token_idx], 0).astype(x.dtype)
        return jnp.zeros((E, C, D), x.dtype).at[fe, sp].add(contrib)

    # dispatch: (G, E, C, D) — G batch-sharded, E model-sharded ('expert'
    # mode) => the token->expert movement lowers to an all-to-all
    buf = jax.vmap(_dispatch)(xt, flat_expert, safe_pos, keep)
    buf = shard_expert_buffer(buf, m.sharding)

    # expert FFN (batched over groups × experts)
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(buf.dtype))
    if act == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(buf.dtype))
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    def _combine(ob, fe, sp, kp, gv):
        gathered = ob[fe, sp]                                      # (Tg*K, D)
        gathered = jnp.where(kp[:, None], gathered, 0)
        weighted = gathered * gv.reshape(-1)[:, None].astype(x.dtype)
        return jnp.zeros((Tg, D), x.dtype).at[token_idx].add(weighted)

    reduce_ctx = _reduce_combine_ctx(m) if m.combine == "reduce" else None
    if reduce_ctx is not None and G % reduce_ctx[2] == 0:
        # 'tensor'-mode combine-before-reduce (EXPERIMENTS.md §Perf B-4):
        # GSPMD will not defer the f-contraction psum through the combine
        # gather (measured, iteration B-3), so do it manually: inside a
        # partial-manual shard_map over (batch axes × model), each f-shard
        # computes its PARTIAL expert outputs for ITS token groups, gathers
        # them back to token order, and only then psums — the TP all-reduce
        # operand shrinks from E*C*D (top_k*cf x T*D) to T*D.
        from jax.sharding import PartitionSpec as _PS
        ctx, maxis, _bsz = reduce_ctx
        bentry = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
        w_down = params["w_down"].astype(h.dtype)

        def _down_combine(h_l, w_l, fe, sp, kp, gv):
            ob = jnp.einsum("gecf,efd->gecd", h_l, w_l)            # partial
            out = jax.vmap(_combine)(ob, fe, sp, kp, gv)           # (G_loc,Tg,D) partial
            # psum in f32: XLA's CPU backend crashes on a bf16 all-reduce
            # inside a partial-manual shard_map ("Invalid binary instruction
            # opcode copy"); f32 is also the numerically right accumulator
            return jax.lax.psum(out.astype(jnp.float32), maxis).astype(out.dtype)

        from repro.sharding.compat import shard_map_compat
        out = shard_map_compat(
            _down_combine, mesh=ctx.mesh,
            in_specs=(_PS(bentry, None, None, maxis), _PS(None, maxis, None),
                      _PS(bentry, None), _PS(bentry, None), _PS(bentry, None),
                      _PS(bentry, None, None)),
            out_specs=_PS(bentry, None, None),
            manual_axes=set(ctx.batch_axes) | {maxis},
        )(h, w_down, flat_expert, safe_pos, keep, gate_vals)
    else:
        out_buf = jnp.einsum("gecf,efd->gecd", h,
                             params["w_down"].astype(h.dtype))     # (G, E, C, D)
        # NOT sharding-constrained (see B-3: constraining forces the psum
        # at full E*C*D size; leaving it free lets GSPMD pick — it still
        # reduces at the dot, hence the B-4 shard_map path above)
        out = jax.vmap(_combine)(out_buf, flat_expert, safe_pos, keep,
                                 gate_vals)
    out = shard_group_tokens(out)

    # --- aux: switch-style load-balance loss + drop fraction ----------------
    probs_t = probs.reshape(T, E)
    me = jnp.mean(probs_t, axis=0)                                  # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0].reshape(T), E,
                                 dtype=jnp.float32), axis=0)
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce) * m.router_aux_weight,
        "drop_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(B, S, D), aux
