"""Spec-first pure-JAX module substrate.

There is no flax/optax in this environment, so the framework defines its own
minimal module convention:

* every module exposes ``*_spec(cfg) -> {name: P | subdict}`` describing its
  parameters — shape, *logical axis names*, initializer, dtype;
* ``init_params(key, spec)`` materializes a params pytree (same nesting);
* ``abstract_params(spec)`` produces ``ShapeDtypeStruct``s (used by the
  multi-pod dry-run so 405B-scale params are never allocated);
* ``spec_axes(spec)`` yields the logical-axes tree consumed by
  ``repro.sharding`` to build ``PartitionSpec``s;
* apply functions are plain functions ``apply(params, cfg, inputs, ...)``.

Logical axis vocabulary (mapped to mesh axes in ``sharding/rules.py``):
  'vocab', 'embed', 'ffn', 'heads', 'kv_heads', 'head_dim', 'expert',
  'expert_ffn', 'state', 'conv', 'layers' (the scan-stacking axis), None.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    """Parameter spec: shape + logical axes + init + dtype."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | fan_in | lecun
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# ---------------------------------------------------------------------------
# spec tree utilities
# ---------------------------------------------------------------------------


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _flatten_spec(spec, prefix=()):
    out = []
    for k, v in spec.items():
        path = prefix + (k,)
        if _is_spec(v):
            out.append((path, v))
        else:
            out.extend(_flatten_spec(v, path))
    return out


def _init_leaf(key, p: P):
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "fan_in":
        fan_in = p.shape[0] if len(p.shape) >= 1 else 1
        s = 1.0 / np.sqrt(max(fan_in, 1))
        return (s * jax.random.normal(key, p.shape)).astype(p.dtype)
    if p.init == "lecun":
        fan_in = int(np.prod(p.shape[:-1])) or 1
        s = 1.0 / np.sqrt(fan_in)
        return (s * jax.random.truncated_normal(key, -2.0, 2.0, p.shape)).astype(p.dtype)
    # 'normal'
    return (p.scale * jax.random.normal(key, p.shape)).astype(p.dtype)


def init_params(key, spec) -> Dict:
    """Materialize a params pytree from a spec tree (deterministic per-path)."""
    flat = _flatten_spec(spec)
    out: Dict = {}
    for path, p in flat:
        k = key
        for name in path:
            k = jax.random.fold_in(k, hash(name) % (2**31))
        node = out
        for name in path[:-1]:
            node = node.setdefault(name, {})
        node[path[-1]] = _init_leaf(k, p)
    return out


def abstract_params(spec) -> Dict:
    """ShapeDtypeStruct tree — dry-run params without any allocation."""
    return _map_spec(spec, lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype))


def spec_axes(spec) -> Dict:
    """Logical-axes tree (same nesting as params)."""
    return _map_spec(spec, lambda p: p.axes)


def _map_spec(spec, fn):
    out = {}
    for k, v in spec.items():
        out[k] = fn(v) if _is_spec(v) else _map_spec(v, fn)
    return out


def spec_param_count(spec) -> int:
    return sum(int(np.prod(p.shape)) for _, p in _flatten_spec(spec))


def stack_spec(spec, n: int):
    """Prepend a 'layers' scan axis of length n to every leaf of a spec."""
    return _map_spec(spec, lambda p: dataclasses.replace(
        p, shape=(n,) + p.shape, axes=("layers",) + p.axes))


# ---------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int, dtype=jnp.float32):
    return {"scale": P((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int, dtype=jnp.float32):
    return {"scale": P((d,), ("embed",), init="ones", dtype=dtype),
            "bias": P((d,), ("embed",), init="zeros", dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def mlp_spec(d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    if act == "swiglu":
        return {
            "w_gate": P((d_model, d_ff), ("embed", "ffn"), init="fan_in", dtype=dtype),
            "w_up": P((d_model, d_ff), ("embed", "ffn"), init="fan_in", dtype=dtype),
            "w_down": P((d_ff, d_model), ("ffn", "embed"), init="fan_in", dtype=dtype),
        }
    return {
        "w_up": P((d_model, d_ff), ("embed", "ffn"), init="fan_in", dtype=dtype),
        "b_up": P((d_ff,), ("ffn",), init="zeros", dtype=dtype),
        "w_down": P((d_ff, d_model), ("ffn", "embed"), init="fan_in", dtype=dtype),
        "b_down": P((d_model,), ("embed",), init="zeros", dtype=dtype),
    }


def wcast(w, x):
    """Apply-time weight cast: params are stored f32 (optimizer fidelity)
    but matmuls run in the activation dtype — otherwise every bf16×f32
    matmul promotes to f32 activations, doubling the memory and
    activation-collective roofline terms (EXPERIMENTS.md §Perf iter 2)."""
    return w.astype(x.dtype)


def mlp(params, x, act: str):
    if act == "swiglu":
        g = x @ wcast(params["w_gate"], x)
        u = x @ wcast(params["w_up"], x)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return h @ wcast(params["w_down"], x)
    h = x @ wcast(params["w_up"], x) + params["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return h @ wcast(params["w_down"], x) + params["b_down"].astype(x.dtype)


def embedding_spec(vocab: int, d_model: int, dtype=jnp.float32):
    # the embed dim is deliberately NOT FSDP-sharded ('embed_table' maps to
    # no mesh axis): the table is already vocab-sharded over 'model', and
    # double-sharding turns every lookup into a full-batch all-reduce.
    return {"table": P((vocab, d_model), ("vocab", "embed_table"),
                       init="normal", scale=0.02, dtype=dtype)}


def embed(params, tokens, compute_dtype):
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params, x):
    # logits in f32 for stable softmax-xent
    return x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T


def positional_embedding_spec(max_len: int, d_model: int, dtype=jnp.float32):
    return {"pos": P((max_len, d_model), (None, "embed"), init="normal",
                     scale=0.02, dtype=dtype)}
