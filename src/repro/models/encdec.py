"""Whisper-style encoder tower [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv1d frontend is a
STUB: ``input_specs()`` provides precomputed (B, source_len, d_model) frame
embeddings. The *transformer* encoder (24 non-causal layers for
whisper-medium) and the decoder (selfcross layers in transformer.py) are
fully implemented. Sinusoidal positions are added to the frame embeddings,
matching Whisper's fixed encoder positional encoding.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config.base import AttentionConfig, ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L


def _enc_attn_cfg(cfg: ModelConfig) -> AttentionConfig:
    a = cfg.attention
    return AttentionConfig(num_heads=a.num_heads, num_kv_heads=a.num_heads,
                           head_dim=a.head_dim, qk_norm=False,
                           use_rope=False, causal=False)


def encoder_layer_spec(cfg: ModelConfig) -> Dict:
    d = cfg.encoder.d_model or cfg.d_model
    return {
        "ln1": L.layernorm_spec(d, cfg.param_dtype),
        "attn": attn_mod.attention_spec(d, _enc_attn_cfg(cfg), cfg.param_dtype),
        "ln2": L.layernorm_spec(d, cfg.param_dtype),
        "ffn": L.mlp_spec(d, cfg.d_ff, "gelu", cfg.param_dtype),
    }


def encoder_spec(cfg: ModelConfig) -> Dict:
    n = cfg.encoder.num_layers
    return {
        "blocks": L.stack_spec(encoder_layer_spec(cfg), n),
        "final_ln": L.layernorm_spec(cfg.encoder.d_model or cfg.d_model,
                                     cfg.param_dtype),
    }


def sinusoids(length: int, channels: int):
    """Whisper's fixed sinusoidal position embedding."""
    log_timescale = jnp.log(10_000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def encoder_forward(params, cfg: ModelConfig, frames):
    """frames: (B, source_len, d_model) precomputed embeddings (stub output)."""
    cd = cfg.compute_dtype
    x = frames.astype(cd) + sinusoids(frames.shape[1], frames.shape[2]).astype(cd)
    a = _enc_attn_cfg(cfg)

    def body(x, lp):
        h = L.layernorm(lp["ln1"], x, cfg.norm_eps)
        x = x + attn_mod.attention(lp["attn"], a, h,
                                   compute_dtype=cd).astype(x.dtype)
        h = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(lp["ffn"], h, "gelu").astype(x.dtype)
        return x, None

    body_ = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body_, x, params["blocks"])
    else:
        for i in range(cfg.encoder.num_layers):
            lp = jax.tree.map(lambda p: p[i], params["blocks"])
            x, _ = body_(x, lp)
    return L.layernorm(params["final_ln"], x, cfg.norm_eps)


def encoder_cross_kv(params, cfg: ModelConfig, frames):
    """Precompute the decoder's per-layer cross K/V from encoder output —
    used to build the serve cache (so decode never re-touches the encoder)."""
    enc = encoder_forward(params["encoder"], cfg, frames)
    a = cfg.attention
    nb = cfg.num_layers // len(cfg.layer_pattern)
    ck, cv = [], []
    for i in range(nb):
        lp = jax.tree.map(lambda p: p[i], params["blocks"])["l0"]
        k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"].astype(enc.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"].astype(enc.dtype))
        if a.qk_norm:
            k = L.rmsnorm(lp["cross_attn"]["k_norm"], k)
        ck.append(k)
        cv.append(v)
    return jnp.stack(ck), jnp.stack(cv), enc
