"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill: the chunked dual form — quadratic attention-like computation
inside chunks of ``chunk_size`` plus a linear cross-chunk state recurrence.
Decode: the classic linear recurrence, O(1) state per step (this is why the
``long_500k`` workload is native for this family).

TPU adaptation: the chunked form is expressed as batched einsums whose
contraction dims (head_dim, d_state, chunk) are 64/128-multiples — MXU
friendly — and the cross-chunk recurrence uses the chunk-level ``segsum``
decay matrix (n_chunks² is small) instead of a sequential scan, keeping a
single fused HLO while staying numerically in f32 where it matters.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SSMConfig
from repro.models.layers import P, rmsnorm_spec


def ssm_dims(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def ssm_spec(d_model: int, s: SSMConfig, dtype=jnp.float32) -> Dict:
    d_inner, H, conv_dim = ssm_dims(d_model, s)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    return {
        "w_in": P((d_model, d_in_proj), ("embed", "ffn"), init="fan_in", dtype=dtype),
        "conv_w": P((s.d_conv, conv_dim), ("conv", "ffn"), init="fan_in", dtype=dtype),
        "conv_b": P((conv_dim,), ("ffn",), init="zeros", dtype=dtype),
        "A_log": P((H,), ("heads",), init="zeros", dtype=jnp.float32),
        "D": P((H,), ("heads",), init="ones", dtype=jnp.float32),
        "dt_bias": P((H,), ("heads",), init="zeros", dtype=jnp.float32),
        "norm": rmsnorm_spec(d_inner, dtype),
        "w_out": P((d_inner, d_model), ("ffn", "embed"), init="fan_in", dtype=dtype),
    }


def _segsum(x):
    """x: (..., T) -> (..., T, T) with out[..., i, j] = sum_{j<k<=i} x[..k],
    and -inf above the diagonal. The decay-matrix builder of SSD."""
    T = x.shape[-1]
    xe = jnp.broadcast_to(x[..., None], x.shape + (T,))   # out[..., d, e] = x[d]
    lower_strict = jnp.tril(jnp.ones((T, T), bool), k=-1)
    xe = jnp.where(lower_strict, xe, 0.0)
    seg = jnp.cumsum(xe, axis=-2)
    lower = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(lower, seg, -jnp.inf)


def _split_proj(params, s: SSMConfig, d_model, x):
    d_inner, H, conv_dim = ssm_dims(d_model, s)
    zxbcdt = x @ params["w_in"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt


def _conv(params, s: SSMConfig, xBC, conv_state=None):
    """Causal depthwise conv width d_conv over (B, S, conv_dim).

    If conv_state (B, d_conv-1, conv_dim) is given (decode), prepend it and
    return (out, new_state)."""
    w = params["conv_w"].astype(xBC.dtype)                # (K, C)
    K = w.shape[0]
    if conv_state is not None:
        full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
        new_state = full[:, -(K - 1):]
    else:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
        full = jnp.concatenate([pad, xBC], axis=1)
        new_state = full[:, -(K - 1):]
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    out = out + params["conv_b"].astype(xBC.dtype)
    return jax.nn.silu(out.astype(jnp.float32)).astype(xBC.dtype), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """The SSD dual-form scan.

    x: (b, S, h, p) inputs; dt: (b, S, h) step sizes (post-softplus);
    A: (h,) negative decay rates; B, C: (b, S, g, n). Returns y (b, S, h, p)
    and the final state (b, h, p, n)."""
    b, S, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if S % chunk != 0:
        # Zero-pad to a chunk multiple. Padding with dt=0 is exact: the padded
        # positions have decay exp(0)=1 (state carried through unchanged) and
        # zero input, so the final state equals the state at position S and
        # the padded outputs are discarded below.
        pad = chunk - S % chunk
        padS = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        y, final_state = ssd_chunked(padS(x), padS(dt), A, padS(B), padS(C), chunk)
        return y[:, :S], final_state
    c = S // chunk
    rep = h // g

    xc = x.reshape(b, c, chunk, h, p)
    dtc = dt.reshape(b, c, chunk, h)
    Bc = jnp.repeat(B.reshape(b, c, chunk, g, n), rep, axis=3)  # (b,c,l,h,n)
    Cc = jnp.repeat(C.reshape(b, c, chunk, g, n), rep, axis=3)

    a = (dtc * A[None, None, None, :]).astype(jnp.float32)      # (b,c,l,h) log decay
    a = jnp.moveaxis(a, -1, 2)                                  # (b,c,h,l)
    a_cum = jnp.cumsum(a, axis=-1)                              # (b,c,h,l)

    x_dt = xc * dtc[..., None].astype(xc.dtype)                 # (b,c,l,h,p)

    # 1) intra-chunk (the "attention-like" quadratic-in-chunk term)
    L = jnp.exp(_segsum(a))                                     # (b,c,h,l,l)
    Y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        Cc, Bc, L.astype(Cc.dtype), x_dt)

    # 2) chunk-final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (b,c,h,l)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn",
                        Bc, decay_states.astype(Bc.dtype), x_dt)  # (b,c,h,p,n)

    # 3) cross-chunk recurrence via chunk-level segsum (c+1 x c+1 decay)
    chunk_decay = a_cum[..., -1]                                # (b,c,h)
    cd = jnp.moveaxis(chunk_decay, -1, 1)                       # (b,h,c)
    cd = jnp.pad(cd, ((0, 0), (0, 0), (1, 0)))                  # (b,h,c+1)
    Dk = jnp.exp(_segsum(cd))                                   # (b,h,c+1,c+1)
    states_pad = jnp.pad(states, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    all_states = jnp.einsum("bhzc,bchpn->bzhpn", Dk.astype(states.dtype),
                            states_pad)                         # (b,c+1,h,p,n)
    init_states, final_state = all_states[:, :-1], all_states[:, -1]

    # 4) contribution of the carried-in state to each position
    out_decay = jnp.exp(a_cum)                                  # (b,c,h,l)
    Y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp",
                       Cc, init_states, out_decay.astype(Cc.dtype))

    y = (Y_diag + Y_off).reshape(b, S, h, p)
    return y, final_state


def ssm_forward(params, s: SSMConfig, d_model: int, x, *,
                compute_dtype=jnp.bfloat16, return_state: bool = False):
    """Full-sequence Mamba-2 block. x: (B, S, d_model) -> same.

    ``return_state`` also returns the decode state {"ssm", "conv"} after the
    last position — the fused-prefill path (one pass instead of S recurrent
    steps)."""
    from repro.models.layers import rmsnorm
    d_inner, H, _ = ssm_dims(d_model, s)
    B_, S, _ = x.shape
    z, xBC_raw, dt = _split_proj(params, s, d_model, x)
    xBC, conv_state = _conv(params, s, xBC_raw)
    xs = xBC[..., :d_inner].reshape(B_, S, H, s.head_dim)
    Bm = xBC[..., d_inner:d_inner + s.n_groups * s.d_state].reshape(B_, S, s.n_groups, s.d_state)
    Cm = xBC[..., d_inner + s.n_groups * s.d_state:].reshape(B_, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                               # (H,)

    y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk_size)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(B_, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(params["norm"], y)
    out = y @ params["w_out"].astype(y.dtype)
    if return_state:
        # decode carries the *pre-activation* conv window of raw xBC rows
        return out, {"ssm": final_state.astype(jnp.float32),
                     "conv": conv_state.astype(compute_dtype)}
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def ssm_state_spec(batch: int, d_model: int, s: SSMConfig, dtype):
    d_inner, H, conv_dim = ssm_dims(d_model, s)
    return {
        "ssm": jax.ShapeDtypeStruct((batch, H, s.head_dim, s.d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), dtype),
    }


def init_ssm_state(batch: int, d_model: int, s: SSMConfig, dtype):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        ssm_state_spec(batch, d_model, s, dtype))


def ssm_step(params, s: SSMConfig, d_model: int, x, state, *,
             compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step. x: (B, 1, d_model)."""
    from repro.models.layers import rmsnorm
    d_inner, H, _ = ssm_dims(d_model, s)
    B_ = x.shape[0]
    z, xBC, dt = _split_proj(params, s, d_model, x)
    xBC, conv_state = _conv(params, s, xBC, conv_state=state["conv"])
    xs = xBC[..., :d_inner].reshape(B_, H, s.head_dim)
    Bm = xBC[:, 0, d_inner:d_inner + s.n_groups * s.d_state].reshape(B_, s.n_groups, s.d_state)
    Cm = xBC[:, 0, d_inner + s.n_groups * s.d_state:].reshape(B_, s.n_groups, s.d_state)
    rep = H // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=1)                            # (B, H, N)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])

    dA = jnp.exp(dt * A[None, :])                               # (B, H)
    xf = xs.astype(jnp.float32) * dt[..., None]                 # (B, H, P)
    new_ssm = (state["ssm"] * dA[..., None, None]
               + xf[..., :, None] * Bm.astype(jnp.float32)[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Cm.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, 1, d_inner).astype(compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(params["norm"], y)
    return y @ params["w_out"].astype(y.dtype), {"ssm": new_ssm, "conv": conv_state}
