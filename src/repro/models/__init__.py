from repro.models import attention, encdec, layers, moe, rglru, ssm, transformer, vision
from repro.models.transformer import (
    model_spec,
    init_params,
    abstract_params,
    param_axes,
    forward,
    lm_loss,
    decode_step,
    cache_spec,
    init_cache,
)

__all__ = [
    "attention", "encdec", "layers", "moe", "rglru", "ssm", "transformer",
    "vision", "model_spec", "init_params", "abstract_params", "param_axes",
    "forward", "lm_loss", "decode_step", "cache_spec", "init_cache",
]
