"""VLM frontend stub helpers (Llama-3.2-Vision).

Per the assignment carve-out the ViT/SigLIP vision encoder + projector are
NOT implemented; ``input_specs()`` provides precomputed patch embeddings of
shape (B, n_patches, d_model) that the gated cross-attention layers (kind
'cross' in transformer.py) consume directly as ``encoder_out``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig


def patch_embedding_spec(cfg: ModelConfig, batch: int):
    """ShapeDtypeStruct for the stubbed vision-encoder output."""
    assert cfg.cross_attn is not None
    return jax.ShapeDtypeStruct(
        (batch, cfg.cross_attn.source_len, cfg.d_model), cfg.compute_dtype)


def dummy_patch_embeddings(key, cfg: ModelConfig, batch: int):
    sds = patch_embedding_spec(cfg, batch)
    return jax.random.normal(key, sds.shape, sds.dtype) * 0.02


def frame_embedding_spec(cfg: ModelConfig, batch: int):
    """ShapeDtypeStruct for the stubbed audio (mel+conv) frontend output."""
    assert cfg.encoder is not None
    d = cfg.encoder.d_model or cfg.d_model
    return jax.ShapeDtypeStruct((batch, cfg.encoder.source_len, d),
                                cfg.compute_dtype)


def dummy_frame_embeddings(key, cfg: ModelConfig, batch: int):
    sds = frame_embedding_spec(cfg, batch)
    return jax.random.normal(key, sds.shape, sds.dtype) * 0.02
