"""RG-LRU recurrent block (Griffin / RecurrentGemma [arXiv:2402.19427]).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
a_t = exp(-c · softplus(Λ) · r_t),  r_t, i_t = σ(block-diag linear of x_t).

Training uses ``jax.lax.associative_scan`` over the time axis (log-depth on
TPU); decode is the single recurrence step. The recurrence runs in f32, the
matmuls in the compute dtype — the paper's numerics, adapted to bf16 MXU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RGLRUConfig
from repro.models.layers import P


def rglru_dims(d_model: int, r: RGLRUConfig):
    width = r.lru_width or d_model
    heads = r.num_heads or 8
    assert width % heads == 0
    return width, heads


def rglru_spec(d_model: int, r: RGLRUConfig, dtype=jnp.float32) -> Dict:
    width, heads = rglru_dims(d_model, r)
    hw = width // heads
    return {
        "w_x": P((d_model, width), ("embed", "ffn"), init="fan_in", dtype=dtype),
        "w_y": P((d_model, width), ("embed", "ffn"), init="fan_in", dtype=dtype),
        "conv_w": P((r.d_conv, width), ("conv", "ffn"), init="fan_in", dtype=dtype),
        "conv_b": P((width,), ("ffn",), init="zeros", dtype=dtype),
        # block-diagonal gates (recurrence gate a, input gate i)
        "w_a": P((heads, hw, hw), ("heads", None, None), init="fan_in", dtype=dtype),
        "b_a": P((heads, hw), ("heads", None), init="zeros", dtype=dtype),
        "w_i": P((heads, hw, hw), ("heads", None, None), init="fan_in", dtype=dtype),
        "b_i": P((heads, hw), ("heads", None), init="zeros", dtype=dtype),
        "lam": P((width,), ("ffn",), init="normal", scale=0.5, dtype=jnp.float32),
        "w_out": P((width, d_model), ("ffn", "embed"), init="fan_in", dtype=dtype),
    }


def _gates(params, r: RGLRUConfig, x, width, heads):
    """x: (B, S, width) -> log_a (f32), gated input (B, S, width)."""
    hw = width // heads
    xh = x.reshape(*x.shape[:-1], heads, hw)
    ra = jnp.einsum("...hk,hkj->...hj", xh, params["w_a"].astype(xh.dtype)) + params["b_a"].astype(x.dtype)
    ri = jnp.einsum("...hk,hkj->...hj", xh, params["w_i"].astype(xh.dtype)) + params["b_i"].astype(x.dtype)
    rt = jax.nn.sigmoid(ra.astype(jnp.float32)).reshape(*x.shape[:-1], width)
    it = jax.nn.sigmoid(ri.astype(jnp.float32)).reshape(*x.shape[:-1], width)
    log_a = -r.c * jax.nn.softplus(params["lam"]) * rt        # (B, S, width) f32
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * it * x.astype(jnp.float32)
    return log_a, gated


def _conv(params, r: RGLRUConfig, x, conv_state=None):
    w = params["conv_w"].astype(x.dtype)
    K = w.shape[0]
    if conv_state is not None:
        full = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
        full = jnp.concatenate([pad, x], axis=1)
    new_state = full[:, -(K - 1):]
    out = sum(full[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out + params["conv_b"].astype(x.dtype), new_state


def rglru_forward(params, r: RGLRUConfig, d_model: int, x, *,
                  compute_dtype=jnp.bfloat16, return_state: bool = False):
    """Full-sequence recurrent block. x: (B, S, d_model).

    ``return_state`` also returns the decode state {"h", "conv"} after the
    last position (fused prefill)."""
    width, heads = rglru_dims(d_model, r)
    y_branch = jax.nn.gelu((x @ params["w_y"].astype(x.dtype)).astype(jnp.float32))
    xb = x @ params["w_x"].astype(x.dtype)
    xb, conv_state = _conv(params, r, xb)
    log_a, gated = _gates(params, r, xb, width, heads)

    # associative scan: h_t = a_t * h_{t-1} + b_t over axis 1
    def op(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a = jnp.exp(log_a)
    _, h = jax.lax.associative_scan(op, (a, gated), axis=1)
    out = (h * y_branch).astype(compute_dtype)
    out = out @ params["w_out"].astype(out.dtype)
    if return_state:
        return out, {"h": h[:, -1], "conv": conv_state.astype(compute_dtype)}
    return out


def rglru_state_spec(batch: int, d_model: int, r: RGLRUConfig, dtype):
    width, _ = rglru_dims(d_model, r)
    return {
        "h": jax.ShapeDtypeStruct((batch, width), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, r.d_conv - 1, width), dtype),
    }


def init_rglru_state(batch: int, d_model: int, r: RGLRUConfig, dtype):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        rglru_state_spec(batch, d_model, r, dtype))


def rglru_step(params, r: RGLRUConfig, d_model: int, x, state, *,
               compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x: (B, 1, d_model)."""
    width, heads = rglru_dims(d_model, r)
    y_branch = jax.nn.gelu((x @ params["w_y"].astype(x.dtype)).astype(jnp.float32))  # (B,1,w)
    xb = x @ params["w_x"].astype(x.dtype)
    xb, conv_state = _conv(params, r, xb, conv_state=state["conv"])
    log_a, gated = _gates(params, r, xb, width, heads)               # (B,1,w)
    h = jnp.exp(log_a[:, 0]) * state["h"] + gated[:, 0]
    out = (h[:, None] * y_branch).astype(compute_dtype)
    return out @ params["w_out"].astype(out.dtype), {"h": h, "conv": conv_state}
