"""GQA attention with RoPE, qk-norm, sliding windows, cross-attention, KV cache.

Train/prefill path: full-sequence attention (XLA einsum or the Pallas flash
kernel). Decode path: one new token against a (possibly ring-buffered) KV
cache — the ``serve_step`` shape required by the decode workloads.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import AttentionConfig
from repro.models.layers import P, rmsnorm, rmsnorm_spec, wcast


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]                         # (..., S, 1, hd/2)
    sin = sin[..., :, None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------


def attention_spec(d_model: int, a: AttentionConfig, dtype=jnp.float32) -> Dict:
    s = {
        "wq": P((d_model, a.num_heads, a.head_dim), ("embed", "heads", "head_dim"),
                init="fan_in", dtype=dtype),
        "wk": P((d_model, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", "head_dim"),
                init="fan_in", dtype=dtype),
        "wv": P((d_model, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", "head_dim"),
                init="fan_in", dtype=dtype),
        "wo": P((a.num_heads, a.head_dim, d_model), ("heads", "head_dim", "embed"),
                init="fan_in", dtype=dtype),
    }
    if a.qk_norm:
        s["q_norm"] = rmsnorm_spec(a.head_dim, dtype)
        s["k_norm"] = rmsnorm_spec(a.head_dim, dtype)
    return s


def _project_qkv(params, a: AttentionConfig, x, kv_source=None):
    q = jnp.einsum("bsd,dhk->bshk", x, wcast(params["wq"], x))
    src = x if kv_source is None else kv_source
    k = jnp.einsum("bsd,dhk->bshk", src, wcast(params["wk"], src))
    v = jnp.einsum("bsd,dhk->bshk", src, wcast(params["wv"], src))
    if a.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    return q, k, v


def _expand_kv(k, q_per_kv: int):
    """(B, S, KV, hd) -> (B, S, KV*q_per_kv, hd) by repetition (GQA)."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _sdpa(q, k, v, mask, compute_dtype):
    """q: (B,Sq,H,hd); k,v: (B,Sk,H,hd); mask: (B|1, 1|H, Sq, Sk) bool."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", probs.astype(compute_dtype),
                     v.astype(compute_dtype))
    return out


def _grouped_sdpa(q, k, v, a: AttentionConfig, q_pos, k_pos, compute_dtype):
    """GQA attention without expanding KV: q (B,Sq,H,hd), k/v (B,Sk,KV,hd).

    Heads are kept grouped (KV, rep) so the per-device logits tensor is
    (B, KV, rep, Sq, Sk) — shardable on the KV-group axis and never
    materializing repeated keys."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bqgrk,bsgk->bgrqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    diff = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones(diff.shape, dtype=bool)
    if a.causal:
        mask &= diff >= 0
    if a.sliding_window is not None:
        mask &= diff < a.sliding_window
    logits = jnp.where(mask[None, None, None], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqs,bsgk->bqgrk", probs.astype(compute_dtype),
                     v.astype(compute_dtype))
    return out.reshape(B, Sq, H, hd)


def _chunked_sdpa(q, k, v, a: AttentionConfig, positions, compute_dtype,
                  chunk: int):
    """Memory-efficient attention: lax.scan over query chunks.

    Peak per-chunk logits are (B, KV, rep, chunk, Sk) — the XLA-level
    equivalent of flash attention's working-set bound (the Pallas kernel
    tightens it further on real TPUs). The chunk body is rematerialized in
    the backward pass.

    Sliding-window layers only read the key span that can be in-window for
    the chunk (a dynamic slice of ``window+chunk`` keys, rounded to chunk)
    instead of masking a full (chunk, S) logits block — S/(window+chunk)×
    fewer attention FLOPs/bytes at long S (EXPERIMENTS.md §Perf, SWA
    hillclimb: llama4-scout prefill useful 0.03→…)."""
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    k_pos = positions
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = positions.reshape(nc, chunk)
    idx = jnp.arange(nc, dtype=jnp.int32)

    win = a.sliding_window
    kspan = S
    if win is not None and a.causal:
        kspan = min(S, -(-(win + chunk) // chunk) * chunk)

    def body(carry, xs):
        q_i, pos_i, i = xs
        if kspan < S:
            start = jnp.clip(i * chunk + chunk - kspan, 0, S - kspan)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, kspan, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, kspan, axis=1)
            kp_i = jax.lax.dynamic_slice_in_dim(k_pos, start, kspan, axis=0)
        else:
            k_i, v_i, kp_i = k, v, k_pos
        o = _grouped_sdpa(q_i, k_i, v_i, a, pos_i, kp_i, compute_dtype)
        return carry, o

    body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, (), (qc, pc, idx))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def _banded_sdpa(q, k, v, a: AttentionConfig, positions, compute_dtype,
                 chunk: int):
    """Sliding-window attention as a *static* band: unrolled query blocks,
    each statically sliced to its in-window key span.

    Same FLOPs as the windowed ``_chunked_sdpa`` but loop-free HLO — used by
    the roofline costing (`launch/costs.py`) so windowed layers report
    S·(window+chunk) attention cost instead of the masked-full-S² the 'xla'
    path would count, and usable as a runtime impl when scan-free HLO is
    preferred."""
    B, S, H, hd = q.shape
    win = a.sliding_window
    if win is None or not a.causal:
        return _grouped_sdpa(q, k, v, a, positions, positions, compute_dtype)
    chunk = min(max(chunk, min(win, 4096)), S)
    nc = -(-S // chunk)
    kspan = min(S, -(-(win + chunk) // chunk) * chunk)
    outs = []
    for i in range(nc):
        q0, q1 = i * chunk, min((i + 1) * chunk, S)
        start = max(0, min(q1 - kspan, S - kspan))
        o = _grouped_sdpa(q[:, q0:q1], k[:, start:start + kspan],
                          v[:, start:start + kspan], a, positions[q0:q1],
                          positions[start:start + kspan], compute_dtype)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def make_mask(a: AttentionConfig, q_pos, k_pos):
    """Boolean attention mask from query/key position vectors.

    q_pos: (Sq,), k_pos: (Sk,) -> (1, 1, Sq, Sk). Causal and/or windowed."""
    diff = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones(diff.shape, dtype=bool)
    if a.causal:
        mask &= diff >= 0
    if a.sliding_window is not None:
        mask &= diff < a.sliding_window
    return mask[None, None]


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def attention(params, a: AttentionConfig, x, *, positions=None, kv_source=None,
              src_positions=None, compute_dtype=jnp.bfloat16, impl="xla",
              attn_chunk: int = 512, return_kv: bool = False):
    """Full-sequence attention. Returns (B, S, d_model), or
    ``(out, (k, v))`` with the rope'd keys/values when ``return_kv`` —
    the fused-prefill path that emits the decode KV cache in one pass.

    kv_source: if given, cross-attention to (B, S_src, d_model) (no causal
    mask, no rope on source unless src_positions given)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, a, x, kv_source)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    if a.use_rope and kv_source is None:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)

    if kv_source is not None:
        # cross-attention: non-causal over a short encoder source
        import dataclasses as _dc
        a_x = _dc.replace(a, causal=False, sliding_window=None)
        src_pos = jnp.arange(kv_source.shape[1], dtype=jnp.int32)
        out = _grouped_sdpa(q, k, v, a_x, positions[0], src_pos, compute_dtype)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=a.causal,
                                   window=a.sliding_window)
    elif impl == "chunked":
        out = _chunked_sdpa(q, k, v, a, positions[0], compute_dtype,
                            chunk=attn_chunk)
    elif impl == "banded":
        out = _banded_sdpa(q, k, v, a, positions[0], compute_dtype,
                           chunk=attn_chunk)
    else:
        out = _grouped_sdpa(q, k, v, a, positions[0], positions[0],
                            compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, wcast(params["wo"], out))
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# KV cache + decode step
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, length: int, a: AttentionConfig, dtype):
    """Abstract-or-real KV cache for one layer: dict of (B, L, KV, hd)."""
    shape = (batch, length, a.num_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_spec(batch: int, length: int, a: AttentionConfig, dtype):
    shape = (batch, length, a.num_kv_heads, a.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def decode_attention(params, a: AttentionConfig, x, cache, index, *,
                     compute_dtype=jnp.bfloat16, window: Optional[int] = None,
                     kv_source=None):
    """One-token decode: x (B, 1, D); cache holds L past positions.

    ``index`` is the current absolute position (scalar int32). If ``window``
    is set, the cache is a ring buffer of size L=window and writes wrap.
    Returns (out, new_cache).
    """
    B = x.shape[0]
    L = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(params, a, x)
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    if a.use_rope:
        q = apply_rope(q, pos, a.rope_theta)
        k_new = apply_rope(k_new, pos, a.rope_theta)

    if kv_source is not None:
        # cross-attention path: attend over the full encoder output, no cache
        import dataclasses as _dc
        from repro.models.layers import wcast as _wc
        k = jnp.einsum("bsd,dhk->bshk", kv_source, _wc(params["wk"], kv_source))
        v = jnp.einsum("bsd,dhk->bshk", kv_source, _wc(params["wv"], kv_source))
        if a.qk_norm:
            k = rmsnorm(params["k_norm"], k)
        a_x = _dc.replace(a, causal=False, sliding_window=None)
        src_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        out = _grouped_sdpa(q, k, v, a_x, jnp.zeros((1,), jnp.int32), src_pos,
                            compute_dtype)
        return jnp.einsum("bshk,hkd->bsd", out, _wc(params["wo"], out)), cache

    slot = index % L if window is not None else index
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    new_cache = {"k": k, "v": v}

    # absolute key positions per cache slot (ring-buffer aware)
    slots = jnp.arange(L, dtype=jnp.int32)
    if window is not None:
        # ring buffer: slot s holds absolute position p where p % L == s and
        # p <= index and p > index - L
        k_pos = index - ((slot - slots) % L)
    else:
        k_pos = slots
    valid = (k_pos >= 0) & (k_pos <= index)
    # encode invalid slots as a *future* position: the causal mask drops them
    k_pos_safe = jnp.where(valid, k_pos, index + 1)
    import dataclasses as _dc
    a_d = a if a.sliding_window is None else _dc.replace(
        a, sliding_window=min(a.sliding_window, L))
    q_pos = jnp.full((1,), index, jnp.int32)
    out = _grouped_sdpa(q, k, v, a_d, q_pos, k_pos_safe, compute_dtype)
    from repro.models.layers import wcast as _wc2
    return jnp.einsum("bshk,hkd->bsd", out, _wc2(params["wo"], out)), new_cache
