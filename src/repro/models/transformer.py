"""Model assembler: configs -> spec/forward/decode for every assigned family.

Layer kinds (``ModelConfig.layer_pattern``):
  'attn'   — global (or config-windowed) self-attention + FFN/MoE
  'local'  — sliding-window self-attention (window from rglru.local_window) + FFN
  'ssm'    — Mamba-2 SSD mixer (no FFN when d_ff == 0)
  'rglru'  — RG-LRU recurrent mixer + FFN
  'cross'  — gated cross-attention layer (Llama-3.2-Vision style) + FFN
  'selfcross' — self-attn + cross-attn + FFN in one layer (whisper decoder)

The stack is scanned over *super-blocks* of one pattern period (stacked
params), with any remainder layers unrolled — this keeps the HLO size
O(pattern) instead of O(num_layers), which is what makes compiling the
126-layer llama3-405b on a host CPU feasible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import P
from repro.sharding.act import shard_activations, shard_logits


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------


def _norm_spec(cfg: ModelConfig):
    return (L.layernorm_spec if cfg.norm == "layernorm" else L.rmsnorm_spec)(
        cfg.d_model, cfg.param_dtype)


def _apply_norm(cfg: ModelConfig, params, x):
    if cfg.norm == "layernorm":
        return L.layernorm(params, x, cfg.norm_eps)
    return L.rmsnorm(params, x, cfg.norm_eps)


def _ffn_spec(cfg: ModelConfig):
    if cfg.moe is not None:
        return moe_mod.moe_spec(cfg.d_model, cfg.moe, cfg.act, cfg.param_dtype)
    if cfg.d_ff == 0:
        return None
    return L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, cfg.param_dtype)


def _attn_cfg(cfg: ModelConfig, kind: str):
    a = cfg.attention
    if kind == "local":
        a = dataclasses.replace(a, sliding_window=cfg.rglru.local_window
                                if cfg.rglru else a.sliding_window)
    return a


def layer_spec(cfg: ModelConfig, kind: str) -> Dict:
    s: Dict[str, Any] = {"ln1": _norm_spec(cfg)}
    if kind in ("attn", "local", "selfcross"):
        s["attn"] = attn_mod.attention_spec(cfg.d_model, _attn_cfg(cfg, kind),
                                            cfg.param_dtype)
    elif kind == "ssm":
        s["ssm"] = ssm_mod.ssm_spec(cfg.d_model, cfg.ssm, cfg.param_dtype)
    elif kind == "rglru":
        s["rglru"] = rglru_mod.rglru_spec(cfg.d_model, cfg.rglru, cfg.param_dtype)
    elif kind == "cross":
        s["cross_attn"] = attn_mod.attention_spec(cfg.d_model, cfg.attention,
                                                  cfg.param_dtype)
        if cfg.cross_attn and cfg.cross_attn.gated:
            s["gate_attn"] = P((), (), init="zeros", dtype=jnp.float32)
            s["gate_ffn"] = P((), (), init="zeros", dtype=jnp.float32)
    if kind == "selfcross":
        s["lnx"] = _norm_spec(cfg)
        s["cross_attn"] = attn_mod.attention_spec(cfg.d_model, cfg.attention,
                                                  cfg.param_dtype)
    ffn = _ffn_spec(cfg)
    if ffn is not None:
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = ffn
    return s


def _pattern_split(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    period = len(cfg.layer_pattern)
    nb, rem = divmod(cfg.num_layers, period)
    return nb, cfg.layer_pattern[:rem]


def model_spec(cfg: ModelConfig) -> Dict:
    """Full parameter spec for the decoder/backbone (+ encoder tower)."""
    nb, rem_kinds = _pattern_split(cfg)
    spec: Dict[str, Any] = {
        "embed": L.embedding_spec(cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "final_norm": _norm_spec(cfg),
    }
    if nb > 0:
        spec["blocks"] = {
            f"l{j}": L.stack_spec(layer_spec(cfg, kind), nb)
            for j, kind in enumerate(cfg.layer_pattern)
        }
    if rem_kinds:
        spec["tail"] = {f"t{j}": layer_spec(cfg, kind)
                        for j, kind in enumerate(rem_kinds)}
    if not cfg.tie_embeddings:
        spec["lm_head"] = {"w": P((cfg.d_model, cfg.vocab_size),
                                  ("embed_table", "vocab"), init="fan_in",
                                  dtype=cfg.param_dtype)}
    if cfg.max_target_positions:
        spec["pos_embed"] = L.positional_embedding_spec(
            cfg.max_target_positions, cfg.d_model, cfg.param_dtype)
    if cfg.encoder is not None:
        from repro.models.encdec import encoder_spec
        spec["encoder"] = encoder_spec(cfg)
    return spec


def init_params(key, cfg: ModelConfig):
    return L.init_params(key, model_spec(cfg))


def abstract_params(cfg: ModelConfig):
    return L.abstract_params(model_spec(cfg))


def param_axes(cfg: ModelConfig):
    return L.spec_axes(model_spec(cfg))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _kv_to_cache(k, v, L: int, dtype):
    """Place prompt K/V rows into a (B, L, KV, hd) decode cache buffer.

    Slots follow the decode ring addressing (slot = pos % L); for P <= L
    (no ring wrap yet) this is the identity placement."""
    B, P = k.shape[0], k.shape[1]
    lo = max(0, P - L)
    pos = jnp.arange(lo, P)
    slots = pos % L
    ck = jnp.zeros((B, L) + k.shape[2:], dtype).at[:, slots].set(
        k[:, lo:].astype(dtype))
    cv = jnp.zeros((B, L) + v.shape[2:], dtype).at[:, slots].set(
        v[:, lo:].astype(dtype))
    return ck, cv


def _apply_layer(lp, kind: str, cfg: ModelConfig, x, *, positions,
                 encoder_out, aux, cache_len: Optional[int] = None,
                 window: Optional[int] = None):
    """One layer. With ``cache_len`` set (fused prefill), also returns the
    layer's decode-cache entry (KV buffer / recurrent state)."""
    cd = cfg.compute_dtype
    entry = None
    h = _apply_norm(cfg, lp["ln1"], x)
    if kind in ("attn", "local"):
        a = _attn_cfg(cfg, kind)
        mix = attn_mod.attention(lp["attn"], a, h,
                                 positions=positions, compute_dtype=cd,
                                 impl=cfg.attn_impl, attn_chunk=cfg.attn_chunk,
                                 return_kv=cache_len is not None)
        if cache_len is not None:
            mix, (k, v) = mix
            eff = min(cache_len, window) if window else cache_len
            if a.sliding_window:
                eff = min(eff, a.sliding_window)
            ck, cv = _kv_to_cache(k, v, eff, cd)
            entry = {"k": ck, "v": cv}
        x = x + mix.astype(x.dtype)
    elif kind == "ssm":
        out = ssm_mod.ssm_forward(lp["ssm"], cfg.ssm, cfg.d_model, h,
                                  compute_dtype=cd,
                                  return_state=cache_len is not None)
        if cache_len is not None:
            out, entry = out
        x = x + out.astype(x.dtype)
    elif kind == "rglru":
        out = rglru_mod.rglru_forward(lp["rglru"], cfg.rglru, cfg.d_model, h,
                                      compute_dtype=cd,
                                      return_state=cache_len is not None)
        if cache_len is not None:
            out, entry = out
        x = x + out.astype(x.dtype)
    elif kind == "cross":
        mix = attn_mod.attention(lp["cross_attn"], cfg.attention, h,
                                 positions=positions, kv_source=encoder_out,
                                 compute_dtype=cd,
                                 return_kv=cache_len is not None)
        if cache_len is not None:
            mix, (ck, cv) = mix
            entry = {"ck": ck.astype(cd), "cv": cv.astype(cd)}
        if "gate_attn" in lp:
            mix = jnp.tanh(lp["gate_attn"]).astype(mix.dtype) * mix
        x = x + mix.astype(x.dtype)
    elif kind == "selfcross":
        a = cfg.attention
        mix = attn_mod.attention(lp["attn"], a, h,
                                 positions=positions, compute_dtype=cd,
                                 return_kv=cache_len is not None)
        if cache_len is not None:
            mix, (k, v) = mix
            eff = min(cache_len, window) if window else cache_len
            if cfg.max_target_positions:
                eff = min(eff, cfg.max_target_positions)
            sk, sv = _kv_to_cache(k, v, eff, cd)
        x = x + mix.astype(x.dtype)
        hx = _apply_norm(cfg, lp["lnx"], x)
        xmix = attn_mod.attention(lp["cross_attn"], a, hx,
                                  positions=positions, kv_source=encoder_out,
                                  compute_dtype=cd,
                                  return_kv=cache_len is not None)
        if cache_len is not None:
            xmix, (ck, cv) = xmix
            entry = {"k": sk, "v": sv,
                     "ck": ck.astype(cd), "cv": cv.astype(cd)}
        x = x + xmix.astype(x.dtype)
    else:
        raise ValueError(kind)

    if "ffn" in lp:
        h2 = _apply_norm(cfg, lp["ln2"], x)
        if cfg.moe is not None:
            out, moe_aux = moe_mod.moe_ffn(lp["ffn"], cfg.moe, h2, cfg.act)
            aux = aux + moe_aux["load_balance_loss"]
        else:
            out = L.mlp(lp["ffn"], h2, cfg.act)
        if kind == "cross" and "gate_ffn" in lp:
            out = jnp.tanh(lp["gate_ffn"]).astype(out.dtype) * out
        x = x + out.astype(x.dtype)
    if cache_len is not None:
        return x, aux, entry
    return x, aux


def forward_hidden(params, cfg: ModelConfig, tokens, *, encoder_out=None,
                   positions=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> final hidden states (B, S, d_model), aux loss."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    if "pos_embed" in params:
        max_len = params["pos_embed"]["pos"].shape[0]
        x = x + params["pos_embed"]["pos"].astype(x.dtype)[
            jnp.minimum(positions, max_len - 1)]
    if cfg.encoder is not None and encoder_out is not None:
        from repro.models.encdec import encoder_forward
        encoder_out = encoder_forward(params["encoder"], cfg, encoder_out)

    nb, rem_kinds = _pattern_split(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    x = shard_activations(x)

    def block_body(carry, bp):
        x, aux = carry
        for j, kind in enumerate(cfg.layer_pattern):
            x, aux = _apply_layer(bp[f"l{j}"], kind, cfg, x,
                                  positions=positions,
                                  encoder_out=encoder_out, aux=aux)
        return (shard_activations(x), aux), None

    if nb > 0:
        body = jax.checkpoint(block_body) if cfg.remat else block_body
        if cfg.scan_layers:
            (x, aux0), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
        else:
            for i in range(nb):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                (x, aux0), _ = body((x, aux0), bp)
    for j, kind in enumerate(rem_kinds):
        x, aux0 = _apply_layer(params["tail"][f"t{j}"], kind, cfg, x,
                               positions=positions, encoder_out=encoder_out,
                               aux=aux0)

    x = _apply_norm(cfg, params["final_norm"], x)
    return x, aux0


def _head_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def forward(params, cfg: ModelConfig, tokens, *, encoder_out=None,
            positions=None, last_only: bool = False):
    """tokens (B, S) -> logits (f32), aux. ``last_only`` returns (B, vocab)
    for the final position only (the realistic prefill output)."""
    x, aux = forward_hidden(params, cfg, tokens, encoder_out=encoder_out,
                            positions=positions)
    if last_only:
        x = x[:, -1:]
    logits = x.astype(jnp.float32) @ _head_matrix(params, cfg).astype(jnp.float32)
    logits = shard_logits(logits)
    return (logits[:, 0] if last_only else logits), aux


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            encoder_out=None, window: Optional[int] = None):
    """Fused prefill: ONE full-sequence forward that also emits the decode
    cache (KV buffers at ring-addressed slots, SSM/RG-LRU states after the
    last position). Equivalent to feeding the prompt token-by-token through
    ``decode_step`` but one pass instead of P recurrent steps.

    tokens: (B, P) with P <= effective cache length. Returns
    (last-position logits (B, vocab) f32, cache matching
    :func:`cache_spec`)."""
    if window is not None and cfg.attention is not None:
        # a ring-buffer serve cache of size `window` == windowed attention:
        # the fused pass must not see keys the sequential path has evicted
        sw = cfg.attention.sliding_window
        cfg = cfg.replace(attention=dataclasses.replace(
            cfg.attention, sliding_window=min(sw, window) if sw else window))
    B, P = tokens.shape
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    positions = jnp.arange(P, dtype=jnp.int32)[None].repeat(B, 0)
    if "pos_embed" in params:
        max_len = params["pos_embed"]["pos"].shape[0]
        x = x + params["pos_embed"]["pos"].astype(x.dtype)[
            jnp.minimum(positions, max_len - 1)]
    if cfg.encoder is not None and encoder_out is not None:
        from repro.models.encdec import encoder_forward
        encoder_out = encoder_forward(params["encoder"], cfg, encoder_out)

    nb, rem_kinds = _pattern_split(cfg)
    aux0 = jnp.zeros((), jnp.float32)
    x = shard_activations(x)
    cache: Dict[str, Any] = {}

    def block_body(carry, bp):
        x, aux = carry
        entries = {}
        for j, kind in enumerate(cfg.layer_pattern):
            x, aux, entries[f"l{j}"] = _apply_layer(
                bp[f"l{j}"], kind, cfg, x, positions=positions,
                encoder_out=encoder_out, aux=aux, cache_len=cache_len,
                window=window)
        return (shard_activations(x), aux), entries

    if nb > 0:
        if cfg.scan_layers:
            (x, aux0), blocks = jax.lax.scan(block_body, (x, aux0),
                                             params["blocks"])
        else:
            outs = []
            for i in range(nb):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                (x, aux0), e = block_body((x, aux0), bp)
                outs.append(e)
            blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        cache["blocks"] = blocks
    if rem_kinds:
        cache["tail"] = {}
        for j, kind in enumerate(rem_kinds):
            x, aux0, cache["tail"][f"t{j}"] = _apply_layer(
                params["tail"][f"t{j}"], kind, cfg, x, positions=positions,
                encoder_out=encoder_out, aux=aux0, cache_len=cache_len,
                window=window)

    x = _apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = x.astype(jnp.float32) @ _head_matrix(params, cfg).astype(jnp.float32)
    return logits[:, 0], cache


def lm_loss(params, cfg: ModelConfig, tokens, labels, *, encoder_out=None,
            seq_chunk: int = 0):
    """Mean next-token cross-entropy + MoE aux, computed in sequence chunks.

    The (B, chunk, vocab) logits block is the only vocab-sized temporary —
    rematerialized in the backward pass — so the full (B, S, vocab) f32
    logits tensor (40 GB/device at 4k×152k vocab) never exists."""
    x, aux = forward_hidden(params, cfg, tokens, encoder_out=encoder_out)
    W = _head_matrix(params, cfg)
    B, S, D = x.shape
    chunk = min(seq_chunk or cfg.xent_chunk, S)
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(tot, xs):
        xi, li = xs                                   # (B, C, D), (B, C)
        logits = xi.astype(jnp.float32) @ W.astype(jnp.float32)
        logits = shard_logits(logits)
        logz = jax.nn.logsumexp(logits, axis=-1)      # (B, C)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    nll = total / (B * S)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def _layer_cache_spec(cfg: ModelConfig, kind: str, batch: int, length: int,
                      window: Optional[int]):
    cd = cfg.compute_dtype
    eff = min(length, window) if window else length
    a = _attn_cfg(cfg, kind) if kind in ("attn", "local", "selfcross") else None
    if kind in ("attn", "local"):
        if a.sliding_window:
            eff = min(eff, a.sliding_window)
        return attn_mod.kv_cache_spec(batch, eff, a, cd)
    if kind == "selfcross":
        eff2 = min(eff, cfg.max_target_positions) if cfg.max_target_positions else eff
        s = attn_mod.kv_cache_spec(batch, eff2, a, cd)
        src = cfg.encoder.source_len
        s["ck"] = jax.ShapeDtypeStruct((batch, src, a.num_kv_heads, a.head_dim), cd)
        s["cv"] = jax.ShapeDtypeStruct((batch, src, a.num_kv_heads, a.head_dim), cd)
        return s
    if kind == "cross":
        a = cfg.attention
        src = cfg.cross_attn.source_len
        return {"ck": jax.ShapeDtypeStruct((batch, src, a.num_kv_heads, a.head_dim), cd),
                "cv": jax.ShapeDtypeStruct((batch, src, a.num_kv_heads, a.head_dim), cd)}
    if kind == "ssm":
        return ssm_mod.ssm_state_spec(batch, cfg.d_model, cfg.ssm, cd)
    if kind == "rglru":
        return rglru_mod.rglru_state_spec(batch, cfg.d_model, cfg.rglru, cd)
    raise ValueError(kind)


def _stack_sds(spec, n):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)


def cache_spec(cfg: ModelConfig, batch: int, length: int,
               window: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct tree for the full decode cache (KV + SSM/LRU states)."""
    nb, rem_kinds = _pattern_split(cfg)
    out: Dict[str, Any] = {}
    if nb > 0:
        out["blocks"] = {
            f"l{j}": _stack_sds(_layer_cache_spec(cfg, kind, batch, length, window), nb)
            for j, kind in enumerate(cfg.layer_pattern)}
    if rem_kinds:
        out["tail"] = {f"t{j}": _layer_cache_spec(cfg, kind, batch, length, window)
                       for j, kind in enumerate(rem_kinds)}
    return out


def init_cache(cfg: ModelConfig, batch: int, length: int,
               window: Optional[int] = None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, length, window))


def _cross_attend(lp, a, cfg, h, ck, cv):
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(h.dtype))
    if a.qk_norm:
        q = L.rmsnorm(lp["q_norm"], q)
    a_x = dataclasses.replace(a, causal=False, sliding_window=None)
    src_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
    out = attn_mod._grouped_sdpa(q, ck, cv, a_x, jnp.zeros((1,), jnp.int32),
                                 src_pos, cfg.compute_dtype)
    return jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(out.dtype))


def _apply_layer_decode(lp, lc, kind: str, cfg: ModelConfig, x, index,
                        window: Optional[int]):
    cd = cfg.compute_dtype
    h = _apply_norm(cfg, lp["ln1"], x)
    if kind in ("attn", "local"):
        a = _attn_cfg(cfg, kind)
        # the cache is always addressed as a ring buffer: when its length
        # covers the full sequence this reduces exactly to linear addressing
        mix, new_c = attn_mod.decode_attention(lp["attn"], a, h, lc, index,
                                               compute_dtype=cd,
                                               window=lc["k"].shape[1])
        x = x + mix.astype(x.dtype)
    elif kind == "ssm":
        mix, new_c = ssm_mod.ssm_step(lp["ssm"], cfg.ssm, cfg.d_model, h,
                                      lc, compute_dtype=cd)
        x = x + mix.astype(x.dtype)
    elif kind == "rglru":
        mix, new_c = rglru_mod.rglru_step(lp["rglru"], cfg.rglru, cfg.d_model,
                                          h, lc, compute_dtype=cd)
        x = x + mix.astype(x.dtype)
    elif kind == "cross":
        mix = _cross_attend(lp["cross_attn"], cfg.attention, cfg, h,
                            lc["ck"], lc["cv"])
        if "gate_attn" in lp:
            mix = jnp.tanh(lp["gate_attn"]).astype(mix.dtype) * mix
        x = x + mix.astype(x.dtype)
        new_c = lc
    elif kind == "selfcross":
        self_c = {"k": lc["k"], "v": lc["v"]}
        mix, new_self = attn_mod.decode_attention(lp["attn"], cfg.attention, h,
                                                  self_c, index,
                                                  compute_dtype=cd,
                                                  window=lc["k"].shape[1])
        x = x + mix.astype(x.dtype)
        hx = _apply_norm(cfg, lp["lnx"], x)
        x = x + _cross_attend(lp["cross_attn"], cfg.attention, cfg, hx,
                              lc["ck"], lc["cv"]).astype(x.dtype)
        new_c = dict(new_self, ck=lc["ck"], cv=lc["cv"])
    else:
        raise ValueError(kind)

    if "ffn" in lp:
        h2 = _apply_norm(cfg, lp["ln2"], x)
        if cfg.moe is not None:
            out, _ = moe_mod.moe_ffn(lp["ffn"], cfg.moe, h2, cfg.act)
        else:
            out = L.mlp(lp["ffn"], h2, cfg.act)
        if kind == "cross" and "gate_ffn" in lp:
            out = jnp.tanh(lp["gate_ffn"]).astype(out.dtype) * out
        x = x + out.astype(x.dtype)
    return x, new_c


def decode_step(params, cfg: ModelConfig, token, cache, index, *,
                window: Optional[int] = None):
    """One decode step: token (B,) int32, cache from :func:`init_cache`,
    ``index`` = current absolute position. Returns (logits (B, vocab), cache)."""
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None], cfg.compute_dtype)
    if "pos_embed" in params:
        pos_idx = jnp.minimum(index, params["pos_embed"]["pos"].shape[0] - 1)
        x = x + params["pos_embed"]["pos"].astype(x.dtype)[pos_idx][None, None]

    nb, rem_kinds = _pattern_split(cfg)
    new_cache: Dict[str, Any] = {}

    if nb > 0:
        def body(x, xs):
            bp, bc = xs
            nc = {}
            for j, kind in enumerate(cfg.layer_pattern):
                x, nc[f"l{j}"] = _apply_layer_decode(bp[f"l{j}"], bc[f"l{j}"],
                                                     kind, cfg, x, index, window)
            return x, nc
        if cfg.scan_layers:
            x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        else:
            outs = []
            for i in range(nb):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                bc = jax.tree.map(lambda c: c[i], cache["blocks"])
                x, nci = body(x, (bp, bc))
                outs.append(nci)
            new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["blocks"] = new_blocks
    if rem_kinds:
        new_cache["tail"] = {}
        for j, kind in enumerate(rem_kinds):
            x, new_cache["tail"][f"t{j}"] = _apply_layer_decode(
                params["tail"][f"t{j}"], cache["tail"][f"t{j}"], kind, cfg, x,
                index, window)

    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = x.astype(jnp.float32) @ params["lm_head"]["w"].astype(jnp.float32)
    return logits[:, 0], new_cache
