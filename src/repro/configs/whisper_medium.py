"""Whisper-medium [arXiv:2212.04356] — enc-dec; mel+conv frontend stubbed.

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA), learned
decoder positions capped at 448 target positions, LayerNorm + GELU, tied
embeddings — the Whisper architecture. input_specs() provides the (B, 1500,
1024) frame embeddings the conv frontend would produce."""
import jax.numpy as jnp

from repro.config import AttentionConfig, EncoderConfig, ModelConfig, register_config


@register_config("whisper-medium")
def whisper_medium() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        d_ff=4096,
        vocab_size=51_865,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                                  use_rope=False),
        encoder=EncoderConfig(num_layers=24, source_len=1500),
        layer_pattern=("selfcross",),
        norm="layernorm",
        norm_eps=1e-5,
        act="gelu",
        tie_embeddings=True,
        max_target_positions=448,
        param_dtype=jnp.float32,
        citation="[arXiv:2212.04356]",
    )
