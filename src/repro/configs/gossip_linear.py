"""The paper's own model family: linear classifiers over fully distributed
data (Pegasos SVM / Adaline), one data record per peer.

These are not transformer configs; they parameterize ``repro.core`` — the
gossip protocol simulator and the on-mesh gossip runtime. Registered here so
``--arch gossip-linear-<dataset>`` selects the paper's exact experimental
setups (Table I). ``FAILURE_SCENARIOS`` names the shared failure operating
points (clean / the paper's extreme / the sparse-delivery regimes of
Fig. 5–7) used by the benchmarks and examples."""
import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class GossipLinearConfig:
    """One gossip-learning experimental setup (the paper's Table I rows).

    Consumed by ``repro.core.simulation.run_simulation`` (both the reference
    and the sharded engine) and by the dataset generators in
    ``repro.data.synthetic``. All protocol knobs live here; execution knobs
    (engine, mesh, Pallas, k_rounds, sampler) are ``run_simulation``
    arguments because they must not change the simulated protocol.

    Field guide — problem shape:

    * ``name``: dataset/config identifier (``--arch gossip-linear-<name>``).
    * ``dim``: feature dimension d — also the transmitted model size.
    * ``n_nodes``: network size N; the paper's fully distributed setting has
      one training record per node, so N = training-set size.
    * ``n_test``: held-out test records used for the error curves.
    * ``class_ratio``: (negative, positive) class counts of the dataset.

    Learning rule (Algorithm 2/3):

    * ``learner``: "pegasos" | "adaline" | "logistic" — the online update.
    * ``lam``: Pegasos regularization λ (its step size is 1/(λt)).
    * ``eta``: Adaline/logistic learning rate (unused by Pegasos).
    * ``cache_size``: per-node bounded model cache backing VOTEDPREDICT
      (Algorithm 4) — the paper's Fig. 3 voting curves use 10.
    * ``variant``: CREATEMODEL variant — "rw" (random walk, no merge),
      "mu" (merge-then-update, the paper's favored P2Pegasos), "um"
      (update-both-then-merge).

    Failure model (paper Section VI-A): the *extreme* scenario is
    ``drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9``.

    * ``drop_prob``: i.i.d. message-drop probability.
    * ``delay_max_cycles``: message delay drawn uniform in [Δ, max·Δ],
      quantized to whole cycles; 1 = next-cycle delivery.
    * ``online_fraction``: stationary fraction of nodes online under the
      lognormal churn trace (1.0 disables churn).

    Wire codec (beyond-paper, ``repro.core.wire_codec``):

    * ``wire_dtype``: name of the wire codec for the *transmitted* model —
      and of the simulator's in-flight payload buffer, the dominant memory
      at ``(delay_max, N, P)``. ``None``/"f32" = full precision;
      "bf16"/"f16" = half-precision cast; "int8"/"int8_sr" = per-message
      affine int8 (an f16 scale/zero-point pair rides with each message,
      +4 wire bytes; "_sr" rounds stochastically from a reproducible
      per-cycle threefry key); "int4"/"int4_ef" = symmetric ±7 codes
      packed two per byte (f16 scale, +2 wire bytes); "ternary"/
      "ternary_ef" = sign+scale codes packed five per byte base-3. The
      "_ef" variants keep a per-sender error-feedback residual
      (``SimState.ef`` — protocol state: what the coarse code lost rides
      on the next send). Merge arithmetic is always f32 — only the wire
      representation changes. Measured trade-offs:
      ``BENCH_wire_quantization.json`` and docs/ENGINES.md.

    Adversarial faults + defenses (beyond-paper, ``repro.core.faults``):

    * ``fault_model``: name of a registered fault model (``None`` = no
      fault injection, the default — fault-free runs are bitwise identical
      to the pre-fault engines). Model-kind faults ("sign_flip",
      "amplify", "zero", "random_payload", "stale_replay") make the
      Byzantine subset lie about its transmitted model before the wire
      encode; the wire-kind "bitflip" flips one bit of the encoded
      payload bytes after it.
    * ``byzantine_frac``: fraction of nodes (seed-chosen, static per run)
      that apply the fault on every send.
    * ``defense``: receive-side payload screen applied per merge round —
      "none", "norm_clip" (clip incoming L2 to a multiple of the
      receiver's own norm) or "cosine_gate" (reject payloads
      anti-aligned with the local model). Measured trade-offs:
      ``BENCH_robustness.json`` and docs/ENGINES.md.

    * ``citation``: provenance of the experimental setup."""
    name: str
    dim: int
    n_nodes: int
    n_test: int
    class_ratio: Tuple[int, int]
    learner: str = "pegasos"
    lam: float = 1e-4
    eta: float = 0.01
    cache_size: int = 10
    variant: str = "mu"
    drop_prob: float = 0.0
    delay_max_cycles: int = 1
    online_fraction: float = 1.0
    wire_dtype: Optional[str] = None
    fault_model: Optional[str] = None
    byzantine_frac: float = 0.0
    defense: str = "none"
    citation: str = "[DOI:10.1002/cpe.2858]"


# The paper's three datasets (Table I). In this offline container the raw UCI
# files are unavailable; repro.data.synthetic generates surrogate sets with
# the same dimensions/sizes/class ratios (documented in EXPERIMENTS.md §Paper).
REUTERS = GossipLinearConfig("reuters", dim=9947, n_nodes=2000, n_test=600,
                             class_ratio=(1300, 1300))
# λ calibrated on the surrogate so sequential Pegasos(20k) lands at the
# paper's Table-I floor (0.104 vs paper 0.111; λ=1e-4 gives 0.124)
SPAMBASE = GossipLinearConfig("spambase", dim=57, n_nodes=4140, n_test=461,
                              class_ratio=(1813, 2788), lam=1e-3)
MALICIOUS_URLS = GossipLinearConfig("malicious-urls", dim=10, n_nodes=10_000,
                                    n_test=2000, class_ratio=(7921, 16039))

DATASETS = {c.name: c for c in (REUTERS, SPAMBASE, MALICIOUS_URLS)}


# Named failure operating points (Section VI-A and the sparse-delivery
# regimes of Fig. 5-7, where only a few percent of the population receives
# per cycle): "extreme" is the paper's hardest published scenario; the
# "sparse-*" family crosses low online fractions with high drop under the
# 10Δ delay — the regimes the sharded engine's compact_all path targets.
FAILURE_SCENARIOS = {
    "clean": dict(drop_prob=0.0, delay_max_cycles=1, online_fraction=1.0),
    "extreme": dict(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9),
    "sparse-d0.5-o0.3": dict(drop_prob=0.5, delay_max_cycles=10,
                             online_fraction=0.3),
    "sparse-d0.5-o0.1": dict(drop_prob=0.5, delay_max_cycles=10,
                             online_fraction=0.1),
    "sparse-d0.8-o0.3": dict(drop_prob=0.8, delay_max_cycles=10,
                             online_fraction=0.3),
    "sparse-d0.8-o0.1": dict(drop_prob=0.8, delay_max_cycles=10,
                             online_fraction=0.1),
}


def with_failure_scenario(cfg: GossipLinearConfig,
                          scenario: str) -> GossipLinearConfig:
    """A copy of ``cfg`` with the named failure operating point applied.

    Every key of the scenario dict is validated against the
    ``GossipLinearConfig`` fields at apply time: a typo'd key in a
    scenario dict used to surface only as ``dataclasses.replace``'s
    generic TypeError (or, with ``**``-merging callers, silently) — now
    it fails loudly naming the offending keys."""
    try:
        overrides = FAILURE_SCENARIOS[scenario]
    except KeyError:
        raise ValueError(f"unknown failure scenario {scenario!r} "
                         f"(expected one of {sorted(FAILURE_SCENARIOS)})"
                         ) from None
    known = {f.name for f in dataclasses.fields(GossipLinearConfig)}
    bad = sorted(set(overrides) - known)
    if bad:
        raise ValueError(
            f"failure scenario {scenario!r} overrides unknown "
            f"GossipLinearConfig field(s) {bad} "
            f"(known fields: {sorted(known)})")
    return dataclasses.replace(cfg, **overrides)
