"""The paper's own model family: linear classifiers over fully distributed
data (Pegasos SVM / Adaline), one data record per peer.

These are not transformer configs; they parameterize ``repro.core`` — the
gossip protocol simulator and the on-mesh gossip runtime. Registered here so
``--arch gossip-linear-<dataset>`` selects the paper's exact experimental
setups (Table I)."""
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class GossipLinearConfig:
    name: str
    dim: int                      # feature dimension d
    n_nodes: int                  # network size N (= training set size)
    n_test: int
    class_ratio: Tuple[int, int]
    learner: str = "pegasos"      # pegasos | adaline | logistic
    lam: float = 1e-4             # Pegasos λ
    eta: float = 0.01             # Adaline learning rate
    cache_size: int = 10          # model cache for local voting (Alg. 4)
    variant: str = "mu"           # rw | mu | um (Alg. 2)
    # failure model (paper Section VI-A.i)
    drop_prob: float = 0.0        # extreme scenario: 0.5
    delay_max_cycles: int = 1     # extreme scenario: 10  (U[Δ, 10Δ])
    online_fraction: float = 1.0  # churn: 0.9 online at any time
    # wire quantization (beyond-paper): "bf16"/"f16" store the transmitted
    # model — and the simulator's in-flight payload buffer — in the reduced
    # dtype; merge arithmetic stays f32 (gossip_optimizer.resolve_wire_dtype)
    wire_dtype: Optional[str] = None
    citation: str = "[DOI:10.1002/cpe.2858]"


# The paper's three datasets (Table I). In this offline container the raw UCI
# files are unavailable; repro.data.synthetic generates surrogate sets with
# the same dimensions/sizes/class ratios (documented in EXPERIMENTS.md §Paper).
REUTERS = GossipLinearConfig("reuters", dim=9947, n_nodes=2000, n_test=600,
                             class_ratio=(1300, 1300))
# λ calibrated on the surrogate so sequential Pegasos(20k) lands at the
# paper's Table-I floor (0.104 vs paper 0.111; λ=1e-4 gives 0.124)
SPAMBASE = GossipLinearConfig("spambase", dim=57, n_nodes=4140, n_test=461,
                              class_ratio=(1813, 2788), lam=1e-3)
MALICIOUS_URLS = GossipLinearConfig("malicious-urls", dim=10, n_nodes=10_000,
                                    n_test=2000, class_ratio=(7921, 16039))

DATASETS = {c.name: c for c in (REUTERS, SPAMBASE, MALICIOUS_URLS)}
