"""Llama-3 405B [arXiv:2407.21783] — dense, GQA kv=8, 128k vocab."""
import jax.numpy as jnp

from repro.config import AttentionConfig, ModelConfig, register_config


@register_config("llama3-405b")
def llama3_405b() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16_384,
        d_ff=53_248,
        vocab_size=128_256,
        attention=AttentionConfig(num_heads=128, num_kv_heads=8, head_dim=128,
                                  rope_theta=500_000.0),
        layer_pattern=("attn",),
        param_dtype=jnp.bfloat16,
        citation="[arXiv:2407.21783]",
    )
