"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk-norm."""
import jax.numpy as jnp

from repro.config import AttentionConfig, ModelConfig, register_config


@register_config("qwen3-8b")
def qwen3_8b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        d_ff=12288,
        vocab_size=151_936,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                                  qk_norm=True, rope_theta=1_000_000.0),
        layer_pattern=("attn",),
        param_dtype=jnp.bfloat16,
        citation="[hf:Qwen/Qwen3-8B]",
    )
