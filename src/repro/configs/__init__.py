"""Assigned-architecture configs. Importing this package registers all ids.

ARCH_IDS lists the 10 assigned architectures; ``gossip-linear`` is the
paper's own model family (linear SVM / Adaline over fully distributed data).
"""
from repro.configs import (  # noqa: F401
    gossip_linear,
    llama3_405b,
    llama32_vision_11b,
    llama4_scout,
    mamba2_780m,
    mixtral_8x22b,
    qwen3_1p7b,
    qwen3_4b,
    qwen3_8b,
    recurrentgemma_9b,
    whisper_medium,
)

ARCH_IDS = [
    "llama-3.2-vision-11b",
    "qwen3-8b",
    "whisper-medium",
    "recurrentgemma-9b",
    "mamba2-780m",
    "qwen3-1.7b",
    "mixtral-8x22b",
    "qwen3-4b",
    "llama3-405b",
    "llama4-scout-17b-a16e",
]
