"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — dense, GQA kv=8, qk-norm."""
import jax.numpy as jnp

from repro.config import AttentionConfig, ModelConfig, register_config


@register_config("qwen3-1.7b")
def qwen3_1p7b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        num_layers=28,
        d_model=2048,
        d_ff=6144,
        vocab_size=151_936,
        attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=128,
                                  qk_norm=True, rope_theta=1_000_000.0),
        layer_pattern=("attn",),
        tie_embeddings=True,
        param_dtype=jnp.bfloat16,
        citation="[hf:Qwen/Qwen3-8B]",
    )
