"""RecurrentGemma-9B [arXiv:2402.19427] — RG-LRU + local attention, 2:1.

Pattern (rglru, rglru, local): two recurrent blocks per local-attention
block, window 2048 — the Griffin layout. 38 layers = 12 full periods + 2
remainder rglru layers (unrolled tail)."""
import jax.numpy as jnp

from repro.config import AttentionConfig, ModelConfig, RGLRUConfig, register_config


@register_config("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        d_ff=12_288,
        vocab_size=256_000,
        attention=AttentionConfig(num_heads=16, num_kv_heads=1, head_dim=256,
                                  rope_theta=10_000.0),
        rglru=RGLRUConfig(lru_width=4096, d_conv=4, num_heads=16, c=8.0,
                          local_window=2048),
        layer_pattern=("rglru", "rglru", "local"),
        act="gelu",
        param_dtype=jnp.bfloat16,
        citation="[arXiv:2402.19427]",
    )
