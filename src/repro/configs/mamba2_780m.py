"""Mamba2-780m [arXiv:2405.21060] — attention-free SSD, d_ff=0."""
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig, register_config


@register_config("mamba2-780m")
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        d_ff=0,                      # attention-free, FFN-free (Mamba block only)
        vocab_size=50_280,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                      chunk_size=256),
        layer_pattern=("ssm",),
        tie_embeddings=True,
        param_dtype=jnp.float32,
        citation="[arXiv:2405.21060]",
    )
