"""Mixtral-8x22B [arXiv:2401.04088] — MoE 8 experts top-2, sliding window.

Expert-sharding note: 8 experts cannot split over the 16-way model axis, so
this config uses the 'tensor' expert-sharding profile (expert d_ff on the
model axis — no all-to-all); contrast with llama4-scout's 'expert' profile.
"""
import jax.numpy as jnp

from repro.config import AttentionConfig, MoEConfig, ModelConfig, register_config


@register_config("mixtral-8x22b")
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        d_ff=16_384,
        vocab_size=32_768,
        attention=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128,
                                  rope_theta=1_000_000.0,
                                  sliding_window=4096),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16_384,
                      sharding="tensor"),
        layer_pattern=("attn",),
        param_dtype=jnp.bfloat16,
        citation="[arXiv:2401.04088]",
    )
