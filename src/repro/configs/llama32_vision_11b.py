"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision] — VLM.

40 layers: gated cross-attention to vision patch embeddings every 5th layer
(pattern: 4 self + 1 cross, 8 periods). The ViT encoder + projector are
stubbed per the carve-out; input_specs() provides (B, 1601, 4096) patch
embeddings (one 448px tile -> 1601 patches)."""
import jax.numpy as jnp

from repro.config import AttentionConfig, CrossAttnConfig, ModelConfig, register_config


@register_config("llama-3.2-vision-11b")
def llama32_vision_11b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        num_layers=40,
        d_model=4096,
        d_ff=14_336,
        vocab_size=128_256,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                                  rope_theta=500_000.0),
        cross_attn=CrossAttnConfig(every_n_layers=5, source_len=1601, gated=True),
        layer_pattern=("attn", "attn", "attn", "attn", "cross"),
        param_dtype=jnp.bfloat16,
        citation="[hf:meta-llama/Llama-3.2-11B-Vision]",
    )
