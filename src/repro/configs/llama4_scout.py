"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE 16e top-1.

16 experts divide the 16-way model axis exactly -> 'expert' sharding profile
(expert parallelism; the dispatch scatter lowers to an all-to-all)."""
import jax.numpy as jnp

from repro.config import AttentionConfig, MoEConfig, ModelConfig, register_config


@register_config("llama4-scout-17b-a16e")
def llama4_scout() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        d_ff=8192,
        vocab_size=202_048,
        attention=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                                  rope_theta=500_000.0,
                                  sliding_window=8192),
        moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                      sharding="expert"),
        layer_pattern=("attn",),
        param_dtype=jnp.bfloat16,
        citation="[hf:meta-llama/Llama-4-Scout-17B-16E]",
    )
