"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_config(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def _ensure_loaded() -> None:
    # importing repro.configs registers everything
    import repro.configs  # noqa: F401


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def reduced_config(cfg: ModelConfig, *, d_model: int = 256, layers: int = 2,
                   vocab: int = 512, max_experts: int = 4) -> ModelConfig:
    """Mechanically shrink a config for CPU smoke tests.

    Keeps the family, layer pattern, GQA ratio, qk-norm, windowing, MoE
    top-k structure — everything that defines the architecture — while
    reducing widths to CPU scale (<=512 d_model, 2 layers, <=4 experts)."""
    import jax.numpy as jnp

    attn = cfg.attention
    if attn is not None:
        ratio = max(1, attn.q_per_kv)
        heads = max(ratio, 4)
        heads -= heads % ratio
        head_dim = max(16, d_model // heads)
        head_dim -= head_dim % 8          # even head_dim for RoPE halves
        d_model = heads * head_dim
        attn = dataclasses.replace(
            attn, num_heads=heads, num_kv_heads=max(1, heads // ratio),
            head_dim=head_dim,
            sliding_window=min(attn.sliding_window, 64) if attn.sliding_window else None)
    moe = cfg.moe
    if moe is not None:
        n_e = min(moe.num_experts, max_experts)
        moe = dataclasses.replace(
            moe, num_experts=n_e, top_k=min(moe.top_k, n_e),
            d_ff_expert=2 * d_model)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, d_state=32, head_dim=32, chunk_size=32)
    rglru = cfg.rglru
    if rglru is not None:
        rglru = dataclasses.replace(rglru, lru_width=d_model, local_window=32,
                                    num_heads=4)
    enc = cfg.encoder
    if enc is not None:
        enc = dataclasses.replace(enc, num_layers=2, source_len=16)
    cross = cfg.cross_attn
    if cross is not None:
        # keep the pattern period so the super-block scan path is exercised
        cross = dataclasses.replace(cross, source_len=16)
        layers = max(layers, cross.every_n_layers)
    if cfg.rglru is not None:
        layers = max(layers, len(cfg.layer_pattern))
    return cfg.replace(
        name=cfg.name + "-smoke",
        num_layers=layers, d_model=d_model,
        d_ff=2 * d_model, vocab_size=vocab,
        attention=attn, moe=moe, ssm=ssm, rglru=rglru, encoder=enc,
        cross_attn=cross,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        max_target_positions=min(cfg.max_target_positions, 64) if cfg.max_target_positions else 0,
        remat=False)
