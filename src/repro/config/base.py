"""Dataclass config system.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
reduced smoke variants are derived mechanically (see ``registry.reduced_config``).
``InputShape`` captures the four assigned workload shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    sliding_window: Optional[int] = None   # None = full attention
    causal: bool = True

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # 'expert'  -> experts sharded over the model axis (all-to-all dispatch);
    # 'tensor'  -> expert d_ff sharded over the model axis (no all-to-all).
    sharding: str = "expert"
    # GShard-style grouped dispatch: tokens are split into this many groups
    # (the data-parallel shard count), each with its OWN capacity computed
    # from the group's token count. 1 = ungrouped (global capacity — only
    # correct on a single device; under pjit it materializes the full
    # (E, C_global, D) buffer on every device). Set by the step builders to
    # the batch-shard size. See EXPERIMENTS.md §Perf (mixtral hillclimb).
    dispatch_groups: int = 1
    # 'gather' — combine gathers from the psum'd (G,E,C,D) buffer;
    # 'reduce' — manual shard_map combine-before-reduce for 'tensor' mode
    # (TP all-reduce operand T*D instead of E*C*D; §Perf B-4). Set by the
    # step builders; falls back to 'gather' without a mesh context.
    combine: str = "gather"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block config [arXiv:2405.21060]."""

    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    d_conv: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block config (Griffin / RecurrentGemma [arXiv:2402.19427])."""

    lru_width: int = 0           # 0 -> d_model
    d_conv: int = 4
    num_heads: int = 0           # block-diagonal gate heads; 0 -> attention heads
    c: float = 8.0               # the fixed exponent scale from the paper
    local_window: int = 2048     # window of the interleaved local-attention layers


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec backbones (whisper). Frontend is stubbed:
    input_specs() provides precomputed (B, source_len, d_model) frame embeddings."""

    num_layers: int
    source_len: int              # 1500 frames for whisper-medium (30 s)
    d_model: int = 0             # 0 -> decoder d_model
    causal: bool = False


@dataclass(frozen=True)
class CrossAttnConfig:
    """Interleaved gated cross-attention (Llama-3.2-Vision style)."""

    every_n_layers: int          # one cross-attn layer per this many layers
    source_len: int              # e.g. 1601 patch embeddings per image tile
    gated: bool = True


# ---------------------------------------------------------------------------
# the model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None
    # repeating layer pattern; 'attn' (global), 'local' (sliding window),
    # 'rglru', 'ssm', 'cross'. The full layer stack is the pattern tiled to
    # num_layers (remainder layers take the pattern prefix).
    layer_pattern: Tuple[str, ...] = ("attn",)
    norm_eps: float = 1e-6
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    act: str = "swiglu"               # swiglu | gelu
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    max_target_positions: int = 0     # 0 = unbounded (rope); whisper: 448
    remat: bool = True
    scan_layers: bool = True
    citation: str = ""
    # attention implementation: 'chunked' (memory-efficient lax.scan over
    # query blocks — the XLA-level flash equivalent), 'xla' (naive full
    # logits), or 'pallas' (the flash kernel; TPU backends)
    attn_impl: str = "chunked"
    attn_chunk: int = 512          # query-block size for 'chunked'
    xent_chunk: int = 512          # sequence-chunk for the chunked xent loss

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- derived quantities ------------------------------------------------

    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.layer_pattern
        reps, rem = divmod(self.num_layers, len(p))
        return p * reps + p[:rem]

    def param_count(self) -> int:
        """Total parameter count (embedding + layers + head), exact for our
        implementation (used for MODEL_FLOPS = 6·N·D roofline term)."""
        from repro.models.transformer import model_spec
        from repro.models.layers import spec_param_count
        return spec_param_count(model_spec(self))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts count)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        # per-expert FFN params (3 matrices for swiglu, 2 for gelu)
        nmat = 3 if self.act == "swiglu" else 2
        per_expert = nmat * self.d_model * m.d_ff_expert
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in ("attn", "local"))
        # MoE replaces the dense FFN in every layer for our moe configs
        n_moe_layers = self.num_layers
        inactive = (m.num_experts - m.top_k) * per_expert * n_moe_layers
        return total - inactive


# ---------------------------------------------------------------------------
# workload shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# run / distribution configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def multi_pod(self) -> bool:
        return self.pods > 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class GossipConfig:
    """Layer-B gossip optimizer settings (the paper's protocol on the mesh)."""

    enabled: bool = True
    schedule: str = "hypercube"    # hypercube | ring | random
    merge: str = "mu"              # mu | um | rw  (rw = no merge: plain local SGD)
    pod_every: int = 8             # gossip across the pod axis every K steps
    seed: int = 0
    # beyond-paper: wire dtype for the exchanged model ("" = param dtype;
    # "bf16" halves the sync wire, averaging still in f32)
    exchange_dtype: str = ""


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 1024
    global_batch: int = 32
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    optimizer: str = "adamw"       # adamw | sgdm | pegasos
    grad_clip: float = 1.0
    seed: int = 0
    log_every: int = 10
    eval_every: int = 0
    checkpoint_every: int = 0
    checkpoint_dir: str = ""


@dataclass(frozen=True)
class ServeConfig:
    max_seq_len: int = 4096
    batch_size: int = 8
    prefill_len: int = 512
    decode_steps: int = 64
    window: Optional[int] = None   # windowed KV cache (ring buffer) if set


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    gossip: GossipConfig = field(default_factory=GossipConfig)
