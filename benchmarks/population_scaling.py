"""Population-scaling sweep: N = 10^3 .. 10^6 nodes, reference vs sharded.

The paper's PeerSim runs stop near N ~ 10^4; related work ("On the Limit
Performance of Floating Gossip") analyzes exactly the N→∞ regime. This bench
measures node-cycles/sec for both engines over the sweep — the reference
engine is measured only up to ``REF_MAX_N`` (its per-cycle host loop makes
larger N pointless), the sharded engine goes to a million nodes.

    PYTHONPATH=src python -m benchmarks.population_scaling [--quick]
    PYTHONPATH=src python -m benchmarks.run --only population_scaling

Output columns: engine, n_nodes, cycles, seconds, node_cycles_per_sec,
final err_fresh (sanity: learning actually happens at every scale).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, write_csv

REF_MAX_N = 100_000            # reference engine measured up to here
SPEEDUP_AT_N = 100_000         # the acceptance-criterion comparison point


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, d: int):
    from repro.configs.gossip_linear import GossipLinearConfig
    # The paper's extreme failure scenario (Fig. 1 lower row): 50% message
    # drop and delays uniform in [Δ, 10Δ] — also the regime where the
    # reference engine's dense (delay_max, N) slot handling is most honest
    # to measure. cache_size 4 keeps the (N, C, d) cache at 160 MB for
    # N=10^6; online_fraction 1.0 keeps host churn-trace generation O(1)
    # so the timing isolates the engines.
    return GossipLinearConfig(name=f"scale-{n}", dim=d, n_nodes=n,
                              n_test=512, class_ratio=(1, 1), lam=1e-3,
                              variant="mu", cache_size=4,
                              drop_prob=0.5, delay_max_cycles=10)


def run(quick: bool = False) -> dict:
    from repro.core.simulation import run_simulation

    d = 10                                      # malicious-urls-sized features
    cycles = 20 if quick else 50
    # k_rounds=8 bounds per-cycle receive truncation to ~zero (overflow≈0),
    # matching the paper's event simulator, which never drops simultaneous
    # arrivals; both engines run the identical protocol parameters.
    k_rounds = 8
    sweep = [1_000, 10_000, 100_000] if quick else [
        1_000, 10_000, 100_000, 1_000_000]
    ref_max = 10_000 if quick else REF_MAX_N

    rows = []
    rates: dict = {}
    for n in sweep:
        X, y, Xt, yt = _dataset(n, d)
        cfg = _cfg(n, d)
        for engine in ("reference", "sharded"):
            if engine == "reference" and n > ref_max:
                continue
            # warm-up run compiles (same chunk length as the timed run);
            # the timed run measures steady state. eval_every=10 gives
            # paper-style curves and lets the sharded engine pipeline host
            # routing against the in-flight device scan.
            run_simulation(cfg, X, y, Xt, yt, cycles=cycles,
                           eval_every=10, seed=0, engine=engine,
                           k_rounds=k_rounds)
            with Timer() as t:
                res = run_simulation(cfg, X, y, Xt, yt, cycles=cycles,
                                     eval_every=10, seed=0,
                                     engine=engine, k_rounds=k_rounds)
            rate = n * cycles / t.s
            rates[(engine, n)] = rate
            rows.append((engine, n, cycles, f"{t.s:.3f}", f"{rate:.0f}",
                         f"{res.err_fresh[-1]:.4f}"))
            print("population_scaling," + ",".join(str(x) for x in rows[-1]))

    cmp_n = min(SPEEDUP_AT_N, ref_max)
    if ("reference", cmp_n) in rates and ("sharded", cmp_n) in rates:
        speedup = rates[("sharded", cmp_n)] / rates[("reference", cmp_n)]
        print(f"population_scaling,speedup@N={cmp_n},{speedup:.1f}x")
    write_csv("population_scaling",
              "engine,n_nodes,cycles,seconds,node_cycles_per_sec,err_fresh",
              rows)
    return rates


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
