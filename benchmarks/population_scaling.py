"""Population-scaling sweep: N = 10^3 .. 10^6 nodes, reference vs sharded.

The paper's PeerSim runs stop near N ~ 10^4; related work ("On the Limit
Performance of Floating Gossip") analyzes exactly the N→∞ regime. This bench
measures node-cycles/sec over the sweep on two scenario families:

* **extreme** (Fig. 1 lower row): 50% drop, delays uniform in [Δ, 10Δ],
  90%-online churn — for ``reference`` (up to ``REF_MAX_N``),
  ``sharded-dense`` (PR 1's dense K-round apply), ``sharded`` (occupancy-
  chosen compacted rounds) and ``sharded-bf16`` (+ bf16 wire dtype);
* **sparse delivery** (the Fig. 5–7 robustness regimes): online fraction
  0.1/0.3 crossed with drop 0.5/0.8 under the 10Δ delay — where only a few
  percent of the population receives per cycle. Here ``sharded-r1dense``
  pins the PR 3 packing (round 1 applied densely, ``compact_mode=
  "compact"``) against ``sharded`` (free to pick the delivery-proportional
  ``compact_all`` packing), so the JSON's ``derived`` speedups record
  exactly what round-1 compaction buys.

    PYTHONPATH=src python -m benchmarks.population_scaling [--quick]
    PYTHONPATH=src python -m benchmarks.run --only population_scaling

Output: CSV rows (results/benchmarks/) plus the machine-readable perf
trajectory ``BENCH_population_scaling.json`` at the repo root — per-row
node-cycles/sec, buffer/wire bytes, compaction telemetry (chunk modes,
round-1 occupancy), the sparse-vs-dense ``derived`` speedups, a bitwise
cross-engine parity probe per wire dtype, and the N=10^6 churn-trace
generation time. ``tools/check_bench_regression.py`` compares a fresh run
against the committed JSON and fails loudly on perf regressions.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, best_of, write_bench_json, write_csv

REF_MAX_N = 100_000            # reference engine measured up to here
SPEEDUP_AT_N = 100_000         # the acceptance-criterion comparison point
CHURN_TRACE_N = 1_000_000      # churn-trace generation is timed at this N
PARITY_N = 2_000               # cross-engine bitwise probe population


def _retrace_total() -> int:
    """Total jit compile-cache entries across both engines' hot-path fns
    (tools/lint/retrace_guard.py is the hard gate; the bench records the
    per-row delta so retrace churn shows up in the perf trajectory)."""
    from repro.core import sharded_engine, simulation
    return (sum(sharded_engine.retrace_counts().values())
            + simulation.simulate_cycle._cache_size()
            + simulation._eval._cache_size())


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, d: int, scenario: str, wire_dtype=None):
    from repro.configs.gossip_linear import (GossipLinearConfig,
                                             with_failure_scenario)
    # cache_size 4 keeps the (N, C, d) cache at 160 MB for N=10^6.
    base = GossipLinearConfig(name=f"scale-{n}", dim=d, n_nodes=n,
                              n_test=512, class_ratio=(1, 1), lam=1e-3,
                              variant="mu", cache_size=4,
                              wire_dtype=wire_dtype)
    return with_failure_scenario(base, scenario)


# label -> (cfg wire_dtype, run_simulation engine kwargs)
EXTREME_VARIANTS = [
    ("reference", None, dict(engine="reference")),
    ("sharded-dense", None, dict(engine="sharded", compact_rounds=False)),
    ("sharded", None, dict(engine="sharded", compact_rounds=True)),
    ("sharded-bf16", "bf16", dict(engine="sharded", compact_rounds=True)),
]

# sparse family: the PR 3 packing (round 1 dense) vs the free engine
SPARSE_VARIANTS = [
    ("sharded-r1dense", None, dict(engine="sharded",
                                   compact_mode="compact")),
    ("sharded", None, dict(engine="sharded")),
]

SPARSE_SCENARIOS = ["sparse-d0.5-o0.3", "sparse-d0.5-o0.1",
                    "sparse-d0.8-o0.3", "sparse-d0.8-o0.1"]


def _parity_probe(d: int, cycles: int, k_rounds: int) -> dict:
    """Bitwise cross-engine probe on the hardest sparse scenario: for every
    wire dtype, reference == sharded-auto == sharded-dense error curves."""
    from repro.core.simulation import run_simulation

    X, y, Xt, yt = _dataset(PARITY_N, d)
    out = {}
    for wire in [None, "bf16", "f16", "int8", "int8_sr"]:
        cfg = _cfg(PARITY_N, d, "sparse-d0.8-o0.1", wire_dtype=wire)
        kw = dict(cycles=cycles, eval_every=10, seed=0, k_rounds=k_rounds)
        ref = run_simulation(cfg, X, y, Xt, yt, **kw)
        auto = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
        dense = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                               compact_rounds=False, **kw)
        out[wire or "f32"] = bool(
            ref.err_fresh == auto.err_fresh == dense.err_fresh
            and ref.err_voted == auto.err_voted == dense.err_voted
            and ref.sent_total == auto.sent_total == dense.sent_total)
    return out


def run(quick: bool = False) -> dict:
    from repro.core.simulation import (CHURN_TRACE_VERSION, churn_trace,
                                       run_simulation)

    d = 10                                      # malicious-urls-sized features
    cycles = 20 if quick else 50
    # k_rounds=8 bounds per-cycle receive truncation to ~zero (overflow≈0),
    # matching the paper's event simulator, which never drops simultaneous
    # arrivals; all engines run the identical protocol parameters.
    k_rounds = 8
    sweep = [1_000, 10_000, 100_000] if quick else [
        1_000, 10_000, 100_000, 1_000_000]
    sparse_sweep = [100_000] if quick else [100_000, 1_000_000]
    ref_max = 10_000 if quick else REF_MAX_N

    rows = []
    json_rows = []
    rates: dict = {}
    results: dict = {}

    def measure(label, scenario, n, wire, kw, X, y, Xt, yt):
        cfg = _cfg(n, d, scenario, wire_dtype=wire)
        # warm-up run compiles (same chunk length as the timed run); the
        # timed runs measure steady state via the shared min-time estimator
        # (telemetry.best_of) — the shared 2-core container's noise is
        # strictly additive. eval_every=10 gives paper-style curves and
        # lets the sharded engine pipeline host routing against the
        # in-flight device scan.
        traces0 = _retrace_total()
        run_simulation(cfg, X, y, Xt, yt, cycles=cycles,
                       eval_every=10, seed=0, k_rounds=k_rounds, **kw)
        best, secs, res = best_of(
            lambda: run_simulation(cfg, X, y, Xt, yt, cycles=cycles,
                                   eval_every=10, seed=0,
                                   k_rounds=k_rounds, **kw))
        rate = n * cycles / best
        rates[(label, scenario, n)] = rate
        results[(label, scenario, n)] = res
        rows.append((label, scenario, n, cycles, f"{best:.3f}",
                     f"{rate:.0f}", f"{res.err_fresh[-1]:.4f}",
                     wire or "f32", res.buf_payload_bytes,
                     res.wire_bytes_total))
        dpc = np.asarray(res.delivered_per_cycle, dtype=np.float64)
        json_rows.append(dict(
            engine=label, scenario=scenario, n_nodes=n, cycles=cycles,
            seconds=best, seconds_all=secs, node_cycles_per_sec=rate,
            err_fresh=res.err_fresh[-1], wire_dtype=wire or "f32",
            buf_payload_bytes=res.buf_payload_bytes,
            wire_bytes_total=res.wire_bytes_total,
            sent_total=res.sent_total,
            delivered_total=res.delivered_total,
            delivered_per_cycle_mean=float(dpc.mean()) if dpc.size else 0.0,
            # compiles this row triggered (warm-up included; the timed
            # runs reuse the warm-up's traces, so steady state adds zero)
            retraces=_retrace_total() - traces0,
            compaction=res.compaction))
        print("population_scaling," + ",".join(str(x) for x in rows[-1]))

    for n in sweep:
        X, y, Xt, yt = _dataset(n, d)
        for label, wire, kw in EXTREME_VARIANTS:
            if label == "reference" and n > ref_max:
                continue
            measure(label, "extreme", n, wire, kw, X, y, Xt, yt)

    for n in sparse_sweep:
        X, y, Xt, yt = _dataset(n, d)
        for scenario in SPARSE_SCENARIOS:
            for label, wire, kw in SPARSE_VARIANTS:
                measure(label, scenario, n, wire, kw, X, y, Xt, yt)

    # churn-trace generation cost at mega-population scale (acceptance:
    # the v2 vectorized sampler stays well under ~2 s at N=10^6)
    with Timer() as t_trace:
        churn_trace(np.random.default_rng(0), CHURN_TRACE_N, cycles, 0.9)
    print(f"population_scaling,churn_trace,v{CHURN_TRACE_VERSION},"
          f"n={CHURN_TRACE_N},cycles={cycles},{t_trace.s:.3f}s")

    parity = _parity_probe(d, cycles=20, k_rounds=k_rounds)
    print("population_scaling,parity," + ",".join(
        f"{k}={'bitwise' if v else 'MISMATCH'}" for k, v in parity.items()))

    derived: dict = {}
    cmp_n = min(SPEEDUP_AT_N, ref_max)
    if (("reference", "extreme", cmp_n) in rates
            and ("sharded", "extreme", cmp_n) in rates):
        speedup = (rates[("sharded", "extreme", cmp_n)]
                   / rates[("reference", "extreme", cmp_n)])
        derived[f"sharded_vs_reference_speedup_at_{cmp_n}"] = speedup
        print(f"population_scaling,speedup@N={cmp_n},{speedup:.1f}x")
    top_n = sweep[-1]
    if ("sharded-dense", "extreme", top_n) in rates:
        compact_speedup = (rates[("sharded", "extreme", top_n)]
                           / rates[("sharded-dense", "extreme", top_n)])
        derived[f"compact_vs_dense_speedup_at_{top_n}"] = compact_speedup
        print(f"population_scaling,compact_speedup@N={top_n},"
              f"{compact_speedup:.2f}x")
    if ("sharded-bf16", "extreme", top_n) in results:
        ratio = (results[("sharded-bf16", "extreme", top_n)].buf_payload_bytes
                 / results[("sharded", "extreme", top_n)].buf_payload_bytes)
        derived[f"bf16_payload_buffer_ratio_at_{top_n}"] = ratio
        print(f"population_scaling,bf16_buffer_ratio@N={top_n},{ratio:.2f}")
    sparse_top = sparse_sweep[-1]
    for scenario in SPARSE_SCENARIOS:
        a = rates.get(("sharded", scenario, sparse_top))
        b = rates.get(("sharded-r1dense", scenario, sparse_top))
        if a and b:
            key = f"r1compact_vs_r1dense_speedup_at_{sparse_top}_{scenario}"
            derived[key] = a / b
            print(f"population_scaling,r1compact_speedup@N={sparse_top},"
                  f"{scenario},{a / b:.2f}x")

    write_csv("population_scaling",
              "engine,scenario,n_nodes,cycles,seconds,node_cycles_per_sec,"
              "err_fresh,wire_dtype,buf_payload_bytes,wire_bytes_total",
              rows)
    from repro.configs.gossip_linear import FAILURE_SCENARIOS
    write_bench_json("population_scaling", dict(
        bench="population_scaling",
        quick=quick,
        protocol=dict(k_rounds=k_rounds, dim=d, cycles=cycles,
                      variant="mu", cache_size=4),
        scenarios={name: FAILURE_SCENARIOS[name]
                   for name in ["extreme"] + SPARSE_SCENARIOS},
        rows=json_rows,
        parity_bitwise=parity,
        churn_trace=dict(version=CHURN_TRACE_VERSION, n_nodes=CHURN_TRACE_N,
                         cycles=cycles, seconds=t_trace.s),
        derived=derived,
    ))
    return rates


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
