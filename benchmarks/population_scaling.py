"""Population-scaling sweep: N = 10^3 .. 10^6 nodes, reference vs sharded.

The paper's PeerSim runs stop near N ~ 10^4; related work ("On the Limit
Performance of Floating Gossip") analyzes exactly the N→∞ regime. This bench
measures node-cycles/sec over the sweep on the paper's FULL extreme scenario
— 50% message drop, delays uniform in [Δ, 10Δ] AND 90%-online churn (the
vectorized v2 trace makes churned 10^6 populations cheap to set up) — for:

* ``reference``       the per-cycle driver (measured up to ``REF_MAX_N``);
* ``sharded-dense``   PR 1's dense K-round apply (``compact_rounds=False``);
* ``sharded``         compacted multi-receive rounds (the default path);
* ``sharded-bf16``    compacted + bf16 wire dtype (halved payload buffer).

    PYTHONPATH=src python -m benchmarks.population_scaling [--quick]
    PYTHONPATH=src python -m benchmarks.run --only population_scaling

Output: CSV rows (results/benchmarks/) plus the machine-readable perf
trajectory ``BENCH_population_scaling.json`` at the repo root — per-N
node-cycles/sec, in-flight payload buffer bytes, wire bytes, and the
N=10^6 churn-trace generation time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, write_bench_json, write_csv

REF_MAX_N = 100_000            # reference engine measured up to here
SPEEDUP_AT_N = 100_000         # the acceptance-criterion comparison point
CHURN_TRACE_N = 1_000_000      # churn-trace generation is timed at this N


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, d: int, wire_dtype=None):
    from repro.configs.gossip_linear import GossipLinearConfig
    # The paper's full extreme failure scenario (Fig. 1 lower row): 50%
    # message drop, delays uniform in [Δ, 10Δ], and churn with 90% of nodes
    # online at any time. cache_size 4 keeps the (N, C, d) cache at 160 MB
    # for N=10^6.
    return GossipLinearConfig(name=f"scale-{n}", dim=d, n_nodes=n,
                              n_test=512, class_ratio=(1, 1), lam=1e-3,
                              variant="mu", cache_size=4,
                              drop_prob=0.5, delay_max_cycles=10,
                              online_fraction=0.9, wire_dtype=wire_dtype)


# label -> (cfg wire_dtype, run_simulation engine kwargs)
VARIANTS = [
    ("reference", None, dict(engine="reference")),
    ("sharded-dense", None, dict(engine="sharded", compact_rounds=False)),
    ("sharded", None, dict(engine="sharded", compact_rounds=True)),
    ("sharded-bf16", "bf16", dict(engine="sharded", compact_rounds=True)),
]


def run(quick: bool = False) -> dict:
    from repro.core.simulation import (CHURN_TRACE_VERSION, churn_trace,
                                       run_simulation)

    d = 10                                      # malicious-urls-sized features
    cycles = 20 if quick else 50
    # k_rounds=8 bounds per-cycle receive truncation to ~zero (overflow≈0),
    # matching the paper's event simulator, which never drops simultaneous
    # arrivals; all engines run the identical protocol parameters.
    k_rounds = 8
    sweep = [1_000, 10_000, 100_000] if quick else [
        1_000, 10_000, 100_000, 1_000_000]
    ref_max = 10_000 if quick else REF_MAX_N

    rows = []
    json_rows = []
    rates: dict = {}
    results: dict = {}
    for n in sweep:
        X, y, Xt, yt = _dataset(n, d)
        for label, wire, kw in VARIANTS:
            if label == "reference" and n > ref_max:
                continue
            cfg = _cfg(n, d, wire_dtype=wire)
            # warm-up run compiles (same chunk length as the timed run);
            # the timed run measures steady state. eval_every=10 gives
            # paper-style curves and lets the sharded engine pipeline host
            # routing against the in-flight device scan.
            run_simulation(cfg, X, y, Xt, yt, cycles=cycles,
                           eval_every=10, seed=0, k_rounds=k_rounds, **kw)
            with Timer() as t:
                res = run_simulation(cfg, X, y, Xt, yt, cycles=cycles,
                                     eval_every=10, seed=0,
                                     k_rounds=k_rounds, **kw)
            rate = n * cycles / t.s
            rates[(label, n)] = rate
            results[(label, n)] = res
            rows.append((label, n, cycles, f"{t.s:.3f}", f"{rate:.0f}",
                         f"{res.err_fresh[-1]:.4f}", wire or "f32",
                         res.buf_payload_bytes, res.wire_bytes_total))
            json_rows.append(dict(
                engine=label, n_nodes=n, cycles=cycles, seconds=t.s,
                node_cycles_per_sec=rate, err_fresh=res.err_fresh[-1],
                wire_dtype=wire or "f32",
                buf_payload_bytes=res.buf_payload_bytes,
                wire_bytes_total=res.wire_bytes_total,
                sent_total=res.sent_total,
                delivered_total=res.delivered_total))
            print("population_scaling," + ",".join(str(x) for x in rows[-1]))

    # churn-trace generation cost at mega-population scale (acceptance:
    # the v2 vectorized sampler stays well under ~2 s at N=10^6)
    with Timer() as t_trace:
        churn_trace(np.random.default_rng(0), CHURN_TRACE_N, cycles, 0.9)
    print(f"population_scaling,churn_trace,v{CHURN_TRACE_VERSION},"
          f"n={CHURN_TRACE_N},cycles={cycles},{t_trace.s:.3f}s")

    derived: dict = {}
    cmp_n = min(SPEEDUP_AT_N, ref_max)
    if ("reference", cmp_n) in rates and ("sharded", cmp_n) in rates:
        speedup = rates[("sharded", cmp_n)] / rates[("reference", cmp_n)]
        derived[f"sharded_vs_reference_speedup_at_{cmp_n}"] = speedup
        print(f"population_scaling,speedup@N={cmp_n},{speedup:.1f}x")
    top_n = sweep[-1]
    if ("sharded-dense", top_n) in rates:
        compact_speedup = rates[("sharded", top_n)] / rates[("sharded-dense", top_n)]
        derived[f"compact_vs_dense_speedup_at_{top_n}"] = compact_speedup
        print(f"population_scaling,compact_speedup@N={top_n},"
              f"{compact_speedup:.2f}x")
    if ("sharded-bf16", top_n) in results:
        ratio = (results[("sharded-bf16", top_n)].buf_payload_bytes
                 / results[("sharded", top_n)].buf_payload_bytes)
        derived[f"bf16_payload_buffer_ratio_at_{top_n}"] = ratio
        print(f"population_scaling,bf16_buffer_ratio@N={top_n},{ratio:.2f}")

    write_csv("population_scaling",
              "engine,n_nodes,cycles,seconds,node_cycles_per_sec,err_fresh,"
              "wire_dtype,buf_payload_bytes,wire_bytes_total",
              rows)
    write_bench_json("population_scaling", dict(
        bench="population_scaling",
        quick=quick,
        scenario=dict(drop_prob=0.5, delay_max_cycles=10,
                      online_fraction=0.9, k_rounds=k_rounds, dim=d,
                      cycles=cycles, variant="mu", cache_size=4),
        rows=json_rows,
        churn_trace=dict(version=CHURN_TRACE_VERSION, n_nodes=CHURN_TRACE_N,
                         cycles=cycles, seconds=t_trace.s),
        derived=derived,
    ))
    return rates


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
