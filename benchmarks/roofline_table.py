"""Render the §Roofline table from the dry-run artifacts (results/dryrun)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import write_csv

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"
RESULTS_OPT = Path(__file__).resolve().parent.parent / "results" / "dryrun_opt"


def load_records(mesh: str = "single", dist: str = "allreduce"):
    recs = []
    for fp in sorted(RESULTS.glob(f"*__{mesh}__{dist}.json")):
        recs.append(json.loads(fp.read_text()))
    return recs


def compare(quick: bool = False):
    """v0 vs optimized roofline per (arch x shape), single pod."""
    rows = []
    for fp in sorted(RESULTS.glob("*__single__allreduce.json")):
        b = json.loads(fp.read_text())
        op = RESULTS_OPT / fp.name
        if b.get("status") != "ok" or not op.exists():
            continue
        o = json.loads(op.read_text())
        t0 = b["compute_s"] + b["memory_s"] + b["collective_s"]
        t1 = o["compute_s"] + o["memory_s"] + o["collective_s"]
        rows.append((b["arch"], b["shape"], round(t0, 3), round(t1, 3),
                     round(t0 / max(t1, 1e-12), 2), b["dominant"],
                     o["dominant"], round(b["useful_ratio"], 2),
                     round(o["useful_ratio"], 2)))
        print(f"roofline_compare,{b['arch']},{b['shape']},v0={t0:.3f}s,"
              f"opt={t1:.3f}s,speedup={t0/max(t1,1e-12):.2f}x")
    if rows:
        write_csv("roofline_compare",
                  "arch,shape,v0_total_s,opt_total_s,speedup,"
                  "v0_dominant,opt_dominant,v0_useful,opt_useful", rows)
    return rows


def run(quick: bool = False):
    compare(quick)
    rows = []
    for r in load_records():
        if r.get("status") == "skip":
            rows.append((r["arch"], r["shape"], "skip", 0, 0, 0, "-", 0,
                         r.get("reason", "")[:40]))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], "FAIL", 0, 0, 0, "-", 0, ""))
            continue
        rows.append((
            r["arch"], r["shape"], "ok",
            round(r["compute_s"] * 1e3, 3),
            round(r["memory_s"] * 1e3, 3),
            round(r["collective_s"] * 1e3, 3),
            r["dominant"],
            round(r["useful_ratio"], 3),
            "+".join(f"{k}:{v}" for k, v in
                     sorted(r.get("collective_counts", {}).items())),
        ))
        print(f"roofline,{r['arch']},{r['shape']},compute_ms="
              f"{r['compute_s']*1e3:.2f},memory_ms={r['memory_s']*1e3:.2f},"
              f"collective_ms={r['collective_s']*1e3:.2f},"
              f"dominant={r['dominant']},useful={r['useful_ratio']:.2f}")
    if rows:
        write_csv("roofline",
                  "arch,shape,status,compute_ms,memory_ms,collective_ms,"
                  "dominant,useful_ratio,collectives", rows)
    else:
        print("roofline,no dry-run artifacts found (run repro.launch.dryrun)")
    return rows
