"""Kernel microbenchmarks: fused Pallas (interpret on CPU) vs the XLA path.

On this CPU host, interpret-mode timings measure the Python-level kernel
body, NOT TPU performance — the structural numbers that matter (and that we
report) are the HBM-traffic models: the fused merge+update kernel moves
3 reads + 1 write per model pair vs 4 reads + 2 writes unfused (1.5x), and
flash attention's working set is O(blk_q x blk_k) vs O(S^2)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core.learners import LinearModel, make_update
from repro.core.merge import create_model_mu
from repro.kernels import gossip_merge as gm
from repro.kernels import pegasos_update as pu
from repro.kernels import ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False):
    n, d = (256, 1024) if quick else (2048, 4096)
    key = jax.random.key(0)
    ks = jax.random.split(key, 6)
    w1 = jax.random.normal(ks[0], (n, d), jnp.float32)
    w2 = jax.random.normal(ks[1], (n, d), jnp.float32)
    x = jax.random.normal(ks[2], (n, d), jnp.float32)
    t1 = jax.random.randint(ks[3], (n,), 1, 50)
    t2 = jax.random.randint(ks[4], (n,), 1, 50)
    y = jnp.sign(jax.random.normal(ks[5], (n,)))

    rows = []
    # XLA reference (what the fused kernel replaces)
    xla_mu = jax.jit(lambda: ref.merge_update_ref(w1, t1, w2, t2, x, y, 1e-2))
    us = _time(xla_mu)
    rows.append(("mu_xla_ref", us, f"n={n};d={d}"))
    us2 = _time(lambda: gm.merge_update(w1, t1, w2, t2, x, y, lam=1e-2,
                                        interpret=True))
    rows.append(("mu_pallas_interpret", us2, "CPU interpret (functional only)"))
    us3 = _time(lambda: pu.pegasos_update(w1, t1, x, y, lam=1e-2,
                                          interpret=True))
    rows.append(("pegasos_pallas_interpret", us3, ""))
    # traffic model (bytes per model pair)
    unfused = (4 + 2) * d * 4
    fused = (3 + 1) * d * 4
    rows.append(("mu_hbm_bytes_unfused", unfused, "per model pair"))
    rows.append(("mu_hbm_bytes_fused", fused,
                 f"{unfused/fused:.2f}x traffic cut"))
    for name, us, note in rows:
        print(f"kernel,{name},{us:.1f},{note}")
    write_csv("kernels", "name,us_per_call,derived", rows)
    return rows
