"""Wire-quantization study: f32/bf16/f16/int8/int8_sr payloads at scale.

The paper's central cost axis is communication: one model per message,
random walks instead of raw-data movement. PR 2 halved the wire bytes with
16-bit float payloads; this sweep measures the next 2x — per-message affine
int8 (deterministic and stochastically rounded) — on the FULL extreme
scenario (50% drop, delays U[Δ, 10Δ], 90%-online churn), recording what the
4x coefficient compression actually costs in terminal error at population
scale.

Dimensions: the sweep runs at d=57 (the paper's spambase feature count), the
regime the paper targets — big enough that the per-message f16
scale/zero-point + int32 counter overhead amortizes (at d=57 an int8 message
is 65 B vs 232 B for f32: 3.57x on the wire; asymptotically 4x), small
enough that 10^6-node populations with 10Δ in-flight buffers still fit.

Per (dtype, N): wire bytes/message, total wire bytes, in-flight
payload-buffer bytes, node-cycles/s (sharded engine, compacted rounds), the
terminal fresh-model error, and its delta vs the f32 baseline at the same N.
A bitwise reference-vs-sharded parity probe for the int8 dtypes runs at the
smallest N (the full matrix lives in tests/test_wire_quantization.py).

    PYTHONPATH=src python -m benchmarks.wire_quantization [--quick]
    PYTHONPATH=src python -m benchmarks.run --only wire_quantization

Output: CSV rows (results/benchmarks/) plus the machine-readable trajectory
``BENCH_wire_quantization.json`` at the repo root.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, write_bench_json, write_csv

DIM = 57                       # spambase-sized models (paper Table I)
WIRE_DTYPES = ["f32", "bf16", "f16", "int8", "int8_sr"]
PARITY_PROBE_N = 1_000         # bitwise ref-vs-sharded check at this N


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, d: int, wire_dtype):
    from repro.configs.gossip_linear import GossipLinearConfig
    # full extreme failure scenario; cache_size 4 bounds the (N, C, d)
    # cache (912 MB f32 at N=10^6, d=57)
    return GossipLinearConfig(
        name=f"wireq-{n}", dim=d, n_nodes=n, n_test=512, class_ratio=(1, 1),
        lam=1e-3, variant="mu", cache_size=4, drop_prob=0.5,
        delay_max_cycles=10, online_fraction=0.9,
        wire_dtype=None if wire_dtype == "f32" else wire_dtype)


def run(quick: bool = False) -> dict:
    from repro.core.simulation import message_wire_bytes, run_simulation

    d = DIM
    cycles = 20 if quick else 50
    k_rounds = 8                            # overflow ~ 0, like the paper
    sweep = [1_000, 10_000, 100_000] if quick else [
        1_000, 10_000, 100_000, 1_000_000]

    rows, json_rows = [], []
    results: dict = {}
    for n in sweep:
        X, y, Xt, yt = _dataset(n, d)
        for wire in WIRE_DTYPES:
            cfg = _cfg(n, d, wire)
            kw = dict(eval_every=10, seed=0, k_rounds=k_rounds,
                      engine="sharded")
            # warm-up compiles the same chunk fn (chunk length eval_every)
            run_simulation(cfg, X, y, Xt, yt, cycles=10, **kw)
            with Timer() as t:
                res = run_simulation(cfg, X, y, Xt, yt, cycles=cycles, **kw)
            rate = n * cycles / t.s
            results[(wire, n)] = res
            err = res.err_fresh[-1]
            base = results.get(("f32", n))
            delta = err - base.err_fresh[-1] if base else 0.0
            rows.append((wire, n, cycles, f"{t.s:.3f}", f"{rate:.0f}",
                         message_wire_bytes(d, cfg.wire_dtype),
                         res.wire_bytes_total, res.buf_payload_bytes,
                         f"{err:.4f}", f"{delta:+.4f}"))
            json_rows.append(dict(
                wire_dtype=wire, n_nodes=n, cycles=cycles, seconds=t.s,
                node_cycles_per_sec=rate,
                wire_bytes_per_msg=message_wire_bytes(d, cfg.wire_dtype),
                wire_bytes_total=res.wire_bytes_total,
                buf_payload_bytes=res.buf_payload_bytes,
                sent_total=res.sent_total, err_fresh=err,
                err_delta_vs_f32=delta))
            print("wire_quantization," + ",".join(str(x) for x in rows[-1]))

    # bitwise cross-engine parity probe for the quantized dtypes
    parity = {}
    Xp, yp, Xtp, ytp = _dataset(PARITY_PROBE_N, d)
    for wire in ("int8", "int8_sr"):
        cfg = _cfg(PARITY_PROBE_N, d, wire)
        kw = dict(cycles=20, eval_every=10, seed=3, k_rounds=k_rounds)
        ref = run_simulation(cfg, Xp, yp, Xtp, ytp, **kw)
        sh = run_simulation(cfg, Xp, yp, Xtp, ytp, engine="sharded", **kw)
        parity[wire] = bool(ref.err_fresh == sh.err_fresh
                            and ref.err_voted == sh.err_voted)
        print(f"wire_quantization,parity,{wire},{parity[wire]}")

    derived: dict = {}
    top_n = sweep[-1]
    for wire in WIRE_DTYPES[1:]:
        if (wire, top_n) in results and ("f32", top_n) in results:
            ratio = (results[("f32", top_n)].wire_bytes_total
                     / results[(wire, top_n)].wire_bytes_total)
            derived[f"{wire}_wire_reduction_at_{top_n}"] = ratio
            print(f"wire_quantization,reduction@N={top_n},{wire},"
                  f"{ratio:.2f}x")

    write_csv("wire_quantization",
              "wire_dtype,n_nodes,cycles,seconds,node_cycles_per_sec,"
              "wire_bytes_per_msg,wire_bytes_total,buf_payload_bytes,"
              "err_fresh,err_delta_vs_f32", rows)
    write_bench_json("wire_quantization", dict(
        bench="wire_quantization",
        quick=quick,
        scenario=dict(drop_prob=0.5, delay_max_cycles=10,
                      online_fraction=0.9, k_rounds=k_rounds, dim=d,
                      cycles=cycles, variant="mu", cache_size=4,
                      engine="sharded"),
        rows=json_rows,
        parity_bitwise=parity,
        derived=derived,
    ))
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
