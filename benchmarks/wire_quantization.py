"""Wire-codec study: every registered codec (f32 … packed ternary) at scale.

The paper's central cost axis is communication: one model per message,
random walks instead of raw-data movement. PR 2 halved the wire bytes with
16-bit float payloads, PR 3 cut 3.57x with per-message affine int8; this
sweep measures the sub-4-bit frontier — packed int4 (two codes/byte) and
base-3 ternary (five codes/byte), each with and without sender-side
error-feedback accumulators — on the FULL extreme scenario (50% drop,
delays U[Δ, 10Δ], 90%-online churn).

It answers the ROADMAP's open question empirically: does the merge-DAG
averaging absorb the quantization/feedback bias? Per (codec, N) the sweep
records the terminal fresh-model error and its delta vs the f32 baseline,
plus the terminal EF-residual norm; the ``derived`` block compares each
``_ef`` codec against its plain sibling (measured on this container: int4's
bias is absorbed — |delta| stays in the 1e-3 band with or without EF —
while ternary's max-scale codes are coarse enough that the EF residual
carries O(|w|) state and re-injects it, a *worse* terminal delta; the
numbers are recorded as found).

Dimensions: d=57 (the paper's spambase feature count) — big enough that the
per-message metadata (f16 scale, and zero-point for int8) + int32 counter
amortizes: int8 is 65 B vs 232 B f32 (3.57x), int4 is 35 B (6.6x,
**0.54× int8**), ternary is 18 B (12.9x).

Per (codec, N): wire bytes/message, total wire bytes, in-flight
payload-buffer bytes, node-cycles/s (sharded engine), terminal err_fresh +
delta vs f32, EF-residual norm. A bitwise reference-vs-sharded parity probe
runs for EVERY registered codec at the smallest N (the full engine/kernel
matrix lives in tests/test_wire_codec.py).

    PYTHONPATH=src python -m benchmarks.wire_quantization [--quick]
    PYTHONPATH=src python -m benchmarks.run --only wire_quantization

Output: CSV rows (results/benchmarks/) plus the machine-readable trajectory
``BENCH_wire_quantization.json`` at the repo root (guarded by
tools/check_bench_regression.py in --bench-smoke).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, write_bench_json, write_csv

DIM = 57                       # spambase-sized models (paper Table I)
PARITY_PROBE_N = 1_000         # bitwise ref-vs-sharded check at this N
# the study compares terminal errors at the few-1e-3 level; the default
# 100-node eval subset has ~5e-3 estimator noise at that point of the
# curve, so the codec deltas are measured over a 4x larger subset
EVAL_NODES = 400


def _codecs():
    from repro.core.wire_codec import WIRE_CODECS
    return list(WIRE_CODECS)   # registration order: f32 … ternary_ef


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, d: int, wire_dtype):
    from repro.configs.gossip_linear import GossipLinearConfig
    # full extreme failure scenario; cache_size 4 bounds the (N, C, d)
    # cache (912 MB f32 at N=10^6, d=57)
    return GossipLinearConfig(
        name=f"wireq-{n}", dim=d, n_nodes=n, n_test=512, class_ratio=(1, 1),
        lam=1e-3, variant="mu", cache_size=4, drop_prob=0.5,
        delay_max_cycles=10, online_fraction=0.9,
        wire_dtype=None if wire_dtype == "f32" else wire_dtype)


def run(quick: bool = False) -> dict:
    from repro.core.simulation import message_wire_bytes, run_simulation

    d = DIM
    codecs = _codecs()
    cycles = 20 if quick else 50
    k_rounds = 8                            # overflow ~ 0, like the paper
    sweep = [1_000, 10_000, 100_000] if quick else [
        1_000, 10_000, 100_000, 1_000_000]

    rows, json_rows = [], []
    results: dict = {}
    for n in sweep:
        X, y, Xt, yt = _dataset(n, d)
        for wire in codecs:
            cfg = _cfg(n, d, wire)
            kw = dict(eval_every=10, seed=0, k_rounds=k_rounds,
                      eval_nodes=EVAL_NODES, engine="sharded")
            # warm-up compiles the same chunk fn (chunk length eval_every)
            run_simulation(cfg, X, y, Xt, yt, cycles=10, **kw)
            with Timer() as t:
                res = run_simulation(cfg, X, y, Xt, yt, cycles=cycles, **kw)
            rate = n * cycles / t.s
            results[(wire, n)] = res
            err = res.err_fresh[-1]
            base = results.get(("f32", n))
            delta = err - base.err_fresh[-1] if base else 0.0
            rows.append((wire, n, cycles, f"{t.s:.3f}", f"{rate:.0f}",
                         message_wire_bytes(d, cfg.wire_dtype),
                         res.wire_bytes_total, res.buf_payload_bytes,
                         f"{err:.4f}", f"{delta:+.4f}",
                         f"{res.ef_residual_norm:.3f}"))
            json_rows.append(dict(
                wire_dtype=wire, n_nodes=n, cycles=cycles, seconds=t.s,
                node_cycles_per_sec=rate,
                wire_bytes_per_msg=message_wire_bytes(d, cfg.wire_dtype),
                wire_bytes_total=res.wire_bytes_total,
                buf_payload_bytes=res.buf_payload_bytes,
                sent_total=res.sent_total, err_fresh=err,
                err_delta_vs_f32=delta,
                ef_residual_norm=res.ef_residual_norm))
            print("wire_quantization," + ",".join(str(x) for x in rows[-1]))

    # bitwise cross-engine parity probe for EVERY registered codec —
    # the subsystem's acceptance bar: a codec that cannot reproduce the
    # reference bits on the sharded engine is not a wire format, it is a
    # different protocol
    parity = {}
    Xp, yp, Xtp, ytp = _dataset(PARITY_PROBE_N, d)
    for wire in codecs:
        cfg = _cfg(PARITY_PROBE_N, d, wire)
        kw = dict(cycles=20, eval_every=10, seed=3, k_rounds=k_rounds)
        ref = run_simulation(cfg, Xp, yp, Xtp, ytp, **kw)
        sh = run_simulation(cfg, Xp, yp, Xtp, ytp, engine="sharded", **kw)
        parity[wire] = bool(ref.err_fresh == sh.err_fresh
                            and ref.err_voted == sh.err_voted
                            and ref.ef_residual_norm == sh.ef_residual_norm)
        print(f"wire_quantization,parity,{wire},{parity[wire]}")

    derived: dict = {}
    top_n = sweep[-1]
    for wire in codecs[1:]:
        if (wire, top_n) in results and ("f32", top_n) in results:
            ratio = (results[("f32", top_n)].wire_bytes_total
                     / results[(wire, top_n)].wire_bytes_total)
            derived[f"{wire}_wire_reduction_at_{top_n}"] = ratio
            print(f"wire_quantization,reduction@N={top_n},{wire},"
                  f"{ratio:.2f}x")
    derived["int4_ef_vs_int8_wire_ratio"] = (
        message_wire_bytes(d, "int4_ef") / message_wire_bytes(d, "int8"))
    # the ROADMAP question: EF vs no-EF terminal deltas, per packed family
    for fam in ("int4", "ternary"):
        for n in sweep:
            plain = results.get((fam, n))
            ef = results.get((f"{fam}_ef", n))
            f32r = results.get(("f32", n))
            if plain and ef and f32r:
                b = f32r.err_fresh[-1]
                derived[f"{fam}_err_delta_at_{n}"] = plain.err_fresh[-1] - b
                derived[f"{fam}_ef_err_delta_at_{n}"] = ef.err_fresh[-1] - b

    write_csv("wire_quantization",
              "wire_dtype,n_nodes,cycles,seconds,node_cycles_per_sec,"
              "wire_bytes_per_msg,wire_bytes_total,buf_payload_bytes,"
              "err_fresh,err_delta_vs_f32,ef_residual_norm", rows)
    write_bench_json("wire_quantization", dict(
        bench="wire_quantization",
        quick=quick,
        scenario=dict(drop_prob=0.5, delay_max_cycles=10,
                      online_fraction=0.9, k_rounds=k_rounds, dim=d,
                      cycles=cycles, variant="mu", cache_size=4,
                      eval_nodes=EVAL_NODES, engine="sharded"),
        codecs=codecs,
        rows=json_rows,
        parity_bitwise=parity,
        derived=derived,
    ))
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
