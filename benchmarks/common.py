"""Shared benchmark utilities: CSV output + dataset cache."""
from __future__ import annotations

import functools
import time
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parent.parent / "results" / "benchmarks"


def write_csv(name: str, header: str, rows) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    fp = OUT_DIR / f"{name}.csv"
    with fp.open("w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return fp


@functools.lru_cache(maxsize=None)
def dataset(name: str, seed: int = 0):
    from repro.data.synthetic import paper_dataset
    return paper_dataset(name, seed)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
