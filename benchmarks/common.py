"""Shared benchmark utilities: CSV/JSON output + dataset cache.

Wall-clock timing lives in ``repro.core.telemetry`` (one home for every
timer/histogram in the repo); ``Timer``, ``best_of`` and
``LatencyHistogram`` are re-exported here so benches keep one import."""
from __future__ import annotations

import functools
import json
from pathlib import Path

from repro.core.telemetry import LatencyHistogram, Timer, best_of

__all__ = ["REPO_ROOT", "OUT_DIR", "write_csv", "write_bench_json",
           "dataset", "Timer", "best_of", "LatencyHistogram"]

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_DIR = REPO_ROOT / "results" / "benchmarks"


def write_csv(name: str, header: str, rows) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    fp = OUT_DIR / f"{name}.csv"
    with fp.open("w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return fp


def write_bench_json(name: str, payload: dict) -> Path:
    """Machine-readable perf trajectory: BENCH_<name>.json at the repo root
    (the CSVs under results/ are per-run; the JSON is the one CI and future
    sessions diff for regressions)."""
    fp = REPO_ROOT / f"BENCH_{name}.json"
    fp.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return fp


@functools.lru_cache(maxsize=None)
def dataset(name: str, seed: int = 0):
    from repro.data.synthetic import paper_dataset
    return paper_dataset(name, seed)
