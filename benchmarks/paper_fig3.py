"""Fig. 3: local voting (cache of 10, Algorithm 4) with and without failures.

Claims checked: voting yields a large improvement for RW, a smaller one for
MU; early cycles can show slight degradation; 'since voting is for free, it
is advisable to use it'."""
from __future__ import annotations

import dataclasses

from benchmarks.common import dataset, write_csv
from repro.core.simulation import run_simulation

AF = dict(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9)


def run(quick: bool = False, datasets=("spambase", "malicious-urls")):
    cycles = 60 if quick else 300
    if quick:
        datasets = ("spambase",)
    rows = []
    for name in datasets:
        X, y, Xt, yt, cfg = dataset(name)
        for failure, fkw in [("none", {}), ("af", AF)]:
            for variant in ("rw", "mu"):
                c = dataclasses.replace(cfg, variant=variant, **fkw)
                res = run_simulation(c, X, y, Xt, yt, cycles=cycles,
                                     eval_every=max(cycles // 15, 1), seed=0)
                for cyc, ef, ev in zip(res.cycles, res.err_fresh,
                                       res.err_voted):
                    rows.append((name, failure, variant, cyc,
                                 round(ef, 4), round(ev, 4)))
                gain = res.err_fresh[-1] - res.err_voted[-1]
                print(f"fig3,{name},{failure},{variant},"
                      f"fresh={res.err_fresh[-1]:.4f},"
                      f"voted={res.err_voted[-1]:.4f},gain={gain:+.4f}")
    write_csv("fig3", "dataset,failure,variant,cycle,err_fresh,err_voted", rows)
    return rows
