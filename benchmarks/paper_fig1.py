"""Fig. 1: prediction-error convergence, no-failure vs all-failures (AF).

Curves per dataset: P2PegasosRW, P2PegasosMU, WB1, WB2 (Eqs. 18-19), in the
failure-free setting and under AF (drop 0.5, delay U[Δ,10Δ], churn 90%
online). The paper's headline claims checked here:
  * MU converges orders of magnitude faster than RW (log-scale cycles);
  * MU tracks WB2 with a small delay;
  * AF costs roughly a constant slowdown factor (≈ delay x drop), not
    convergence.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import dataset, write_csv
from repro.core.ensemble import run_weighted_bagging
from repro.core.simulation import run_simulation

AF = dict(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9)


def run(quick: bool = False, datasets=("spambase", "malicious-urls", "reuters")):
    cycles = 60 if quick else 300
    if quick:
        datasets = ("spambase",)
    rows = []
    for name in datasets:
        X, y, Xt, yt, cfg = dataset(name)
        n = X.shape[0]
        for failure, fkw in [("none", {}), ("af", AF)]:
            for variant in ("rw", "mu"):
                c = dataclasses.replace(cfg, variant=variant, **fkw)
                res = run_simulation(c, X, y, Xt, yt, cycles=cycles,
                                     eval_every=max(cycles // 15, 1), seed=0)
                for cyc, e in zip(res.cycles, res.err_fresh):
                    rows.append((name, failure, f"p2pegasos-{variant}", cyc,
                                 round(e, 4)))
                print(f"fig1,{name},{failure},{variant},final={res.err_fresh[-1]:.4f}")
        bag = run_weighted_bagging(X, y, Xt, yt, n_models=min(n, 2048),
                                   cycles=cycles, lam=cfg.lam,
                                   eval_every=max(cycles // 15, 1))
        for cyc, e1, e2 in zip(bag.cycles, bag.err_wb1, bag.err_wb2):
            rows.append((name, "none", "wb1", cyc, round(e1, 4)))
            rows.append((name, "none", "wb2", cyc, round(e2, 4)))
        print(f"fig1,{name},none,wb1,final={bag.err_wb1[-1]:.4f}")
        print(f"fig1,{name},none,wb2,final={bag.err_wb2[-1]:.4f}")
    write_csv("fig1", "dataset,failure,algorithm,cycle,err", rows)
    return rows
