"""Theorem 1: empirical average regret along an MU merge chain vs the
G²(log t + 1)/(2λt) bound, on each Table-I surrogate geometry (reduced dim
for reuters so w* is computable quickly)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_csv
from repro.core.theory import mu_chain_regret
from repro.data.synthetic import make_linear_dataset

GEOMS = {
    # name -> (n, d, lam)
    "reuters-like": (500, 256, 1e-2),
    "spambase-like": (1000, 57, 1e-3),
    "malicious-urls-like": (2000, 10, 1e-2),
}


def run(quick: bool = False):
    rows = []
    steps = 120 if quick else 400
    for name, (n, d, lam) in GEOMS.items():
        rng = np.random.default_rng(0)
        X, y = make_linear_dataset(rng, n, d, noise=0.05, separation=3.0)
        tr = mu_chain_regret(X, y, lam=lam, steps=steps, seed=0)
        for i in range(0, len(tr.t), max(len(tr.t) // 12, 1)):
            rows.append((name, tr.t[i], round(tr.avg_regret[i], 5),
                         round(tr.bound[i], 5)))
        print(f"theory,{name},holds={tr.holds},"
              f"final_avg_regret={tr.avg_regret[-1]:.5f},"
              f"final_bound={tr.bound[-1]:.5f}")
        assert tr.holds, f"Theorem 1 bound violated on {name}"
    write_csv("theory_theorem1", "geometry,t,avg_regret,bound", rows)
    return rows
