"""Layer-B headline: gossip parameter averaging vs gradient all-reduce.

Two measurements:
 (1) ON-MESH COLLECTIVE BYTES (from dry-run artifacts when present): the
     per-step cross-replica wire bytes of the gossip step vs the all-reduce
     step for the same (arch x shape) — the datacenter transcription of the
     paper's 'one message per node per cycle' cost model.
 (2) CONVERGENCE (CPU-runnable): same ~1-10M-param LM trained with gossip
     (MU/UM, hypercube) and with exact all-reduce DP; loss curves + peer
     disagreement show the accuracy cost of replacing the all-reduce.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def wire_bytes_comparison():
    rows = []
    for gp in sorted(RESULTS.glob("*__gossip.json")):
        g = json.loads(gp.read_text())
        if g.get("status") != "ok":
            continue
        ap = RESULTS / gp.name.replace("__gossip", "__allreduce")
        if not ap.exists():
            continue
        a = json.loads(ap.read_text())
        if a.get("status") != "ok":
            continue
        rows.append((g["arch"], g["shape"], g["mesh"],
                     int(a["collective_wire_bytes"]),
                     int(g["collective_wire_bytes"]),
                     round(a["collective_wire_bytes"]
                           / max(g["collective_wire_bytes"], 1), 2)))
        print(f"gossip_vs_ar,{g['arch']},{g['shape']},"
              f"ar_wire={a['collective_wire_bytes']:.3e},"
              f"gossip_wire={g['collective_wire_bytes']:.3e},"
              f"ratio={rows[-1][-1]}")
    if rows:
        write_csv("gossip_vs_allreduce_wire",
                  "arch,shape,mesh,allreduce_wire_B,gossip_wire_B,ratio", rows)
    return rows


def convergence_comparison(quick: bool = False):
    from repro.launch.train import train
    steps = 30 if quick else 150
    rows = []
    for dist, merge in [("allreduce", "-"), ("gossip", "mu"), ("gossip", "rw")]:
        _, hist = train("qwen3-1.7b", reduced=True, steps=steps, batch=8,
                        seq_len=64, lr=2e-3, dist=dist, n_peers=4,
                        merge=merge if merge != "-" else "mu",
                        log_every=max(steps // 6, 1), seed=0)
        for s, loss, dis in hist:
            rows.append((dist if merge != "rw" else "localsgd-rw", s,
                         round(loss, 4), f"{dis:.2e}"))
        print(f"gossip_vs_ar_convergence,{dist}-{merge},"
              f"final_loss={hist[-1][1]:.4f}")
    write_csv("gossip_vs_allreduce_convergence",
              "dist,step,loss,peer_disagreement", rows)
    return rows


def run(quick: bool = False):
    rows = wire_bytes_comparison()
    rows += [tuple(r) for r in convergence_comparison(quick)]
    return rows
