"""Fig. 2: MU vs UM vs PERFECT MATCHING + model-similarity (cosine).

Claims checked: MU >= UM in convergence speed (despite UM's single-step
advantage, Section V-B); perfect matching does not clearly beat uniform
sampling for Pegasos; similarity correlates with error."""
from __future__ import annotations

import dataclasses

from benchmarks.common import dataset, write_csv
from repro.core.simulation import run_simulation


def run(quick: bool = False, datasets=("spambase", "malicious-urls")):
    cycles = 60 if quick else 300
    if quick:
        datasets = ("spambase",)
    rows = []
    for name in datasets:
        X, y, Xt, yt, cfg = dataset(name)
        runs = [
            ("mu", "uniform"),
            ("um", "uniform"),
            ("mu", "matching"),
        ]
        for variant, sampler in runs:
            c = dataclasses.replace(cfg, variant=variant)
            res = run_simulation(c, X, y, Xt, yt, cycles=cycles,
                                 eval_every=max(cycles // 15, 1), seed=0,
                                 sampler=sampler)
            label = f"{variant}-{sampler}"
            for cyc, e, s in zip(res.cycles, res.err_fresh, res.similarity):
                rows.append((name, label, cyc, round(e, 4), round(s, 4)))
            print(f"fig2,{name},{label},final_err={res.err_fresh[-1]:.4f},"
                  f"final_similarity={res.similarity[-1]:.3f}")
    write_csv("fig2", "dataset,algorithm,cycle,err,similarity", rows)
    return rows
