"""Benchmark harness — one module per paper table/figure + system benches.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,table1]
Prints ``name,...`` CSV lines and writes results/benchmarks/*.csv.
"""
from __future__ import annotations

import argparse
import time

SUITES = ["table1", "fig1", "fig2", "fig3", "theory", "kernels",
          "gossip_vs_allreduce", "roofline", "population_scaling",
          "wire_quantization", "robustness", "serving",
          "telemetry_overhead"]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced cycles/iters (CI-sized)")
    p.add_argument("--only", default="",
                   help="comma-separated subset of: " + ",".join(SUITES))
    args = p.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    t0 = time.time()
    if "table1" in only:
        from benchmarks import paper_table1
        paper_table1.run(args.quick)
    if "fig1" in only:
        from benchmarks import paper_fig1
        paper_fig1.run(args.quick)
    if "fig2" in only:
        from benchmarks import paper_fig2
        paper_fig2.run(args.quick)
    if "fig3" in only:
        from benchmarks import paper_fig3
        paper_fig3.run(args.quick)
    if "theory" in only:
        from benchmarks import paper_theory
        paper_theory.run(args.quick)
    if "kernels" in only:
        from benchmarks import kernel_bench
        kernel_bench.run(args.quick)
    if "gossip_vs_allreduce" in only:
        from benchmarks import gossip_vs_allreduce
        gossip_vs_allreduce.run(args.quick)
    if "roofline" in only:
        from benchmarks import roofline_table
        roofline_table.run(args.quick)
    if "population_scaling" in only:
        from benchmarks import population_scaling
        population_scaling.run(args.quick)
    if "wire_quantization" in only:
        from benchmarks import wire_quantization
        wire_quantization.run(args.quick)
    if "robustness" in only:
        from benchmarks import robustness
        robustness.run(args.quick)
    if "serving" in only:
        from benchmarks import serving
        serving.run(args.quick)
    if "telemetry_overhead" in only:
        from benchmarks import telemetry_overhead
        telemetry_overhead.run(args.quick)
    print(f"benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
