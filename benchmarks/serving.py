"""Serving-tier bench: live query throughput + latency under a running run.

Measures the gossip serving tier (repro.core.serving + the GossipServer
request loop) end to end: the sharded engine gossips underneath while a
query stream — drawn from the held-out test set, so every served answer has
a label — is batched and answered from eval-point snapshots with the cache
majority vote. Per (scenario, N) the rows record co-serving protocol
throughput (node-cycles/s over the full wall clock, serving included),
queries/s, histogram-backed p50/p90/p99/p999 batch latency (the shared
fixed-bucket ``repro.core.telemetry.LatencyHistogram`` — rows also carry
the sparse bucket dump, comparable across PRs) and the fresh-vs-voted
accuracy of the *served* answers, at N = 10^4..10^6 (quick: 10^4) under
the clean and the paper's extreme (50% drop, 10Δ delays, 90% online)
scenarios.

Bitwise probes ride along at a fixed PROBE_N (the robustness-bench
precedent — the reference engine cannot reach 10^6): per scenario × wire
(f32 + int4), (a) ``snapshot/...`` — eval-point QuerySnapshots are bitwise
identical across engines, and every row carries its scenario's verdict as
``snapshot_parity``; (b) ``kernel/...`` — the Pallas
``voted_predict_batched`` path answers bitwise == the jnp ``serve_voted``
path; (c) ``no_perturb/...`` — a hooked-and-serving run reproduces the
unhooked error curves bit for bit. All three are no-baseline hard gates in
tools/check_bench_regression.py.

    PYTHONPATH=src python -m benchmarks.serving [--quick]
    PYTHONPATH=src python -m benchmarks.run --only serving

Output: CSV rows (results/benchmarks/) plus the machine-readable
``BENCH_serving.json`` at the repo root (guarded as the fourth pair of
tools/run_tests.sh --bench-smoke).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, write_bench_json, write_csv

DIM = 57                       # spambase-sized models (paper Table I)
PROBE_N = 2_000                # bitwise parity probes run at this N
BATCH = 256                    # serving batch size (one compiled signature)
SCENARIOS = ("clean", "extreme")
PROBE_WIRES = (None, "int4")   # full-precision + a packed-codec wire


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 2048, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, scenario: str, wire=None):
    from repro.configs.gossip_linear import (GossipLinearConfig,
                                             with_failure_scenario)
    return with_failure_scenario(
        GossipLinearConfig(name=f"serve-{n}", dim=DIM, n_nodes=n,
                           n_test=2048, class_ratio=(1, 1), lam=1e-3,
                           variant="mu", cache_size=4, wire_dtype=wire),
        scenario)


def _serving_run(cfg, data, *, cycles, queries_per_eval, use_kernel=False,
                 seed=0, engine="sharded"):
    """One hooked run: returns (SimResult, GossipServer, per-query labels)."""
    from repro.core.simulation import run_simulation
    from repro.launch.gossip_serve import GossipServer

    X, y, Xt, yt = data
    srv = GossipServer(batch_size=BATCH, policy="uniform",
                       use_kernel=use_kernel)
    qrng = np.random.default_rng(17)
    labels = []

    def hook(cycle, snapshot):
        srv.serve_hook(cycle, snapshot)
        idx = qrng.integers(0, len(Xt), queries_per_eval)
        labels.append(yt[idx])
        srv.submit(Xt[idx])

    res = run_simulation(cfg, X, y, Xt, yt, cycles=cycles, eval_every=10,
                         seed=seed, engine=engine, serve_hook=hook)
    srv.flush()
    return res, srv, np.concatenate(labels) if labels else np.zeros(0)


def _parity_probes(cycles: int) -> dict:
    """The fixed-N bitwise gates: snapshot engine-parity, kernel-vs-jnp
    served answers, and the serving-never-perturbs property."""
    import jax
    import jax.numpy as jnp

    from repro.core import serving
    from repro.core.simulation import run_simulation
    from repro.kernels.voted_predict import voted_predict_batched

    parity = {}
    data = _dataset(PROBE_N, DIM, seed=1)
    X, y, Xt, yt = data
    kw = dict(cycles=cycles, eval_every=10, seed=3)
    for scenario in SCENARIOS:
        for wire in PROBE_WIRES:
            cfg = _cfg(PROBE_N, scenario, wire=wire)
            tag = f"{scenario}/{wire or 'f32'}"

            snaps = {"reference": {}, "sharded": {}}

            def collect(store):
                def hook(cycle, snap):
                    store[cycle] = jax.tree.map(np.array, snap)
                return hook

            hooked = {}
            for engine in ("reference", "sharded"):
                hooked[engine] = run_simulation(
                    cfg, X, y, Xt, yt, engine=engine,
                    serve_hook=collect(snaps[engine]), **kw)
            ok = sorted(snaps["reference"]) == sorted(snaps["sharded"])
            for cyc, ref_snap in snaps["reference"].items():
                sh_snap = snaps["sharded"].get(cyc)
                ok = ok and sh_snap is not None and all(
                    np.array_equal(a, b)
                    for a, b in zip(ref_snap, sh_snap))
            parity[f"snapshot/{tag}"] = bool(ok)

            # serving must not perturb: the hooked curves == unhooked
            clean = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
            parity[f"no_perturb/{tag}"] = bool(
                hooked["sharded"].err_fresh == clean.err_fresh
                and hooked["sharded"].err_voted == clean.err_voted)

            # kernel path == jnp path on the terminal sharded snapshot
            last = snaps["sharded"][max(snaps["sharded"])]
            w, count = jnp.asarray(last.w), jnp.asarray(last.count)
            Xq = jnp.asarray(Xt[:512], jnp.float32)
            assign = jnp.asarray(serving.assign_queries(512, PROBE_N,
                                                        seed=9))
            exp = serving.serve_voted(w, count, Xq, assign)
            got = voted_predict_batched(w[assign], count[assign], Xq,
                                        interpret=True)
            parity[f"kernel/{tag}"] = bool(
                np.array_equal(np.asarray(got), np.asarray(exp)))
            print(f"serving,parity,{tag},"
                  f"snapshot={parity[f'snapshot/{tag}']},"
                  f"kernel={parity[f'kernel/{tag}']},"
                  f"no_perturb={parity[f'no_perturb/{tag}']}")
    return parity


def run(quick: bool = False) -> dict:
    cycles = 20 if quick else 50
    queries_per_eval = 512 if quick else 2048
    n_sweep = [10_000] if quick else [10_000, 100_000, 1_000_000]

    parity = _parity_probes(20)

    rows, json_rows = [], []
    results: dict = {}
    for scenario in SCENARIOS:
        for n in n_sweep:
            data = _dataset(n, DIM)
            cfg = _cfg(n, scenario)
            # warm-up: compiles the chunk fn AND the serve fns at the
            # (N, BATCH) signatures the timed run uses
            _serving_run(cfg, data, cycles=10,
                         queries_per_eval=queries_per_eval)
            with Timer() as t:
                res, srv, y_served = _serving_run(
                    cfg, data, cycles=cycles,
                    queries_per_eval=queries_per_eval)
            s = srv.stats()
            rate = n * cycles / t.s
            acc_voted = float(np.mean(srv.answers() == y_served))
            acc_fresh = float(np.mean(srv.answers_fresh() == y_served))
            results[(scenario, n)] = (res, s, acc_voted, acc_fresh)
            snap_ok = all(parity[f"snapshot/{scenario}/{w or 'f32'}"]
                          for w in PROBE_WIRES)
            rows.append((scenario, n, cycles, f"{t.s:.3f}", f"{rate:.0f}",
                         s.queries, f"{s.queries_per_sec:.0f}",
                         f"{s.p50_latency_s * 1e3:.3f}",
                         f"{s.p99_latency_s * 1e3:.3f}",
                         f"{acc_voted:.4f}", f"{acc_fresh:.4f}", snap_ok))
            json_rows.append(dict(
                engine="sharded", scenario=scenario, n_nodes=n,
                cycles=cycles, seconds=t.s, node_cycles_per_sec=rate,
                queries=s.queries, queries_per_sec=s.queries_per_sec,
                p50_latency_s=s.p50_latency_s,
                p99_latency_s=s.p99_latency_s,
                p90_latency_s=s.p90_latency_s,
                p999_latency_s=s.p999_latency_s,
                latency_hist=s.latency_hist,
                acc_voted=acc_voted, acc_fresh=acc_fresh,
                snapshot_parity=snap_ok))
            print("serving," + ",".join(str(x) for x in rows[-1]))

    derived: dict = {}
    base = results.get(("clean", 10_000))
    if base:
        _, s, acc_voted, acc_fresh = base
        derived["clean_10k_queries_per_sec"] = s.queries_per_sec
        derived["clean_10k_acc_voted"] = acc_voted
        derived["clean_10k_acc_fresh"] = acc_fresh
        derived["voted_minus_fresh_acc"] = acc_voted - acc_fresh
    derived["all_snapshot_probes_bitwise"] = all(
        v for k, v in parity.items() if k.startswith("snapshot/"))
    derived["all_kernel_probes_bitwise"] = all(
        v for k, v in parity.items() if k.startswith("kernel/"))
    derived["all_runs_unperturbed"] = all(
        v for k, v in parity.items() if k.startswith("no_perturb/"))

    write_csv("serving",
              "scenario,n_nodes,cycles,seconds,node_cycles_per_sec,"
              "queries,queries_per_sec,p50_latency_ms,p99_latency_ms,"
              "acc_voted,acc_fresh,snapshot_parity", rows)
    write_bench_json("serving", dict(
        bench="serving",
        quick=quick,
        setup=dict(dim=DIM, variant="mu", cache_size=4, batch=BATCH,
                   queries_per_eval=queries_per_eval, eval_every=10,
                   policy="uniform", probe_n=PROBE_N,
                   probe_wires=[w or "f32" for w in PROBE_WIRES],
                   engine="sharded"),
        rows=json_rows,
        parity_bitwise=parity,
        derived=derived,
    ))
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
