"""Telemetry-overhead bench: armed vs unarmed runs at mega-population N.

The telemetry contract (docs/CONTRACTS.md) has two halves: an armed
:class:`repro.core.telemetry.Telemetry` must be *bitwise invisible* to the
protocol, and it must stay *cheap* — the acceptance criterion is <= 5%
node-cycles/s overhead on the sharded engine at N = 10^6. This bench
measures both:

* **Overhead rows** — best-of-2 sharded runs on the paper's extreme
  scenario (50% drop, 10Δ delays, 90% online), unarmed
  (``engine="sharded"``) vs armed with a fresh Telemetry per run
  (``engine="sharded-telemetry"``, which adds the per-cycle stream
  reductions, host spans, and the "/telem" chunk-fn variant). A matching
  reference-engine pair rides along at REF_N. The headline derived number
  is ``telemetry_overhead_ratio`` (armed seconds / unarmed seconds at the
  top N) — tools/check_bench_regression.py fails if a fresh run's ratio
  exceeds 1.10x the committed one; the <= 5% absolute acceptance
  criterion is recorded in the committed full-run baseline as
  ``derived.overhead_within_ceiling`` (vs ``RATIO_CEILING``).
* **Invisibility probes** (``parity_bitwise``) — at PROBE_N, armed vs
  unarmed error curves and message totals are bitwise identical on BOTH
  engines, and the armed reference and sharded runs emit bitwise-equal
  metric streams (the cross-engine parity surface of
  tests/test_telemetry.py, re-checked here as a no-baseline hard gate).

    PYTHONPATH=src python -m benchmarks.telemetry_overhead [--quick]
    PYTHONPATH=src python -m benchmarks.run --only telemetry_overhead

Output: CSV rows (results/benchmarks/) plus the machine-readable
``BENCH_telemetry_overhead.json`` at the repo root (guarded as the fifth
pair of tools/run_tests.sh --bench-smoke).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import best_of, write_bench_json, write_csv

DIM = 10                       # matches the population_scaling sweep
K_ROUNDS = 8
REF_N = 10_000                 # reference-engine overhead pair runs here
PROBE_N = 2_000                # bitwise invisibility probes run at this N
RATIO_CEILING = 1.05           # the <= 5% acceptance criterion


def _dataset(n: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, DIM, noise=0.07,
                               separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, scenario: str = "extreme", wire=None):
    from repro.configs.gossip_linear import (GossipLinearConfig,
                                             with_failure_scenario)
    return with_failure_scenario(
        GossipLinearConfig(name=f"telov-{n}", dim=DIM, n_nodes=n,
                           n_test=512, class_ratio=(1, 1), lam=1e-3,
                           variant="mu", cache_size=4, wire_dtype=wire),
        scenario)


def _invisibility_probes(cycles: int) -> dict:
    """Armed == unarmed bitwise, both engines; ref == sharded streams."""
    from repro.core.simulation import run_simulation
    from repro.core.telemetry import METRIC_STREAMS, Telemetry

    X, y, Xt, yt = _dataset(PROBE_N, seed=1)
    cfg = _cfg(PROBE_N)
    kw = dict(cycles=cycles, eval_every=10, seed=0, k_rounds=2)

    parity = {}
    tels = {}
    for engine in ("reference", "sharded"):
        plain = run_simulation(cfg, X, y, Xt, yt, engine=engine, **kw)
        tel = Telemetry(label=f"probe-{engine}")
        armed = run_simulation(cfg, X, y, Xt, yt, engine=engine,
                               telemetry=tel, **kw)
        tels[engine] = tel
        parity[f"invisible/{engine}"] = bool(
            plain.err_fresh == armed.err_fresh
            and plain.err_voted == armed.err_voted
            and plain.sent_total == armed.sent_total
            and plain.delivered_total == armed.delivered_total)
    parity["streams_equal"] = all(
        np.array_equal(tels["reference"].stream_array(name),
                       tels["sharded"].stream_array(name))
        for name in METRIC_STREAMS)
    print("telemetry_overhead,probes," + ",".join(
        f"{k}={'bitwise' if v else 'MISMATCH'}"
        for k, v in sorted(parity.items())))
    return parity


def run(quick: bool = False) -> dict:
    from repro.core.simulation import run_simulation
    from repro.core.telemetry import Telemetry

    cycles = 20 if quick else 50
    top_n = 100_000 if quick else 1_000_000

    parity = _invisibility_probes(20)

    rows, json_rows = [], []
    best_secs: dict = {}
    for engine, n in [("reference", REF_N), ("sharded", top_n)]:
        X, y, Xt, yt = _dataset(n)
        cfg = _cfg(n)
        kw = dict(cycles=cycles, eval_every=10, seed=0, k_rounds=K_ROUNDS,
                  engine=engine)
        for armed in (False, True):
            label = engine + ("-telemetry" if armed else "")

            def one_run():
                tel = Telemetry(label=label) if armed else None
                res = run_simulation(cfg, X, y, Xt, yt, telemetry=tel,
                                     **kw)
                return res, tel

            one_run()                         # warm-up (compiles)
            best, secs, (res, tel) = best_of(one_run)
            rate = n * cycles / best
            best_secs[label] = best
            row = dict(engine=label, scenario="extreme", n_nodes=n,
                       cycles=cycles, seconds=best, seconds_all=secs,
                       node_cycles_per_sec=rate,
                       err_fresh=res.err_fresh[-1], wire_dtype="f32")
            if tel is not None:
                row["spans"] = len(tel.spans)
                row["stream_cycles"] = len(tel.streams["sent"])
                row["phase_seconds"] = {
                    k: round(v, 6)
                    for k, v in sorted(tel.phase_seconds().items())}
            json_rows.append(row)
            rows.append((label, "extreme", n, cycles, f"{best:.3f}",
                         f"{rate:.0f}", f"{res.err_fresh[-1]:.4f}"))
            print("telemetry_overhead," + ",".join(
                str(x) for x in rows[-1]))

    derived = {}
    for engine, n in [("reference", REF_N), ("sharded", top_n)]:
        ratio = best_secs[engine + "-telemetry"] / best_secs[engine]
        key = ("telemetry_overhead_ratio" if engine == "sharded"
               else "reference_overhead_ratio")
        derived[key] = ratio
        print(f"telemetry_overhead,ratio,{engine},N={n},{ratio:.4f}x")
    derived["overhead_within_ceiling"] = bool(
        derived["telemetry_overhead_ratio"] <= RATIO_CEILING)
    derived["all_invisible_bitwise"] = all(parity.values())

    write_csv("telemetry_overhead",
              "engine,scenario,n_nodes,cycles,seconds,"
              "node_cycles_per_sec,err_fresh", rows)
    write_bench_json("telemetry_overhead", dict(
        bench="telemetry_overhead",
        quick=quick,
        setup=dict(dim=DIM, variant="mu", cache_size=4, k_rounds=K_ROUNDS,
                   cycles=cycles, scenario="extreme", top_n=top_n,
                   ref_n=REF_N, probe_n=PROBE_N,
                   ratio_ceiling=RATIO_CEILING),
        rows=json_rows,
        parity_bitwise=parity,
        derived=derived,
    ))
    return derived


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
