"""Adversarial-robustness study: fault models x Byzantine fraction x defense.

The paper's failure model (Section VI-A) is benign — drops, delays, churn —
and gossip learning rides through it. This sweep measures the *adversarial*
regime layered on top of the same extreme scenario (50% drop, delays
U[delta, 10*delta], 90%-online churn): a seed-chosen Byzantine subset
corrupts every model it sends (``repro.core.faults``: sign_flip / amplify /
zero / random_payload / stale_replay, plus the honest-fault wire bitflip),
and the receive path optionally screens each incoming payload per merge
round (``defense=``: none / norm_clip / cosine_gate).

Per (fault, byzantine_frac, defense, N) the sweep records terminal
fresh/voted error, the delta vs the fault-free baseline at the same N, and
the engine's fault counters (corrupted sends, gated + clipped receives).
The headline acceptance number lives in ``derived``: at N=10^4 with 10%
sign-flip attackers, ``norm_clip`` must recover terminal err_fresh to
within 2x the fault-free baseline while ``none`` measurably diverges.

A bitwise reference-vs-sharded parity probe runs for EVERY fault model at
N=1000 on f32 + int8 + int4 wires (the full engine/packing matrix lives in
tests/test_faults.py) — fault injection that cannot reproduce the
reference bits on the sharded engine is not a fault model, it is a
different protocol.

    PYTHONPATH=src python -m benchmarks.robustness [--quick]
    PYTHONPATH=src python -m benchmarks.run --only robustness

Output: CSV rows (results/benchmarks/) plus the machine-readable
``BENCH_robustness.json`` at the repo root (guarded by
tools/check_bench_regression.py in --bench-smoke).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, write_bench_json, write_csv

DIM = 57                       # spambase-sized models (paper Table I)
BASE_N = 10_000                # the acceptance-criterion population
PARITY_PROBE_N = 1_000         # bitwise ref-vs-sharded check at this N
EVAL_NODES = 400               # 4x the default eval subset (less noise)
ATTACK_FRAC = 0.1              # headline Byzantine fraction


def _dataset(n: int, d: int, seed: int = 0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 512, d, noise=0.07, separation=2.5)
    return X[:n], y[:n], X[n:], y[n:]


def _cfg(n: int, fault, frac: float, defense: str, wire=None):
    from repro.configs.gossip_linear import GossipLinearConfig
    return GossipLinearConfig(
        name=f"robust-{n}", dim=DIM, n_nodes=n, n_test=512,
        class_ratio=(1, 1), lam=1e-3, variant="mu", cache_size=4,
        drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9,
        wire_dtype=wire, fault_model=fault,
        byzantine_frac=frac if fault else 0.0, defense=defense)


def _combos(quick: bool):
    """(n, fault, frac, defense) sweep: the fault-free baseline anchors
    every N; sign_flip (the headline attack) crosses fractions x all
    defenses at BASE_N; every other fault runs at the headline fraction
    with and without norm_clip; full mode scales sign_flip to 10^5/10^6."""
    from repro.core.faults import FAULT_MODELS
    combos = [(BASE_N, None, 0.0, "none")]
    for frac in (ATTACK_FRAC, 0.3):
        for defense in ("none", "norm_clip", "cosine_gate"):
            combos.append((BASE_N, "sign_flip", frac, defense))
    for fault in FAULT_MODELS:
        if fault == "sign_flip":
            continue
        for defense in ("none", "norm_clip"):
            combos.append((BASE_N, fault, ATTACK_FRAC, defense))
    if not quick:
        for n in (100_000, 1_000_000):
            combos.append((n, None, 0.0, "none"))
            for defense in ("none", "norm_clip"):
                combos.append((n, "sign_flip", ATTACK_FRAC, defense))
    return combos


def run(quick: bool = False) -> dict:
    from repro.core.simulation import run_simulation

    cycles = 30 if quick else 60
    k_rounds = 8                            # overflow ~ 0, like the paper
    kw = dict(eval_every=10, seed=0, k_rounds=k_rounds,
              eval_nodes=EVAL_NODES, engine="sharded")

    rows, json_rows = [], []
    results: dict = {}
    data_cache: dict = {}
    for n, fault, frac, defense in _combos(quick):
        if n not in data_cache:
            data_cache[n] = _dataset(n, DIM)
        X, y, Xt, yt = data_cache[n]
        cfg = _cfg(n, fault, frac, defense)
        # warm-up compiles the same chunk fn (chunk length eval_every)
        run_simulation(cfg, X, y, Xt, yt, cycles=10, **kw)
        with Timer() as t:
            res = run_simulation(cfg, X, y, Xt, yt, cycles=cycles, **kw)
        rate = n * cycles / t.s
        results[(fault, frac, defense, n)] = res
        err = res.err_fresh[-1]
        base = results.get((None, 0.0, "none", n))
        delta = err - base.err_fresh[-1] if base else 0.0
        fs = res.fault_stats
        rows.append((fault or "none", frac, defense, n, cycles,
                     f"{t.s:.3f}", f"{rate:.0f}", f"{err:.4f}",
                     f"{res.err_voted[-1]:.4f}", f"{delta:+.4f}",
                     fs["corrupted"], fs["gated"], fs["clipped"]))
        json_rows.append(dict(
            engine="sharded", scenario="extreme",
            fault_model=fault, byzantine_frac=frac, defense=defense,
            n_nodes=n, cycles=cycles, seconds=t.s,
            node_cycles_per_sec=rate, err_fresh=err,
            err_voted=res.err_voted[-1], err_delta_vs_clean=delta,
            corrupted=fs["corrupted"], gated=fs["gated"],
            clipped=fs["clipped"]))
        print("robustness," + ",".join(str(x) for x in rows[-1]))

    # bitwise cross-engine parity probe for EVERY registered fault model,
    # on the f32 + int8 + int4 wires, with the norm_clip screen active —
    # the defended merge path must reproduce the reference bits exactly
    from repro.core.faults import FAULT_MODELS
    parity = {}
    Xp, yp, Xtp, ytp = _dataset(PARITY_PROBE_N, DIM)
    pkw = dict(cycles=20, eval_every=10, seed=3, k_rounds=k_rounds)
    for fault in FAULT_MODELS:
        for wire in (None, "int8", "int4"):
            cfg = _cfg(PARITY_PROBE_N, fault, ATTACK_FRAC, "norm_clip",
                       wire=wire)
            ref = run_simulation(cfg, Xp, yp, Xtp, ytp, **pkw)
            sh = run_simulation(cfg, Xp, yp, Xtp, ytp, engine="sharded",
                                **pkw)
            key = f"{fault}/{wire or 'f32'}"
            parity[key] = bool(ref.err_fresh == sh.err_fresh
                               and ref.err_voted == sh.err_voted
                               and ref.fault_stats == sh.fault_stats)
            print(f"robustness,parity,{key},{parity[key]}")

    # the acceptance criterion, recorded as found: 10% sign-flip at
    # N=10^4 — norm_clip recovers to <= 2x the fault-free terminal error
    # while the undefended run measurably diverges
    derived: dict = {}
    clean = results[(None, 0.0, "none", BASE_N)].err_fresh[-1]
    derived[f"clean_err_at_{BASE_N}"] = clean
    for defense in ("none", "norm_clip", "cosine_gate"):
        r = results.get(("sign_flip", ATTACK_FRAC, defense, BASE_N))
        if r is not None:
            derived[f"sign_flip_10pct_{defense}_err"] = r.err_fresh[-1]
            derived[f"sign_flip_10pct_{defense}_ratio_vs_clean"] = (
                r.err_fresh[-1] / clean if clean > 0 else float("inf"))
    nc = derived.get("sign_flip_10pct_norm_clip_ratio_vs_clean")
    un = derived.get("sign_flip_10pct_none_ratio_vs_clean")
    if nc is not None and un is not None:
        derived["norm_clip_recovers_within_2x"] = bool(nc <= 2.0)
        derived["undefended_diverges"] = bool(un > nc)
        print(f"robustness,acceptance,norm_clip {nc:.2f}x clean "
              f"(<=2x: {nc <= 2.0}),undefended {un:.2f}x clean")

    write_csv("robustness",
              "fault_model,byzantine_frac,defense,n_nodes,cycles,seconds,"
              "node_cycles_per_sec,err_fresh,err_voted,err_delta_vs_clean,"
              "corrupted,gated,clipped", rows)
    write_bench_json("robustness", dict(
        bench="robustness",
        quick=quick,
        scenario=dict(drop_prob=0.5, delay_max_cycles=10,
                      online_fraction=0.9, k_rounds=k_rounds, dim=DIM,
                      cycles=cycles, variant="mu", cache_size=4,
                      eval_nodes=EVAL_NODES, engine="sharded"),
        fault_models=list(FAULT_MODELS),
        rows=json_rows,
        parity_bitwise=parity,
        derived=derived,
    ))
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(ap.parse_args().quick)
