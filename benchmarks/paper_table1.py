"""Table I: datasets + sequential Pegasos (20,000 iterations) 0-1 error.

Paper values (on the UCI originals): Reuters 0.025, SpamBase 0.111,
Malicious URLs(10) 0.080. Our surrogates (same dim / sizes / class ratio;
see repro.data.synthetic) are calibrated to land near these floors, so the
gossip dynamics run on comparable geometry.
"""
from __future__ import annotations

import time

from benchmarks.common import dataset, write_csv
from repro.core.ensemble import run_sequential_pegasos

PAPER = {"reuters": 0.025, "spambase": 0.111, "malicious-urls": 0.080}


def run(quick: bool = False):
    rows = []
    iters = 2000 if quick else 20_000
    for name, target in PAPER.items():
        X, y, Xt, yt, cfg = dataset(name)
        t0 = time.time()
        _, pts = run_sequential_pegasos(X, y, Xt, yt, iters=iters,
                                        lam=cfg.lam, eval_every=iters)
        err = pts[-1][1]
        us = (time.time() - t0) / iters * 1e6
        rows.append((name, X.shape[0], Xt.shape[0], X.shape[1],
                     round(err, 4), target, round(us, 2)))
        print(f"table1,{name},err={err:.4f},paper={target},us_per_iter={us:.1f}")
    write_csv("table1", "dataset,n_train,n_test,dim,err,paper_err,us_per_iter",
              rows)
    return rows
