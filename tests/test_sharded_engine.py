"""Sharded mega-population engine: parity with the reference engine.

The sharded engine consumes the same host RNG stream and per-cycle key
sequence as the reference driver and shares its cycle math, so for a given
seed the error curves must reproduce the reference engine's (the acceptance
bar is 0.02 at every eval point; in practice they are bitwise-equal)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core.sharded_engine import key_schedule
from repro.core.simulation import run_simulation
from repro.data.synthetic import make_linear_dataset


def small_cfg(n_nodes=128, **kw):
    base = dict(name="toy", dim=16, n_nodes=n_nodes, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def toy(n=128, d=16, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 64, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


def assert_curves_close(a, b, tol=0.02):
    assert a.cycles == b.cycles
    for xa, xb in zip(a.err_fresh, b.err_fresh):
        assert abs(xa - xb) <= tol, (a.err_fresh, b.err_fresh)
    for xa, xb in zip(a.err_voted, b.err_voted):
        assert abs(xa - xb) <= tol, (a.err_voted, b.err_voted)


def test_key_schedule_matches_host_split_loop():
    keys = key_schedule(7, 5)
    k = jax.random.key(7)
    for c in range(5):
        k, sub = jax.random.split(k)
        assert jnp.all(jax.random.key_data(keys[c]) == jax.random.key_data(sub))


def test_sharded_matches_reference_clean_scenario():
    X, y, Xt, yt = toy()
    kw = dict(cycles=30, eval_every=10, seed=1)
    ref = run_simulation(small_cfg(), X, y, Xt, yt, **kw)
    sh = run_simulation(small_cfg(), X, y, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)
    assert (ref.sent_total, ref.delivered_total, ref.lost_total,
            ref.overflow_total) == (sh.sent_total, sh.delivered_total,
                                    sh.lost_total, sh.overflow_total)


def test_sharded_matches_reference_failure_scenario():
    """Drop 0.5 + 10Δ delay + churn — the paper's extreme setting."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9)
    kw = dict(cycles=40, eval_every=20, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)
    assert ref.lost_total == sh.lost_total > 0  # churn actually loses messages


@pytest.mark.parametrize("variant", ["mu", "um", "rw"])
def test_sharded_pallas_kernel_matches_reference(variant):
    """The fused gossip_cycle kernel path (interpret mode on CPU)."""
    X, y, Xt, yt = toy(n=64)
    cfg = small_cfg(n_nodes=64, variant=variant, drop_prob=0.2,
                    delay_max_cycles=3)
    kw = dict(cycles=20, eval_every=10, seed=5)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                        use_pallas=True, interpret=True, **kw)
    assert_curves_close(ref, sh)


@pytest.mark.parametrize("sampler", ["uniform", "matching"])
@pytest.mark.parametrize("n", [32, 33])
def test_sharded_engine_odd_and_even_populations(sampler, n):
    """Both engines handle odd N — incl. the matching sampler's idle node."""
    X, y, Xt, yt = toy(n=n)
    kw = dict(cycles=16, eval_every=8, seed=2, sampler=sampler)
    ref = run_simulation(small_cfg(n_nodes=n), X, y, Xt, yt, **kw)
    sh = run_simulation(small_cfg(n_nodes=n), X, y, Xt, yt,
                        engine="sharded", **kw)
    assert_curves_close(ref, sh)
    if sampler == "matching" and n % 2 == 1:
        # one node idles per cycle: at most (n-1) sends per cycle
        assert ref.sent_total <= (n - 1) * 16


def test_sharded_engine_multirecord_nodes():
    """(N, k, d) multi-record nodes stream through the scan path too."""
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 64 * 3 + 32, 8, noise=0.05)
    Xtr = X[:192].reshape(64, 3, 8)
    ytr = y[:192].reshape(64, 3)
    Xt, yt = X[192:], y[192:]
    cfg = small_cfg(n_nodes=64, dim=8)
    kw = dict(cycles=12, eval_every=6, seed=4)
    ref = run_simulation(cfg, Xtr, ytr, Xt, yt, **kw)
    sh = run_simulation(cfg, Xtr, ytr, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)


def test_sharded_engine_rejects_unknown_engine():
    X, y, Xt, yt = toy(n=16)
    with pytest.raises(ValueError, match="unknown engine"):
        run_simulation(small_cfg(n_nodes=16), X, y, Xt, yt, cycles=2,
                       engine="bogus")


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.configs.gossip_linear import GossipLinearConfig
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset

    assert len(jax.devices()) == 4
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 128 + 64, 16, noise=0.05, separation=3.0)
    Xtr, ytr, Xt, yt = X[:128], y[:128], X[128:], y[128:]
    cfg = GossipLinearConfig(name="toy", dim=16, n_nodes=128, n_test=64,
                             class_ratio=(1, 1), lam=1e-3, variant="mu",
                             drop_prob=0.3, delay_max_cycles=4)
    kw = dict(cycles=20, eval_every=10, seed=6)
    ref = run_simulation(cfg, Xtr, ytr, Xt, yt, **kw)
    mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("nodes",))
    sh = run_simulation(cfg, Xtr, ytr, Xt, yt, engine="sharded",
                        mesh=mesh, **kw)
    for a, b in zip(ref.err_fresh, sh.err_fresh):
        assert abs(a - b) <= 0.02, (ref.err_fresh, sh.err_fresh)
    assert ref.sent_total == sh.sent_total
    print("MESH_PARITY_OK")
""")


def test_sharded_engine_multidevice_mesh_parity():
    """shard_map node-axis path on a 4-device (forced host) mesh.

    Runs in a subprocess because device count must be fixed before JAX
    initializes (tests/conftest.py pins the main process to one device)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MESH_PARITY_OK" in out.stdout
