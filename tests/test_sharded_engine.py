"""Sharded mega-population engine: parity with the reference engine.

The sharded engine consumes the same host RNG stream and per-cycle key
sequence as the reference driver and shares its cycle math, so for a given
seed the error curves must reproduce the reference engine's (the acceptance
bar is 0.02 at every eval point; in practice they are bitwise-equal)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core.sharded_engine import (dense_table, key_schedule,
                                       pack_compact_rounds)
from repro.core.simulation import message_wire_bytes, run_simulation
from repro.data.synthetic import make_linear_dataset


def small_cfg(n_nodes=128, **kw):
    base = dict(name="toy", dim=16, n_nodes=n_nodes, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def toy(n=128, d=16, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 64, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


def assert_curves_close(a, b, tol=0.02):
    assert a.cycles == b.cycles
    for xa, xb in zip(a.err_fresh, b.err_fresh):
        assert abs(xa - xb) <= tol, (a.err_fresh, b.err_fresh)
    for xa, xb in zip(a.err_voted, b.err_voted):
        assert abs(xa - xb) <= tol, (a.err_voted, b.err_voted)


def test_key_schedule_matches_host_split_loop():
    keys = key_schedule(7, 5)
    k = jax.random.key(7)
    for c in range(5):
        k, sub = jax.random.split(k)
        assert jnp.all(jax.random.key_data(keys[c]) == jax.random.key_data(sub))


def test_sharded_matches_reference_clean_scenario():
    X, y, Xt, yt = toy()
    kw = dict(cycles=30, eval_every=10, seed=1)
    ref = run_simulation(small_cfg(), X, y, Xt, yt, **kw)
    sh = run_simulation(small_cfg(), X, y, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)
    assert (ref.sent_total, ref.delivered_total, ref.lost_total,
            ref.overflow_total) == (sh.sent_total, sh.delivered_total,
                                    sh.lost_total, sh.overflow_total)


def test_sharded_matches_reference_failure_scenario():
    """Drop 0.5 + 10Δ delay + churn — the paper's extreme setting."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9)
    kw = dict(cycles=40, eval_every=20, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)
    assert ref.lost_total == sh.lost_total > 0  # churn actually loses messages


@pytest.mark.parametrize("wire", [None, "bf16", "int8", "int8_sr"])
@pytest.mark.parametrize("variant", ["mu", "um", "rw"])
def test_sharded_pallas_kernel_matches_reference(variant, wire):
    """The fused gossip_cycle kernel path (interpret mode on CPU), including
    bf16 wire message operands (the widened 16-sublane node block) and
    affine-int8 operands (32-sublane block, in-kernel dequant from the
    per-message f16 scale/zero-point)."""
    X, y, Xt, yt = toy(n=64)
    cfg = small_cfg(n_nodes=64, variant=variant, drop_prob=0.2,
                    delay_max_cycles=3, wire_dtype=wire)
    kw = dict(cycles=20, eval_every=10, seed=5)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                        use_pallas=True, interpret=True, **kw)
    assert_curves_close(ref, sh)


@pytest.mark.parametrize("sampler", ["uniform", "matching"])
@pytest.mark.parametrize("n", [32, 33])
def test_sharded_engine_odd_and_even_populations(sampler, n):
    """Both engines handle odd N — incl. the matching sampler's idle node."""
    X, y, Xt, yt = toy(n=n)
    kw = dict(cycles=16, eval_every=8, seed=2, sampler=sampler)
    ref = run_simulation(small_cfg(n_nodes=n), X, y, Xt, yt, **kw)
    sh = run_simulation(small_cfg(n_nodes=n), X, y, Xt, yt,
                        engine="sharded", **kw)
    assert_curves_close(ref, sh)
    if sampler == "matching" and n % 2 == 1:
        # one node idles per cycle: at most (n-1) sends per cycle
        assert ref.sent_total <= (n - 1) * 16


def test_sharded_engine_multirecord_nodes():
    """(N, k, d) multi-record nodes stream through the scan path too."""
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 64 * 3 + 32, 8, noise=0.05)
    Xtr = X[:192].reshape(64, 3, 8)
    ytr = y[:192].reshape(64, 3)
    Xt, yt = X[192:], y[192:]
    cfg = small_cfg(n_nodes=64, dim=8)
    kw = dict(cycles=12, eval_every=6, seed=4)
    ref = run_simulation(cfg, Xtr, ytr, Xt, yt, **kw)
    sh = run_simulation(cfg, Xtr, ytr, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)


def test_compact_and_dense_rounds_agree():
    """compact_rounds gathers/applies/scatters only multi-receivers; the
    curves and the message economy must equal the dense K-round apply."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9)
    kw = dict(cycles=40, eval_every=20, seed=3, engine="sharded")
    dense = run_simulation(cfg, X, y, Xt, yt, compact_rounds=False, **kw)
    comp = run_simulation(cfg, X, y, Xt, yt, compact_rounds=True, **kw)
    assert dense.err_fresh == comp.err_fresh
    assert dense.err_voted == comp.err_voted
    assert (dense.sent_total, dense.delivered_total, dense.lost_total,
            dense.overflow_total) == (comp.sent_total, comp.delivered_total,
                                      comp.lost_total, comp.overflow_total)


def test_pack_compact_rounds_covers_every_multi_receive():
    """The compacted tables must encode exactly the dense table's rounds:
    round 1 dense, every round >= 2 receive present at the receiver's
    compact position, padding inert (-1)."""
    rng = np.random.default_rng(0)
    T, K, n = 3, 4, 32
    src_slot = np.full((T, K, n), -1, np.int32)
    for t in range(T):
        recv = rng.choice(n, size=12, replace=False)
        for j, node in enumerate(recv):
            depth = 1 + (j % K)              # winner rounds fill in order
            src_slot[t, :depth, node] = rng.integers(0, 64, size=depth)
    multi = [np.flatnonzero(src_slot[t, 1] >= 0).astype(np.int32)
             for t in range(T)]
    # the winner-tuple form the router emits (ascending t)
    t_w, r_w, dst_w = (a.astype(np.int32) for a in np.nonzero(src_slot >= 0))
    win = (t_w, r_w, dst_w, src_slot[t_w, r_w, dst_w])
    assert np.array_equal(dense_table(win, T, K, n), src_slot)
    width = max(r.size for r in multi) + 3   # over-wide: padding must be inert
    src0, ridx, rslot = pack_compact_rounds(win, multi, T, K, n, width)
    assert np.array_equal(src0, src_slot[:, 0])
    for t in range(T):
        m = multi[t]
        assert np.array_equal(ridx[t, :m.size], m)
        assert np.all(ridx[t, m.size:] == -1)
        assert np.all(rslot[t, :, m.size:] == -1)
        for k in range(1, K):
            assert np.array_equal(rslot[t, k - 1, :m.size], src_slot[t, k, m])


def test_compact_dense_fallback_mid_run(monkeypatch):
    """A chunk whose receiver subsets are near-full (> N/2) must fall back
    to the dense table without disturbing the compact chunks around it.
    Forced by making the router report full receiver lists for one chunk —
    the src_slot table stays truthful, so the dense path must reproduce the
    reference curves while the run mixes compact and dense chunk fns."""
    from repro.core import sharded_engine as se

    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9)
    kw = dict(cycles=30, eval_every=10, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)

    orig = se._HostRouter.route_chunk
    calls = []

    def fake(self, dsts, arrivals, online_rows, clock0, k_rounds,
             per_cycle_stats=False):
        src_slot, stats, multi, recv = orig(self, dsts, arrivals,
                                            online_rows, clock0, k_rounds,
                                            per_cycle_stats=per_cycle_stats)
        if len(calls) == 1:           # middle chunk: claim near-full rounds
            full = [np.arange(self.n, dtype=np.int32)] * len(multi)
            multi, recv = full, full
        calls.append(max(r.size for r in multi))
        return src_slot, stats, multi, recv

    monkeypatch.setattr(se._HostRouter, "route_chunk", fake)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                        compact_rounds=True, **kw)
    assert len(calls) == 3 and calls[1] == 128  # fallback chunk was forced
    assert sh.compaction["chunk_modes"]["dense"] == 1  # ... and ran dense
    assert_curves_close(ref, sh)
    assert ref.sent_total == sh.sent_total


def test_sharded_wire_bf16_matches_reference_wire_bf16():
    """Both engines quantize at send time with the same semantics, so the
    bf16-wire curves must agree across engines like the f32 curves do."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9,
                    wire_dtype="bf16")
    kw = dict(cycles=40, eval_every=20, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert_curves_close(ref, sh)
    assert (ref.sent_total, ref.delivered_total) == (sh.sent_total,
                                                     sh.delivered_total)


def test_wire_bf16_curves_close_to_f32():
    """Documented tolerance: bf16 wire quantization moves the error curves
    by at most 0.05 at any eval point on the toy problem."""
    X, y, Xt, yt = toy()
    kw = dict(cycles=30, eval_every=10, seed=1, engine="sharded")
    f32 = run_simulation(small_cfg(), X, y, Xt, yt, **kw)
    bf16 = run_simulation(small_cfg(wire_dtype="bf16"), X, y, Xt, yt, **kw)
    assert_curves_close(f32, bf16, tol=0.05)


def test_wire_quantization_matches_gossip_merge_exchange():
    """The simulator's wire semantics — quantize the transmitted model,
    merge in f32 — must equal gossip_merge's exchange_dtype semantics."""
    from repro.core.gossip_optimizer import gossip_merge
    from repro.core.learners import LinearModel
    from repro.core.merge import merge

    w = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)), jnp.float32)
    out = gossip_merge({"w": w}, np.array([1, 0]),
                       exchange_dtype=jnp.bfloat16)["w"]
    # simulator: peer 1's model is quantized at send time, peer 0 merges it
    # (in f32) with its own full-precision model
    msg = w[1].astype(jnp.bfloat16).astype(jnp.float32)
    t = jnp.zeros((), jnp.int32)
    mine = merge(LinearModel(msg, t), LinearModel(w[0], t)).w
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(mine))


@pytest.mark.parametrize("engine", ["reference", "sharded"])
def test_wire_and_buffer_accounting(engine):
    """wire_bytes_total and buf_payload_bytes follow the wire dtype exactly;
    bf16 halves both the per-coefficient wire cost and the dominant
    in-flight buffer."""
    X, y, Xt, yt = toy(n=32)
    kw = dict(cycles=10, eval_every=10, seed=0, engine=engine)
    d, D = 16, 4
    f32 = run_simulation(small_cfg(n_nodes=32, delay_max_cycles=D),
                         X, y, Xt, yt, **kw)
    bf16 = run_simulation(small_cfg(n_nodes=32, delay_max_cycles=D,
                                    wire_dtype="bf16"), X, y, Xt, yt, **kw)
    assert message_wire_bytes(d, None) == d * 4 + 4
    assert message_wire_bytes(d, "bf16") == d * 2 + 4
    assert f32.wire_bytes_total == f32.sent_total * (d * 4 + 4)
    assert bf16.wire_bytes_total == bf16.sent_total * (d * 2 + 4)
    assert f32.buf_payload_bytes == D * 32 * d * 4
    assert bf16.buf_payload_bytes == D * 32 * d * 2
    assert bf16.sent_total == f32.sent_total  # routing is payload-blind


def test_sharded_engine_rejects_unknown_engine():
    X, y, Xt, yt = toy(n=16)
    with pytest.raises(ValueError, match="unknown engine"):
        run_simulation(small_cfg(n_nodes=16), X, y, Xt, yt, cycles=2,
                       engine="bogus")


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.configs.gossip_linear import GossipLinearConfig
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset

    assert len(jax.devices()) == 4
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 128 + 64, 16, noise=0.05, separation=3.0)
    Xtr, ytr, Xt, yt = X[:128], y[:128], X[128:], y[128:]
    cfg = GossipLinearConfig(name="toy", dim=16, n_nodes=128, n_test=64,
                             class_ratio=(1, 1), lam=1e-3, variant="mu",
                             drop_prob=0.3, delay_max_cycles=4)
    kw = dict(cycles=20, eval_every=10, seed=6)
    ref = run_simulation(cfg, Xtr, ytr, Xt, yt, **kw)
    mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("nodes",))
    sh = run_simulation(cfg, Xtr, ytr, Xt, yt, engine="sharded",
                        mesh=mesh, **kw)
    for a, b in zip(ref.err_fresh, sh.err_fresh):
        assert abs(a - b) <= 0.02, (ref.err_fresh, sh.err_fresh)
    assert ref.sent_total == sh.sent_total
    # compacted rounds now run UNDER the node mesh (per-shard packed
    # tables): the default run must have used a compact packing
    assert sh.compaction["shards"] == 4, sh.compaction
    cm = sh.compaction["chunk_modes"]
    assert cm["compact"] + cm["compact_all"] > 0, cm

    # forced shard-local compact_all parity under the mesh
    sha = run_simulation(cfg, Xtr, ytr, Xt, yt, engine="sharded",
                         mesh=mesh, compact_mode="compact_all", **kw)
    assert sha.err_fresh == sh.err_fresh
    assert sha.sent_total == sh.sent_total

    # int8 wire dtype under node sharding: the (D, N) scale/zero-point
    # lanes shard with the buffer and parity still holds
    import dataclasses
    cfg8 = dataclasses.replace(cfg, wire_dtype="int8_sr")
    ref8 = run_simulation(cfg8, Xtr, ytr, Xt, yt, **kw)
    sh8 = run_simulation(cfg8, Xtr, ytr, Xt, yt, engine="sharded",
                         mesh=mesh, **kw)
    for a, b in zip(ref8.err_fresh, sh8.err_fresh):
        assert abs(a - b) <= 0.02, (ref8.err_fresh, sh8.err_fresh)
    assert ref8.sent_total == sh8.sent_total

    # packed int4 + error feedback under node sharding: the packed (D, N,
    # ceil(d/2)) payload, the scale lane AND the (N, d) EF residual all
    # shard over the node axis; the residual telemetry matches the
    # reference engine exactly
    cfg4 = dataclasses.replace(cfg, wire_dtype="int4_ef")
    ref4 = run_simulation(cfg4, Xtr, ytr, Xt, yt, **kw)
    sh4 = run_simulation(cfg4, Xtr, ytr, Xt, yt, engine="sharded",
                         mesh=mesh, **kw)
    for a, b in zip(ref4.err_fresh, sh4.err_fresh):
        assert abs(a - b) <= 0.02, (ref4.err_fresh, sh4.err_fresh)
    assert ref4.sent_total == sh4.sent_total
    assert abs(ref4.ef_residual_norm - sh4.ef_residual_norm) \\
        <= 1e-6 * max(ref4.ef_residual_norm, 1.0)
    print("MESH_PARITY_OK")
""")


def test_sharded_engine_multidevice_mesh_parity():
    """shard_map node-axis path on a 4-device (forced host) mesh.

    Runs in a subprocess because device count must be fixed before JAX
    initializes (tests/conftest.py pins the main process to one device)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MESH_PARITY_OK" in out.stdout
