import os

# Tests must see ONE device (only launch/dryrun.py forces 512). Keep any
# user-provided XLA_FLAGS but never the host-device override.
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" in flags:
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in flags.split() if "host_platform_device_count" not in f)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
