"""Serving-tier pins: kernel parity, vote semantics, snapshot-under-churn.

Three contracts. (1) The Pallas ``voted_predict_batched`` path answers
bitwise identically to the jnp ``serve_voted`` einsum path and to the
``cache.voted_predict`` oracle — across cache fill levels (count 1,
partial, wrapped ring), odd query-batch sizes and N not a multiple of the
node block. (2) The exact voting semantics of ``voted_predict``: the
``p_ratio == 0.5`` tie goes positive, a zero score votes positive, and the
rule intentionally diverges from ``ensemble._weighted_vote_err``'s
score-sum vote. (3) Serving never perturbs the protocol: a run with a
serve hook produces bitwise the curves of a run without one, snapshots are
bitwise identical across engines, and served answers are reproducible for
a fixed seed."""
import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core import cache as cache_mod
from repro.core import serving
from repro.core.cache import ModelCache, cache_add, init_cache
from repro.core.simulation import run_simulation
from repro.kernels.voted_predict import voted_predict_batched
from repro.launch.gossip_serve import GossipServer


def _filled_cache(n: int, c: int, d: int, adds: int, seed: int) -> ModelCache:
    """A cache after ``adds`` all-node cache_add rounds (count = 1 + adds,
    saturating at c; adds > c wraps the ring so ptr has lapped it)."""
    cache = init_cache(n, c, d)
    key = jax.random.key(seed)
    for i in range(adds):
        key, sub = jax.random.split(key)
        w_new = jax.random.normal(sub, (n, d), jnp.float32)
        cache = cache_add(cache, jnp.ones(n, bool), w_new,
                          jnp.full((n,), i + 1, jnp.int32))
    return cache


def _queries(m: int, n: int, d: int, seed: int):
    key = jax.random.key(seed)
    X = jax.random.normal(jax.random.fold_in(key, 0), (m, d), jnp.float32)
    assign = jax.random.randint(jax.random.fold_in(key, 1), (m,), 0, n)
    return X, assign.astype(jnp.int32)


# --------------------------------------------------------------------------
# (1) batched-vote parity: kernel == jnp path == voted_predict oracle
# --------------------------------------------------------------------------

# (n, c, d, m, adds): count=1 fresh cache, partially filled, wrapped ring;
# odd m; n=33 not a multiple of the node block; d off the 128-lane grid
PARITY_CASES = [
    (8, 4, 8, 8, 0),        # count == 1 everywhere (init model only)
    (33, 5, 57, 11, 3),     # partial fill, odd batch, off-block n and d
    (16, 4, 16, 37, 9),     # ring wrapped twice
    (10, 3, 128, 1, 2),     # single-query batch, lane-aligned d
    (9, 8, 30, 5, 20),      # deep wrap, c on the sublane boundary
]


@pytest.mark.parametrize("n,c,d,m,adds", PARITY_CASES)
def test_serve_voted_matches_oracle(n, c, d, m, adds):
    """jnp serve path == row-gathered cache.voted_predict, bitwise."""
    cache = _filled_cache(n, c, d, adds, seed=n * d + adds)
    X, assign = _queries(m, n, d, seed=m)
    got = serving.serve_voted(cache.w, cache.count, X, assign)
    full = cache_mod.voted_predict(cache, X)        # (N, m)
    exp = full[assign, jnp.arange(m)]
    npt.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("n,c,d,m,adds", PARITY_CASES)
def test_kernel_matches_jnp_path(n, c, d, m, adds):
    """Pallas voted_predict_batched == serve_voted, bitwise, and the
    serve_voted_kernel wrapper == the direct kernel call."""
    cache = _filled_cache(n, c, d, adds, seed=n * d + adds)
    X, assign = _queries(m, n, d, seed=m + 1)
    exp = serving.serve_voted(cache.w, cache.count, X, assign)
    direct = voted_predict_batched(cache.w[assign], cache.count[assign], X,
                                   interpret=True)
    wrapped = serving.serve_voted_kernel(cache.w, cache.count, X, assign)
    npt.assert_array_equal(np.asarray(direct), np.asarray(exp))
    npt.assert_array_equal(np.asarray(wrapped), np.asarray(exp))


def test_serve_fresh_matches_predict_fresh():
    cache = _filled_cache(12, 4, 19, 6, seed=5)
    X, assign = _queries(23, 12, 19, seed=9)
    fresh_w, _ = cache_mod.freshest(cache)
    got = serving.serve_fresh(fresh_w, X, assign)
    exp = cache_mod.predict_fresh(cache, X)[assign, jnp.arange(23)]
    npt.assert_array_equal(np.asarray(got), np.asarray(exp))


# --------------------------------------------------------------------------
# (2) voting semantics of cache.voted_predict — edge cases pinned
# --------------------------------------------------------------------------

def _cache_with_scores(first_coords, count=None):
    """One node whose cache slots score exactly ``first_coords`` against the
    query x = e_0 — every score is an exactly-representable small float, so
    the sign tests below are free of rounding."""
    first_coords = np.asarray(first_coords, np.float32)
    c = len(first_coords)
    w = np.zeros((1, c, 4), np.float32)
    w[0, :, 0] = first_coords
    cnt = np.array([c if count is None else count], np.int32)
    return ModelCache(jnp.asarray(w), jnp.zeros((1, c), jnp.int32),
                      jnp.asarray(cnt), jnp.asarray(cnt))


X_E0 = jnp.asarray(np.eye(1, 4, dtype=np.float32))      # the query e_0


def test_voted_predict_tie_breaks_positive():
    """p_ratio == 0.5 exactly (2 of 4 votes positive) predicts +1: the
    ``p_ratio - 0.5 >= 0`` rule at cache.py:81 ties up, never -1."""
    cache = _cache_with_scores([1.0, 2.0, -1.0, -2.0])
    pred = cache_mod.voted_predict(cache, X_E0)
    npt.assert_array_equal(np.asarray(pred), [[1.0]])
    # the serving paths inherit the same tie-break
    got = serving.serve_voted(cache.w, cache.count, X_E0,
                              jnp.zeros(1, jnp.int32))
    gotk = serving.serve_voted_kernel(cache.w, cache.count, X_E0,
                                      jnp.zeros(1, jnp.int32))
    npt.assert_array_equal(np.asarray(got), [1.0])
    npt.assert_array_equal(np.asarray(gotk), [1.0])


def test_voted_predict_one_below_tie_is_negative():
    """1 of 4 votes positive -> p_ratio 0.25 < 0.5 -> -1 (the tie-break
    boundary is sharp: exactly half is +1, strictly below is -1)."""
    cache = _cache_with_scores([1.0, -1.0, -1.0, -2.0])
    pred = cache_mod.voted_predict(cache, X_E0)
    npt.assert_array_equal(np.asarray(pred), [[-1.0]])


def test_voted_predict_zero_score_votes_positive():
    """score == 0 votes +1 (the ``scores >= 0`` sign convention): a zero
    model — every node's cache slot 0 at init — is a positive voter, so
    the init-only cache predicts +1 everywhere."""
    cache = _cache_with_scores([0.0, -1.0], count=2)
    # zero score votes +1 -> 1 of 2 positive -> tie -> +1
    pred = cache_mod.voted_predict(cache, X_E0)
    npt.assert_array_equal(np.asarray(pred), [[1.0]])
    init = init_cache(3, 4, 4)
    npt.assert_array_equal(
        np.asarray(cache_mod.voted_predict(init, X_E0)), np.ones((3, 1)))
    npt.assert_array_equal(
        np.asarray(serving.serve_voted_kernel(
            init.w, init.count, X_E0, jnp.zeros(1, jnp.int32))), [1.0])


def test_voted_predict_diverges_from_score_sum_vote():
    """Algorithm 4 counts ±1 votes; ``ensemble._weighted_vote_err`` sums raw
    scores (ensemble.py:45) — intentionally different rules. A cache of two
    weak positives and one strong negative splits them: majority vote +1,
    score sum 1 + 1 - 10 < 0 -> -1."""
    from repro.core.ensemble import _weighted_vote_err
    coords = [1.0, 1.0, -10.0]
    cache = _cache_with_scores(coords)
    pred_vote = cache_mod.voted_predict(cache, X_E0)
    npt.assert_array_equal(np.asarray(pred_vote), [[1.0]])
    # same three models through the score-sum rule: predicts -1, so its
    # error against the +1 label is 1.0
    W = cache.w[0]                                   # (3, 4) model bank
    err_sum = _weighted_vote_err(W, X_E0, jnp.ones(1, jnp.float32))
    assert float(err_sum) == 1.0


# --------------------------------------------------------------------------
# (3) snapshots: non-perturbing, engine-parity, reproducible answers
# --------------------------------------------------------------------------

N_NODES, DIM, CYCLES, EVAL_EVERY = 96, 12, 12, 4


def _sim_setup(seed=0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, N_NODES + 64, DIM, noise=0.07,
                               separation=2.5)
    cfg = GossipLinearConfig(name="serve-test", dim=DIM, n_nodes=N_NODES,
                             n_test=64, class_ratio=(1, 1), lam=1e-3,
                             cache_size=4)
    return cfg, X[:N_NODES], y[:N_NODES], X[N_NODES:], y[N_NODES:]


def _run(engine, serve_hook=None, scenario=None):
    cfg, X, y, Xt, yt = _sim_setup()
    if scenario is not None:
        from repro.configs.gossip_linear import with_failure_scenario
        cfg = with_failure_scenario(cfg, scenario)
    return run_simulation(cfg, X, y, Xt, yt, cycles=CYCLES,
                          eval_every=EVAL_EVERY, seed=3, engine=engine,
                          serve_hook=serve_hook)


@pytest.mark.parametrize("engine", ["reference", "sharded"])
@pytest.mark.parametrize("scenario", [None, "extreme"])
def test_serving_does_not_perturb_the_run(engine, scenario):
    """A hooked run (snapshots taken AND queries served at every eval
    point) produces bitwise the same error curves as an unhooked run."""
    srv = GossipServer(batch_size=16, seed=1)
    qX = np.asarray(_sim_setup()[3][:24], np.float32)

    def hook(cycle, snap):
        srv.serve_hook(cycle, snap)
        srv.submit(qX)

    hooked = _run(engine, serve_hook=hook, scenario=scenario)
    srv.flush()
    assert srv.stats().queries == 24 * len(hooked.cycles)
    clean = _run(engine, scenario=scenario)
    npt.assert_array_equal(np.asarray(hooked.err_fresh),
                           np.asarray(clean.err_fresh))
    npt.assert_array_equal(np.asarray(hooked.err_voted),
                           np.asarray(clean.err_voted))


def test_snapshot_parity_reference_vs_sharded():
    """Snapshots at every eval point are bitwise identical across engines
    (the serving-tier extension of the engine parity contract). The hook
    copies to host immediately: the sharded scan donates its carry."""
    def collect(store):
        def hook(cycle, snap):
            store[cycle] = jax.tree.map(np.array, snap)
        return hook

    ref_snaps, sh_snaps = {}, {}
    _run("reference", serve_hook=collect(ref_snaps))
    _run("sharded", serve_hook=collect(sh_snaps))
    assert sorted(ref_snaps) == sorted(sh_snaps) and ref_snaps
    for cyc in ref_snaps:
        for field, a, b in zip(serving.QuerySnapshot._fields,
                               ref_snaps[cyc], sh_snaps[cyc]):
            npt.assert_array_equal(a, b, err_msg=f"cycle {cyc}: {field}")


@pytest.mark.parametrize("use_kernel", [False, True])
def test_served_answers_reproducible_and_kernel_invariant(use_kernel):
    """Same seed + same submission order -> bitwise identical answers, on
    both serve paths — and the kernel path answers == the jnp path."""
    def serve_once(kernel):
        srv = GossipServer(batch_size=16, policy="uniform", seed=5,
                           use_kernel=kernel)
        qX = np.asarray(_sim_setup()[3][:40], np.float32)

        def hook(cycle, snap):
            srv.serve_hook(cycle, snap)
            srv.submit(qX)

        _run("sharded", serve_hook=hook)
        srv.flush()
        return srv.answers(), srv.answers_fresh()

    a1, f1 = serve_once(use_kernel)
    a2, f2 = serve_once(use_kernel)
    npt.assert_array_equal(a1, a2)
    npt.assert_array_equal(f1, f2)
    if use_kernel:
        aj, fj = serve_once(False)
        npt.assert_array_equal(a1, aj)
        npt.assert_array_equal(f1, fj)


def test_gossip_server_batching_and_order():
    """Batch accumulation: submits below batch_size stay pending, crossing
    it serves exactly batch_size, flush pads + serves the tail, and
    answers() returns submission order regardless of batch boundaries."""
    cache = _filled_cache(6, 3, 5, 4, seed=2)
    snap = serving._snapshot(cache, jnp.int32(7))
    srv = GossipServer(batch_size=8, policy="round_robin")
    srv.serve_hook(3, snap)

    key = jax.random.key(11)
    X = np.asarray(jax.random.normal(key, (13, 5)), np.float32)
    srv.submit(X[:5])
    assert not srv.batches                       # 5 < 8: still pending
    srv.submit(X[5:11])
    assert [b.size for b in srv.batches] == [8]  # crossed once, served 8
    srv.submit(X[11:])
    srv.flush()
    assert [b.size for b in srv.batches] == [8, 5]
    assert all(b.cycle == 3 for b in srv.batches)
    # answers in submission order == serving the whole set in one shot
    assign = serving.assign_queries(16, 6, policy="round_robin")
    exp = serving.serve_voted(cache.w, cache.count,
                              jnp.asarray(np.concatenate(
                                  [X, np.zeros((3, 5), np.float32)])),
                              jnp.asarray(assign))
    npt.assert_array_equal(srv.answers(), np.asarray(exp)[:13])
    with pytest.raises(RuntimeError):
        GossipServer(batch_size=2).submit(X[:2])  # no snapshot adopted


def test_assign_queries_policies():
    rr = serving.assign_queries(7, 3, policy="round_robin", offset=2)
    npt.assert_array_equal(rr, [2, 0, 1, 2, 0, 1, 2])
    u1 = serving.assign_queries(64, 9, policy="uniform", seed=4, offset=0)
    u2 = serving.assign_queries(64, 9, policy="uniform", seed=4, offset=0)
    npt.assert_array_equal(u1, u2)               # deterministic per (seed, offset)
    u3 = serving.assign_queries(64, 9, policy="uniform", seed=4, offset=64)
    assert not np.array_equal(u1, u3)            # offset advances the stream
    assert u1.min() >= 0 and u1.max() < 9
    with pytest.raises(ValueError):
        serving.assign_queries(4, 3, policy="nope")
