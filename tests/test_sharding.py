"""Sharding-rule unit tests: divisibility fallbacks, profiles, cache specs."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.sharding.rules import (LogicalRules, cache_pspecs, default_rules,
                                  partition_spec)

SIZES = {"data": 16, "model": 16}
SIZES_MP = {"pod": 2, "data": 16, "model": 16}


def test_ffn_shards_on_model():
    r = default_rules()
    ps = partition_spec((4096, 12288), ("embed", "ffn"), SIZES, r)
    assert ps == PS("data", "model")


def test_indivisible_heads_fall_back_to_replication():
    """llama4-scout: 40 heads % 16 != 0 -> heads dim replicated."""
    r = default_rules()
    ps = partition_spec((5120, 40, 128), ("embed", "heads", "head_dim"), SIZES, r)
    assert ps == PS("data", None) or ps == PS("data")


def test_kv_heads_8_on_16way_model_axis_replicated():
    r = default_rules()
    ps = partition_spec((4096, 8, 128), ("embed", "kv_heads", "head_dim"),
                        SIZES, r)
    assert ps[1] is None if len(ps) > 1 else True


def test_small_params_always_replicated():
    r = default_rules()
    ps = partition_spec((2048,), ("embed",), SIZES, r)
    assert ps == PS()


def test_expert_profile_shards_expert_dim():
    r = default_rules(moe_sharding="expert")
    ps = partition_spec((16, 5120, 8192), ("expert", "embed", "expert_ffn"),
                        SIZES, r)
    assert ps[0] == "model"


def test_tensor_profile_shards_expert_ffn():
    r = default_rules(moe_sharding="tensor")
    ps = partition_spec((8, 6144, 16384), ("expert", "embed", "expert_ffn"),
                        SIZES, r)
    assert len(ps) == 3 and ps[0] is None and ps[2] == "model"


def test_no_double_use_of_one_mesh_axis():
    r = default_rules()
    ps = partition_spec((4096, 4096), ("ffn", "ffn"), SIZES, r)
    used = [p for p in ps if p is not None]
    assert len(used) <= 1


def test_multi_pod_fsdp_uses_pod_and_data():
    r = default_rules(multi_pod=True)
    ps = partition_spec((16384, 53248), ("embed", "ffn"), SIZES_MP, r)
    assert ps[0] == ("pod", "data")


def test_gossip_peer_axes_excluded_from_fsdp():
    r = default_rules(peer_axes=("data",))
    ps = partition_spec((4096, 12288), ("embed", "ffn"), SIZES, r)
    assert ps[0] is None or ps[0] == "model"  # 'data' reserved for peers


def test_embed_table_never_fsdp():
    r = default_rules()
    ps = partition_spec((151936, 2048), ("vocab", "embed_table"), SIZES, r)
    assert ps == PS("model")


def test_cache_pspec_batch_sharded():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sds = {"k": jax.ShapeDtypeStruct((2, 128, 32, 8, 128), np.float32)}
    # on a 1x1 mesh everything degrades to replication without error
    specs = cache_pspecs(sds, mesh)
    assert isinstance(specs["k"], PS)
