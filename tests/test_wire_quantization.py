"""Affine int8 wire dtypes: quantizer properties, reproducibility,
accounting, and cross-engine/optimizer parity.

The wire contract (shared by the protocol simulator and the on-mesh
optimizer): the *transmitted* model is quantized — per message, with an f16
scale/zero-point pair riding along — and every merge runs in f32 on the
dequantized values. "int8" rounds to nearest; "int8_sr" rounds
stochastically from a counter-based threefry key, so runs stay bitwise
reproducible."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core.gossip_optimizer import (INT8_QMAX, dequantize_wire,
                                         gossip_merge, is_quantized_wire,
                                         quantize_wire, resolve_wire_dtype,
                                         wire_itemsize, wire_overhead_bytes)
from repro.core.simulation import (message_wire_bytes, payload_buffer_bytes,
                                   run_simulation)
from repro.data.synthetic import make_linear_dataset


def small_cfg(n_nodes=128, **kw):
    base = dict(name="toy", dim=16, n_nodes=n_nodes, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def toy(n=128, d=16, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 64, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


# ---------------------------------------------------------------------------
# quantizer properties
# ---------------------------------------------------------------------------


def _random_messages(rng, n, d):
    """Messages spanning the regimes the quantizer must survive: mixed
    magnitudes, large offsets with tiny ranges, constant rows, zeros."""
    w = rng.normal(size=(n, d)) * np.exp(rng.uniform(-6, 6, size=(n, 1)))
    w += rng.normal(size=(n, 1)) * np.exp(rng.uniform(-2, 8, size=(n, 1)))
    w[0] = 0.0                      # the all-zero init model
    w[1] = w[1, 0]                  # constant row: scale collapses to 0
    w[2, :] = 1000.0
    w[2, 0] = 1000.001              # huge offset, tiny range
    return jnp.asarray(w, jnp.float32)


@pytest.mark.parametrize("wire", ["int8", "int8_sr"])
def test_roundtrip_error_bounded_by_one_step(wire):
    """Property: per coordinate, |w - dequant(quant(w))| <= one quantization
    step of the *transmitted* (f16-rounded) scale — for every message,
    including degenerate ranges. Half a step for round-to-nearest."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        w = _random_messages(rng, 64, 24)
        key = jax.random.key(trial)
        q, sc, zp = quantize_wire(w, wire, key=key)
        assert q.dtype == jnp.int8
        back = dequantize_wire(q, sc, zp)
        step = np.asarray(sc, np.float32)[:, None]
        # + tiny absolute slack for ranges whose scale underflows f16 to 0
        bound = (0.5 if wire == "int8" else 1.0) * step + 1e-4
        err = np.abs(np.asarray(back) - np.asarray(w))
        assert np.all(err <= bound), (trial, err.max(), step.max())


def test_quantizer_wire_representation():
    """What rides the wire: int8 codes within ±127 and an f16 scale/zp pair
    (the f16-rounded values are the ones the quantizer itself used)."""
    w = _random_messages(np.random.default_rng(1), 32, 16)
    q, sc, zp = quantize_wire(w, "int8")
    assert q.dtype == jnp.int8 and sc.dtype == jnp.float16
    assert zp.dtype == jnp.float16 and sc.shape == zp.shape == w.shape[:-1]
    qn = np.asarray(q, np.int32)
    assert qn.min() >= -127 and qn.max() <= 127
    # headroom contract: f16 rounding of the scale never pushes a code
    # past INT8_QMAX + 1
    assert np.all(np.abs(qn) <= INT8_QMAX + 1)


def test_stochastic_rounding_is_unbiased():
    """E[dequant] = w: averaging many independent SR draws converges to the
    unquantized value well below one step (round-to-nearest cannot do this
    for values between codes)."""
    w = jnp.asarray(np.random.default_rng(2).normal(size=(4, 16)), jnp.float32)
    draws = []
    for i in range(512):
        q, sc, zp = quantize_wire(w, "int8_sr", key=jax.random.key(i))
        draws.append(np.asarray(dequantize_wire(q, sc, zp)))
    step = np.asarray(quantize_wire(w, "int8")[1], np.float32)[:, None]
    bias = np.abs(np.mean(draws, axis=0) - np.asarray(w))
    assert np.all(bias <= 0.15 * step), bias.max() / step.max()


@pytest.mark.parametrize("wire", ["int8", "int8_sr"])
def test_quantizer_saturates_beyond_f16_range(wire):
    """Regression: coefficients past the f16 range (a divergent learner)
    must saturate the f16 scale/zero-point, never overflow to inf — inf/NaN
    payloads would poison every downstream merge."""
    w = jnp.asarray([[1e5, -2e5, 3e7, 0.5],
                     [7e4, 7e4, 7e4, 7e4],
                     [1.0, -1.0, 0.25, 0.0]], jnp.float32)
    q, sc, zp = quantize_wire(w, wire, key=jax.random.key(0))
    back = dequantize_wire(q, sc, zp)
    assert np.all(np.isfinite(np.asarray(sc, np.float32)))
    assert np.all(np.isfinite(np.asarray(zp, np.float32)))
    assert np.all(np.isfinite(np.asarray(back)))
    # in-range messages are untouched by the guard
    step = float(np.asarray(sc, np.float32)[2])
    assert np.all(np.abs(np.asarray(back[2]) - np.asarray(w[2])) <= step + 1e-4)


def test_int8_sr_bitwise_reproducible_for_fixed_key():
    w = _random_messages(np.random.default_rng(3), 16, 8)
    a = quantize_wire(w, "int8_sr", key=jax.random.key(9))
    b = quantize_wire(w, "int8_sr", key=jax.random.key(9))
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    c = quantize_wire(w, "int8_sr", key=jax.random.key(10))
    assert not np.array_equal(np.asarray(a[0]), np.asarray(c[0]))


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def test_wire_dtype_registry():
    assert resolve_wire_dtype("int8") == jnp.int8
    assert resolve_wire_dtype("int8_sr") == jnp.int8
    assert wire_itemsize("int8") == wire_itemsize("int8_sr") == 1
    assert wire_overhead_bytes("int8") == wire_overhead_bytes("int8_sr") == 4
    assert wire_overhead_bytes("bf16") == wire_overhead_bytes(None) == 0
    assert is_quantized_wire("int8") and is_quantized_wire("int8_sr")
    assert not is_quantized_wire("bf16") and not is_quantized_wire(None)


@pytest.mark.parametrize("engine", ["reference", "sharded"])
def test_wire_bytes_account_for_scale_and_zero_point(engine):
    """Regression: int8 messages cost d + 4 (counter) + 4 (f16 scale+zp)
    bytes and the payload buffer carries the (D, N) metadata lanes — both
    totals must reflect the overhead, and routing stays payload-blind."""
    X, y, Xt, yt = toy(n=32)
    d, D, n = 16, 4, 32
    kw = dict(cycles=10, eval_every=10, seed=0, engine=engine)
    f32 = run_simulation(small_cfg(n_nodes=n, delay_max_cycles=D),
                         X, y, Xt, yt, **kw)
    i8 = run_simulation(small_cfg(n_nodes=n, delay_max_cycles=D,
                                  wire_dtype="int8"), X, y, Xt, yt, **kw)
    assert message_wire_bytes(d, "int8") == d + 4 + 4
    assert message_wire_bytes(d, "int8_sr") == d + 4 + 4
    assert i8.wire_bytes_total == i8.sent_total * (d + 8)
    assert i8.buf_payload_bytes == payload_buffer_bytes(D, n, d, "int8") \
        == D * n * (d + 4)
    assert i8.sent_total == f32.sent_total
    # ≥ 3x wire saving already at d=16; the asymptotic payload ratio is 4x
    assert f32.wire_bytes_total / i8.wire_bytes_total > 2.8


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["int8", "int8_sr"])
def test_sharded_matches_reference_bitwise(wire):
    """Acceptance bar: reference/sharded error-curve parity holds *bitwise*
    for each new wire dtype at matched seeds — the engines share the churn
    trace, the per-cycle threefry draws, AND the per-cycle k_recv
    stochastic-rounding key, so quantization is identical at send time."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9,
                    wire_dtype=wire)
    kw = dict(cycles=40, eval_every=20, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    dense = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                           compact_rounds=False, **kw)
    assert ref.err_fresh == sh.err_fresh == dense.err_fresh
    assert ref.err_voted == sh.err_voted == dense.err_voted
    assert (ref.sent_total, ref.delivered_total, ref.lost_total,
            ref.overflow_total) == (sh.sent_total, sh.delivered_total,
                                    sh.lost_total, sh.overflow_total)


@pytest.mark.parametrize("wire", ["int8", "int8_sr"])
def test_int8_run_is_reproducible(wire):
    X, y, Xt, yt = toy(n=64)
    cfg = small_cfg(n_nodes=64, drop_prob=0.3, delay_max_cycles=4,
                    wire_dtype=wire)
    kw = dict(cycles=20, eval_every=10, seed=7, engine="sharded")
    a = run_simulation(cfg, X, y, Xt, yt, **kw)
    b = run_simulation(cfg, X, y, Xt, yt, **kw)
    assert a.err_fresh == b.err_fresh and a.err_voted == b.err_voted


@pytest.mark.parametrize("wire", ["int8", "int8_sr"])
def test_wire_int8_curves_close_to_f32(wire):
    """Documented tolerance: 4x-compressed wire payloads move the error
    curves by at most 0.05 at any eval point on the toy problem."""
    X, y, Xt, yt = toy()
    kw = dict(cycles=30, eval_every=10, seed=1, engine="sharded")
    f32 = run_simulation(small_cfg(), X, y, Xt, yt, **kw)
    i8 = run_simulation(small_cfg(wire_dtype=wire), X, y, Xt, yt, **kw)
    assert f32.cycles == i8.cycles
    for a, b in zip(f32.err_fresh + f32.err_voted,
                    i8.err_fresh + i8.err_voted):
        assert abs(a - b) <= 0.05


# ---------------------------------------------------------------------------
# optimizer parity (the gossip_merge exchange_dtype contract)
# ---------------------------------------------------------------------------


def test_gossip_merge_int8_exchange_matches_simulator_semantics():
    """gossip_merge(exchange_dtype=int8) must equal the simulator's wire
    path: quantize the transmitted model per-row, dequantize, merge in f32
    with the receiver's full-precision model."""
    from repro.core.learners import LinearModel
    from repro.core.merge import merge

    w = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)),
                    jnp.float32)
    out = gossip_merge({"w": w}, np.array([1, 0]),
                       exchange_dtype=resolve_wire_dtype("int8"))["w"]
    msg = dequantize_wire(*quantize_wire(w[1], "int8"))
    t = jnp.zeros((), jnp.int32)
    mine = merge(LinearModel(msg, t), LinearModel(w[0], t)).w
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(mine))


def test_gossip_merge_int8_quantizes_scalar_leaves_per_peer():
    """Regression: a rank-1 leaf holds one scalar *per peer* — each peer's
    message must get its own scale/zero-point (grouping across the peer
    axis once leaked one shared scale, flattening a [0.001, 100.0] pair)."""
    s = jnp.asarray([0.001, 100.0], jnp.float32)
    out = gossip_merge({"s": s}, np.array([1, 0]),
                       exchange_dtype=resolve_wire_dtype("int8"))["s"]
    # a single-coordinate message round-trips to its f16-rounded value,
    # so the merge is exact to f16 precision per peer
    expect = (np.float32(s) + np.float16(s)[::-1].astype(np.float32)) / 2
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-3)
