"""Per-kernel allclose tests vs the pure-jnp oracles (shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import gossip_cycle as gc
from repro.kernels import gossip_merge as gm
from repro.kernels import pegasos_update as pu
from repro.kernels import ref


@pytest.mark.parametrize("n,d", [(1, 8), (7, 57), (32, 128), (33, 300),
                                 (5, 1000)])
@pytest.mark.parametrize("lam", [0.1, 1e-3])
def test_pegasos_kernel_sweep(n, d, lam):
    key = jax.random.key(n * d)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (n, d), jnp.float32)
    x = jax.random.normal(ks[1], (n, d), jnp.float32)
    t = jax.random.randint(ks[2], (n,), 0, 100)
    y = jnp.sign(jax.random.normal(ks[3], (n,)))
    got_w, got_t = pu.pegasos_update(w, t, x, y, lam=lam, interpret=True)
    exp_w, exp_t = ref.pegasos_update_ref(w, t, x, y, lam)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(exp_w),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(exp_t))


@pytest.mark.parametrize("n,d", [(4, 16), (19, 257), (8, 512)])
def test_merge_update_kernel_sweep(n, d):
    key = jax.random.key(n + d)
    ks = jax.random.split(key, 6)
    w1 = jax.random.normal(ks[0], (n, d), jnp.float32)
    w2 = jax.random.normal(ks[1], (n, d), jnp.float32)
    x = jax.random.normal(ks[2], (n, d), jnp.float32)
    t1 = jax.random.randint(ks[3], (n,), 0, 40)
    t2 = jax.random.randint(ks[4], (n,), 0, 40)
    y = jnp.sign(jax.random.normal(ks[5], (n,)))
    got_w, got_t = gm.merge_update(w1, t1, w2, t2, x, y, lam=0.01,
                                   interpret=True)
    exp_w, exp_t = ref.merge_update_ref(w1, t1, w2, t2, x, y, 0.01)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(exp_w),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(exp_t))


@pytest.mark.parametrize("variant", ["mu", "um", "rw"])
@pytest.mark.parametrize("n,c,d,k", [(6, 3, 10, 2), (33, 10, 57, 4),
                                     (8, 5, 128, 1)])
def test_gossip_cycle_kernel_sweep(variant, n, c, d, k):
    """Fused deliver→merge→update→cache-write vs the apply_receives oracle."""
    from repro.core.cache import ModelCache
    from repro.core.learners import make_update
    from repro.core.simulation import apply_receives

    lam = 0.01
    key = jax.random.key(n * d + c)
    ks = jax.random.split(key, 8)
    last_w = jax.random.normal(ks[0], (n, d), jnp.float32)
    last_t = jax.random.randint(ks[1], (n,), 0, 30)
    cache = ModelCache(jax.random.normal(ks[2], (n, c, d), jnp.float32),
                       jax.random.randint(ks[3], (n, c), 0, 30),
                       jax.random.randint(ks[4], (n,), 1, 3 * c),
                       jnp.minimum(jax.random.randint(ks[4], (n,), 1, 3 * c), c))
    msg_w = jax.random.normal(ks[5], (k, n, d), jnp.float32)
    msg_t = jax.random.randint(ks[6], (k, n), 0, 30)
    valid = jax.random.bernoulli(ks[7], 0.7, (k, n))
    x = jax.random.normal(ks[0], (n, d), jnp.float32)
    y = jnp.sign(jax.random.normal(ks[1], (n,)) + 0.1)

    upd = make_update("pegasos", lam=lam)
    exp_lw, exp_lt, exp_cache, _, _ = apply_receives(
        last_w, last_t, cache, msg_w, msg_t, valid, x, y,
        variant=variant, update=upd)
    got = gc.fused_receive_apply(
        last_w, last_t, cache.w, cache.t, cache.ptr, cache.count,
        msg_w, msg_t, valid.astype(jnp.int32), x, y,
        variant=variant, lam=lam, interpret=True)
    got_lw, got_lt, got_cw, got_ct, got_ptr, got_cnt, _, _ = got
    np.testing.assert_allclose(np.asarray(got_lw), np.asarray(exp_lw),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_lt), np.asarray(exp_lt))
    np.testing.assert_allclose(np.asarray(got_cw), np.asarray(exp_cache.w),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_ct), np.asarray(exp_cache.t))
    np.testing.assert_array_equal(np.asarray(got_ptr), np.asarray(exp_cache.ptr))
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(exp_cache.count))


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 2, 2, 32),      # MHA
    (2, 128, 4, 2, 64),      # GQA 2:1
    (1, 256, 8, 1, 64),      # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window):
    key = jax.random.key(B * S + H)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    got = fa.flash_attention(q, k, v, causal=causal, window=window,
                             blk_q=64, blk_k=64, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    key = jax.random.key(0)
    ks = jax.random.split(key, 3)
    B, S, H, KV, hd = 1, 128, 2, 1, 64
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(jnp.bfloat16)
    got = fa.flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64,
                             interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


def test_flash_attention_odd_head_dim_padding():
    key = jax.random.key(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 48), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 48), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 48), jnp.float32)
    got = fa.flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32,
                             interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-4)
