"""Fused send-side quantization kernel: bitwise parity with the jnp path.

``quantize_send`` must reproduce ``quantize_wire`` bit for bit — codes,
f16 scale and zero-point — including the "int8_sr" stochastic-rounding
uniform, which the kernel regenerates *in kernel* with an op-exact
threefry-2x32 (the engines' parity contract rules out the TPU-native PRNG,
whose stream differs from ``jax.random.uniform``'s)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gossip_optimizer import quantize_wire
from repro.kernels.gossip_cycle import _uniform_at, quantize_send


def rand_w(n, d, seed=0, spread=True):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n, d))
    if spread:                      # heterogeneous per-message ranges
        w *= rng.uniform(1e-3, 30.0, size=(n, 1))
    return jnp.asarray(w, jnp.float32)


@pytest.mark.parametrize("n,d", [(64, 10), (33, 7), (1, 1), (128, 57),
                                 (40, 128), (7, 130)])
def test_quantize_send_matches_quantize_wire_int8(n, d):
    w = rand_w(n, d, seed=n)
    q0, s0, z0 = quantize_wire(w, "int8")
    q1, s1, z1 = quantize_send(w, "int8", interpret=True)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))


@pytest.mark.parametrize("n,d", [(64, 10), (33, 7), (5, 3), (96, 57)])
def test_quantize_send_matches_quantize_wire_int8_sr(n, d):
    """Stochastic rounding: the in-kernel threefry draw must equal the
    ``jax.random.uniform(k_recv, (n, d))`` draw of the jnp path — both even
    and odd counter sizes (the odd case exercises the zero pad)."""
    w = rand_w(n, d, seed=n + 1)
    key = jax.random.split(jax.random.key(42), 4)[0]     # a k_recv slot
    q0, s0, z0 = quantize_wire(w, "int8_sr", key=key)
    q1, s1, z1 = quantize_send(w, "int8_sr",
                               key_data=jax.random.key_data(key),
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))


@pytest.mark.parametrize("size", [8, 9, 2048, 2049])
def test_uniform_at_matches_jax_random_uniform(size):
    """The kernel's threefry helper IS jax.random.uniform, elementwise."""
    key = jax.random.key(123)
    ref = jax.random.uniform(key, (size,))
    k0, k1 = (jnp.uint32(x) for x in np.asarray(jax.random.key_data(key)))
    got = _uniform_at(k0, k1, jnp.arange(size, dtype=jnp.int32), size)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_constant_and_degenerate_rows():
    """Constant rows (scale 0) and huge-range rows (f16 saturation) take
    the same guarded paths as quantize_wire."""
    w = jnp.stack([jnp.full((16,), 3.25), jnp.zeros((16,)),
                   jnp.linspace(-7e4, 7e4, 16)]).astype(jnp.float32)
    q0, s0, z0 = quantize_wire(w, "int8")
    q1, s1, z1 = quantize_send(w, "int8", interpret=True)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))


def test_sharded_engine_send_kernel_bitwise():
    """Engine-level: use_send_kernel routes every send through the kernel
    and the run still reproduces the reference engine bitwise."""
    from repro.configs.gossip_linear import GossipLinearConfig
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset

    rng = np.random.default_rng(0)
    n, d = 64, 16
    X, y = make_linear_dataset(rng, n + 48, d, noise=0.05, separation=3.0)
    cfg = GossipLinearConfig(name="sendk", dim=d, n_nodes=n, n_test=48,
                             class_ratio=(1, 1), lam=1e-3, variant="mu",
                             drop_prob=0.5, delay_max_cycles=6,
                             online_fraction=0.8, wire_dtype="int8_sr")
    kw = dict(cycles=18, eval_every=6, seed=9)
    ref = run_simulation(cfg, X[:n], y[:n], X[n:], y[n:], **kw)
    sh = run_simulation(cfg, X[:n], y[:n], X[n:], y[n:], engine="sharded",
                        use_send_kernel=True, interpret=True, **kw)
    assert ref.cycles == sh.cycles
    assert ref.err_fresh == sh.err_fresh
    assert ref.err_voted == sh.err_voted
    assert ref.sent_total == sh.sent_total


def test_send_kernel_argument_validation():
    from repro.configs.gossip_linear import GossipLinearConfig
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset

    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 32 + 16, 8, noise=0.05)
    cfg = GossipLinearConfig(name="v", dim=8, n_nodes=32, n_test=16,
                             class_ratio=(1, 1))
    with pytest.raises(ValueError, match="quantized"):
        run_simulation(cfg, X[:32], y[:32], X[32:], y[32:], cycles=2,
                       engine="sharded", use_send_kernel=True)
    with pytest.raises(ValueError, match="needs key_data"):
        quantize_send(jnp.zeros((4, 4)), "int8_sr", interpret=True)
    with pytest.raises(ValueError, match="quantized wire codec"):
        quantize_send(jnp.zeros((4, 4)), "bf16", interpret=True)


@pytest.mark.parametrize("wire", ["int4", "ternary"])
@pytest.mark.parametrize("n,d", [(64, 10), (33, 7), (1, 1), (96, 57),
                                 (40, 128), (7, 130)])
def test_quantize_send_matches_codec_encode_packed(wire, n, d):
    """The packed sub-4-bit codecs: in-kernel symmetric scale, code pack
    and (with ``ef``) the fused EF residual must all equal the jnp codec
    chain bit for bit — including odd d (half-filled final byte) and the
    d > 128 multi-lane-tile case."""
    from repro.core.wire_codec import get_codec

    codec = get_codec(wire)
    w = rand_w(n, d, seed=n + d)
    p0, s0, _ = codec.encode(w)
    p1, s1 = quantize_send(w, wire, interpret=True)
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))

    # fused EF pass: encode(w + ef) + residual, vs the jnp chain
    ef = rand_w(n, d, seed=n + d + 1) * 0.1
    x = w + ef
    p2, s2, _ = codec.encode(x)
    resid = x - codec.decode(p2, s2, None, d)
    p3, s3, r3 = quantize_send(w, wire, ef=ef, interpret=True)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p3))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s3))
    np.testing.assert_array_equal(np.asarray(resid), np.asarray(r3))


def test_quantize_send_packed_degenerate_rows():
    """Constant, zero and f16-saturating rows take the same guarded paths
    as the jnp codec."""
    from repro.core.wire_codec import get_codec

    w = jnp.stack([jnp.full((16,), 3.25), jnp.zeros((16,)),
                   jnp.linspace(-7e4, 7e4, 16)]).astype(jnp.float32)
    for wire in ("int4", "ternary"):
        codec = get_codec(wire)
        p0, s0, _ = codec.encode(w)
        p1, s1 = quantize_send(w, wire, interpret=True)
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
