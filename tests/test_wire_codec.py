"""Wire-codec subsystem: registry properties, sub-4-bit pack/unpack
bit-exactness, error-feedback accumulator boundedness, and the bitwise
cross-engine/kernel parity contract for every registered codec.

The codec contract (``repro.core.wire_codec``): the *transmitted* model is
encoded — per message, with f16 scale metadata riding along — and every
merge runs in f32 on the decoded values. The ``_ef`` codecs add sender-side
error-feedback residual state that the engines thread as protocol state;
these tests pin its boundedness and its bitwise agreement across the
reference engine, both sharded packings and the Pallas interpret paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core.gossip_optimizer import gossip_merge
from repro.core.simulation import (ef_residual_norm, message_wire_bytes,
                                   payload_buffer_bytes, run_simulation)
from repro.core.wire_codec import (INT4_QMAX, WIRE_CODECS, deterministic_codec,
                                   get_codec, pack_int4, pack_ternary,
                                   unpack_int4, unpack_ternary)
from repro.data.synthetic import make_linear_dataset

QUANTIZED = [n for n, c in WIRE_CODECS.items() if c.quantized]
PACKED = ["int4", "int4_ef", "ternary", "ternary_ef"]
EF = [n for n, c in WIRE_CODECS.items() if c.ef]


def small_cfg(n_nodes=128, **kw):
    base = dict(name="toy", dim=16, n_nodes=n_nodes, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def toy(n=128, d=16, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 64, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


def _degenerate_messages(rng, n, d):
    """Messages spanning the regimes every codec must survive: mixed
    magnitudes, large offsets with tiny ranges, all-equal rows, zeros."""
    w = rng.normal(size=(n, d)) * np.exp(rng.uniform(-6, 6, size=(n, 1)))
    w += rng.normal(size=(n, 1)) * np.exp(rng.uniform(-2, 8, size=(n, 1)))
    w[0] = 0.0                      # the all-zero init model
    w[1] = w[1, 0]                  # constant row: range collapses
    w[2, :] = 1000.0
    w[2, 0] = 1000.001              # huge offset, tiny range
    w[3] = np.linspace(-6e4, 6e4, d)  # f16-range extremes, inf-free
    return jnp.asarray(w, jnp.float32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_names_and_aliases():
    assert set(WIRE_CODECS) == {"f32", "bf16", "f16", "int8", "int8_sr",
                                "int4", "int4_ef", "ternary", "ternary_ef"}
    assert get_codec(None) is WIRE_CODECS["f32"]
    assert get_codec("") is WIRE_CODECS["f32"]
    with pytest.raises(ValueError, match="unknown wire dtype"):
        get_codec("int2")
    assert deterministic_codec(get_codec("int8_sr")) is get_codec("int8")
    assert deterministic_codec(get_codec("int4_ef")) is get_codec("int4_ef")


def test_codec_lane_declarations():
    """Each codec's declared lanes drive SimState/carry allocation — pin
    the flag matrix so a registry edit cannot silently change state."""
    flags = {n: (c.quantized, c.has_zp, c.ef, c.stochastic)
             for n, c in WIRE_CODECS.items()}
    assert flags == {
        "f32": (False, False, False, False),
        "bf16": (False, False, False, False),
        "f16": (False, False, False, False),
        "int8": (True, True, False, False),
        "int8_sr": (True, True, False, True),
        "int4": (True, False, False, False),
        "int4_ef": (True, False, True, False),
        "ternary": (True, False, False, False),
        "ternary_ef": (True, False, True, False),
    }


def test_wire_byte_accounting_per_codec():
    """The acceptance numbers at d=57 (spambase-sized): packed int4 rides
    at ≤ 0.55× the int8 wire bytes, ternary at ≈ 0.28×."""
    d = 57
    assert message_wire_bytes(d, None) == 4 * d + 4
    assert message_wire_bytes(d, "bf16") == 2 * d + 4
    assert message_wire_bytes(d, "int8") == d + 4 + 4 == 65
    assert message_wire_bytes(d, "int4_ef") == 29 + 4 + 2 == 35
    assert message_wire_bytes(d, "ternary_ef") == 12 + 4 + 2 == 18
    assert message_wire_bytes(d, "int4_ef") <= 0.55 * message_wire_bytes(
        d, "int8")
    # buffer accounting: packed payload cols × 1 B + scale overhead
    assert payload_buffer_bytes(10, 100, d, "int4") == 10 * 100 * (29 + 2)
    assert payload_buffer_bytes(10, 100, d, "ternary") == 10 * 100 * (12 + 2)


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", QUANTIZED)
def test_roundtrip_error_bounded_by_one_step(wire):
    """Property: per coordinate, |w - decode(encode(w))| <= one step of the
    *transmitted* f16 scale, across degenerate ranges — half a step for the
    round-to-nearest codecs, a full step for stochastic rounding."""
    codec = get_codec(wire)
    rng = np.random.default_rng(0)
    for trial in range(20):
        w = _degenerate_messages(rng, 64, 24)
        payload, sc, zp = codec.encode(w, key=jax.random.key(trial))
        back = codec.decode(payload, sc, zp, 24)
        step = np.asarray(sc, np.float32)[:, None]
        # + tiny absolute slack for ranges whose scale underflows f16 to 0
        frac = 1.0 if codec.stochastic else 0.5
        bound = frac * step + 1e-4
        err = np.abs(np.asarray(back) - np.asarray(w))
        assert np.all(err <= bound), (wire, trial, err.max(), step.max())


@pytest.mark.parametrize("wire", PACKED)
def test_packed_payload_representation(wire):
    codec = get_codec(wire)
    d = 57
    w = _degenerate_messages(np.random.default_rng(1), 16, d)
    payload, sc, zp = codec.encode(w)
    assert payload.dtype == jnp.uint8
    assert payload.shape == (16, codec.payload_cols(d))
    assert sc.dtype == jnp.float16 and sc.shape == (16,)
    assert zp is None
    if codec.group == 5:            # base-3 bytes stay within 3^5 - 1
        assert int(np.max(np.asarray(payload))) <= 242


@pytest.mark.parametrize("d", [1, 3, 7, 57, 128, 130])
def test_int4_pack_unpack_bit_exact(d):
    """Pack→unpack is the identity on int4 codes for every width — odd d
    exercises the half-filled final byte."""
    rng = np.random.default_rng(d)
    q = jnp.asarray(rng.integers(-8, 8, size=(9, d)), jnp.int32)
    b = pack_int4(q)
    assert b.shape == (9, -(-d // 2))
    np.testing.assert_array_equal(np.asarray(unpack_int4(b, d)),
                                  np.asarray(q))


@pytest.mark.parametrize("d", [1, 4, 5, 57, 128, 131])
def test_ternary_pack_unpack_bit_exact(d):
    rng = np.random.default_rng(d)
    q = jnp.asarray(rng.integers(-1, 2, size=(9, d)), jnp.int32)
    b = pack_ternary(q)
    assert b.shape == (9, -(-d // 5))
    np.testing.assert_array_equal(np.asarray(unpack_ternary(b, d)),
                                  np.asarray(q))


def test_int4_codes_stay_symmetric():
    """The -8 nibble is never produced by the quantizer (codes target
    ±INT4_QMAX), so the symmetric decode has no asymmetry artifact."""
    codec = get_codec("int4")
    w = _degenerate_messages(np.random.default_rng(2), 64, 31)
    q, _ = codec.quantize_codes(w)
    qn = np.asarray(q)
    assert qn.min() >= -INT4_QMAX and qn.max() <= INT4_QMAX


# ---------------------------------------------------------------------------
# error-feedback accumulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", EF)
def test_ef_accumulator_bounded(wire):
    """EF-SGD boundedness: iterating e' = (w + e) - decode(encode(w + e))
    over a drifting model keeps |e| within half a step of the largest
    transmitted scale seen — the residual never snowballs."""
    codec = get_codec(wire)
    rng = np.random.default_rng(0)
    d = 24
    ef = jnp.zeros((8, d), jnp.float32)
    max_step = np.zeros((8, 1), np.float32)
    for t in range(300):
        w = jnp.asarray(rng.normal(size=(8, d)) * (1 + 3 * np.sin(t / 20)),
                        jnp.float32)
        x = w + ef
        payload, sc, zp = codec.encode(x)
        ef = x - codec.decode(payload, sc, zp, d)
        max_step = np.maximum(max_step, np.asarray(sc, np.float32)[:, None])
        assert np.all(np.abs(np.asarray(ef)) <= 0.5 * max_step + 1e-4), t


@pytest.mark.parametrize("wire", EF)
def test_ef_recovers_constant_model(wire):
    """With a FIXED model the EF chain makes the time-averaged transmitted
    payload converge to the true model (the bias the plain codec keeps is
    recycled through the residual) — the EF-SGD telescoping-sum property."""
    codec = get_codec(wire)
    plain = get_codec(wire.replace("_ef", ""))
    w = jnp.asarray(np.random.default_rng(3).normal(size=(4, 16)),
                    jnp.float32)
    ef = jnp.zeros_like(w)
    acc = np.zeros(w.shape, np.float64)
    T = 400
    for _ in range(T):
        x = w + ef
        payload, sc, zp = codec.encode(x)
        dec = codec.decode(payload, sc, zp, 16)
        ef = x - dec
        acc += np.asarray(dec, np.float64)
    ef_bias = np.abs(acc / T - np.asarray(w)).max()
    plain_bias = np.abs(np.asarray(plain.roundtrip(w)) - np.asarray(w)).max()
    # time-averaging beats the one-shot code by a wide margin
    assert ef_bias < 0.2 * plain_bias, (ef_bias, plain_bias)


@pytest.mark.parametrize("wire", EF)
def test_ef_residual_updates_only_on_sends(wire):
    """Protocol state contract: a node that does not transmit this cycle
    keeps its residual — pinned end to end by running the reference engine
    under churn+drop (many non-senders per cycle) and checking the sharded
    compact_all packing (which refreshes ONLY the sender subset) lands on
    the bitwise-identical residual lane."""
    X, y, Xt, yt = toy(n=96)
    cfg = small_cfg(n_nodes=96, drop_prob=0.6, delay_max_cycles=5,
                    online_fraction=0.5, wire_dtype=wire)
    kw = dict(cycles=25, eval_every=25, seed=11)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    ca = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                        compact_mode="compact_all", **kw)
    assert ref.err_fresh == ca.err_fresh
    assert ref.ef_residual_norm == ca.ef_residual_norm
    assert ref.ef_residual_norm > 0.0


def test_ef_residual_norm_helper():
    assert ef_residual_norm(jnp.zeros((0, 0))) == 0.0
    ef = jnp.asarray([[3.0, 4.0], [0.0, 0.0]], jnp.float32)
    assert abs(ef_residual_norm(ef) - np.sqrt(25 / 2)) < 1e-6


# ---------------------------------------------------------------------------
# bitwise parity: reference == sharded (all packings) == Pallas-interpret
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", PACKED)
def test_fixed_key_bitwise_parity_all_paths(wire):
    """Acceptance bar for every new codec: for a fixed seed the error
    curves (and EF telemetry) agree bitwise across the reference engine,
    the sharded engine's dense and compact_all packings, the fused Pallas
    receive kernel and the fused send kernel (interpret mode)."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9,
                    wire_dtype=wire)
    kw = dict(cycles=30, eval_every=15, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    runs = dict(
        compact=run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw),
        dense=run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                             compact_rounds=False, **kw),
        compact_all=run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                                   compact_mode="compact_all", **kw),
        pallas=run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                              use_pallas=True, interpret=True, **kw),
    )
    for name, r in runs.items():
        assert ref.err_fresh == r.err_fresh, (wire, name)
        assert ref.err_voted == r.err_voted, (wire, name)
        assert ref.ef_residual_norm == r.ef_residual_norm, (wire, name)
        assert (ref.sent_total, ref.delivered_total, ref.lost_total,
                ref.overflow_total) == (r.sent_total, r.delivered_total,
                                        r.lost_total, r.overflow_total)


@pytest.mark.parametrize("wire", ["int4_ef", "ternary"])
def test_run_is_reproducible(wire):
    X, y, Xt, yt = toy(n=64)
    cfg = small_cfg(n_nodes=64, drop_prob=0.3, delay_max_cycles=4,
                    wire_dtype=wire)
    kw = dict(cycles=20, eval_every=10, seed=7, engine="sharded")
    a = run_simulation(cfg, X, y, Xt, yt, **kw)
    b = run_simulation(cfg, X, y, Xt, yt, **kw)
    assert a.err_fresh == b.err_fresh and a.err_voted == b.err_voted
    assert a.ef_residual_norm == b.ef_residual_norm


@pytest.mark.parametrize("wire", ["int4", "int4_ef"])
def test_wire_int4_curves_close_to_f32(wire):
    """Documented tolerance: 8x-compressed wire payloads move the error
    curves by at most 0.06 at any eval point on the toy problem."""
    X, y, Xt, yt = toy()
    kw = dict(cycles=30, eval_every=10, seed=1, engine="sharded")
    f32 = run_simulation(small_cfg(), X, y, Xt, yt, **kw)
    i4 = run_simulation(small_cfg(wire_dtype=wire), X, y, Xt, yt, **kw)
    assert f32.cycles == i4.cycles
    for a, b in zip(f32.err_fresh + f32.err_voted,
                    i4.err_fresh + i4.err_voted):
        assert abs(a - b) <= 0.06


def test_accounting_packed_end_to_end():
    """wire_bytes_total / buf_payload_bytes follow the codec exactly and
    routing stays payload-blind for the packed codecs."""
    X, y, Xt, yt = toy(n=32)
    d, D, n = 16, 4, 32
    kw = dict(cycles=10, eval_every=10, seed=0, engine="sharded")
    f32 = run_simulation(small_cfg(n_nodes=n, delay_max_cycles=D),
                         X, y, Xt, yt, **kw)
    i4 = run_simulation(small_cfg(n_nodes=n, delay_max_cycles=D,
                                  wire_dtype="int4_ef"), X, y, Xt, yt, **kw)
    t3 = run_simulation(small_cfg(n_nodes=n, delay_max_cycles=D,
                                  wire_dtype="ternary_ef"), X, y, Xt, yt,
                        **kw)
    assert i4.wire_bytes_total == i4.sent_total * (8 + 4 + 2)
    assert t3.wire_bytes_total == t3.sent_total * (4 + 4 + 2)
    assert i4.buf_payload_bytes == D * n * (8 + 2)
    assert t3.buf_payload_bytes == D * n * (4 + 2)
    assert i4.sent_total == t3.sent_total == f32.sent_total


# ---------------------------------------------------------------------------
# gossip_merge exchange path (the on-mesh optimizer contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["int4", "ternary"])
def test_gossip_merge_packed_exchange_matches_codec_roundtrip(wire):
    """gossip_merge(exchange_dtype=<codec name>) must equal the simulator's
    wire path: encode the transmitted model per-row, decode, merge in f32
    with the receiver's full-precision model."""
    codec = get_codec(wire)
    w = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)),
                    jnp.float32)
    out = gossip_merge({"w": w}, np.array([1, 0]), exchange_dtype=wire)["w"]
    msg = codec.roundtrip(w[1])
    expect = (w[0] + msg) / 2.0
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(expect))


def test_gossip_merge_accepts_names_and_dtypes():
    """Back-compat: the legacy dtype spellings keep their exact behavior
    (bf16 cast; jnp.int8 = the "int8" codec) and names alias them."""
    w = jnp.asarray(np.random.default_rng(4).normal(size=(4, 32)),
                    jnp.float32)
    perm = np.array([1, 0, 3, 2])
    by_dtype = gossip_merge({"w": w}, perm, exchange_dtype=jnp.bfloat16)["w"]
    by_name = gossip_merge({"w": w}, perm, exchange_dtype="bf16")["w"]
    np.testing.assert_array_equal(np.asarray(by_dtype), np.asarray(by_name))
    i8_dtype = gossip_merge({"w": w}, perm, exchange_dtype=jnp.int8)["w"]
    i8_name = gossip_merge({"w": w}, perm, exchange_dtype="int8")["w"]
    sr_name = gossip_merge({"w": w}, perm, exchange_dtype="int8_sr")["w"]
    np.testing.assert_array_equal(np.asarray(i8_dtype), np.asarray(i8_name))
    # the optimizer path has no per-step key: int8_sr falls back to
    # deterministic rounding
    np.testing.assert_array_equal(np.asarray(i8_name), np.asarray(sr_name))


def test_int4_ef_terminal_error_near_f32():
    """The ROADMAP question, miniature edition: the merge-DAG averaging
    absorbs the int4 feedback bias — terminal error with the 8x-compressed
    int4_ef wire stays within a few error points of f32. (Ternary is a
    different story: its max-scale codes are coarse enough that the EF
    residual legitimately carries O(|w|) state and re-injects it, measured
    as a *worse* terminal delta in BENCH_wire_quantization.json — the
    benchmark records that answer rather than asserting it away.)"""
    X, y, Xt, yt = toy(n=256, d=24, seed=5)
    kw = dict(cycles=60, eval_every=60, seed=2, engine="sharded")
    f32 = run_simulation(small_cfg(n_nodes=256, dim=24), X, y, Xt, yt, **kw)
    i4ef = run_simulation(small_cfg(n_nodes=256, dim=24,
                                    wire_dtype="int4_ef"), X, y, Xt, yt,
                          **kw)
    assert abs(i4ef.err_fresh[-1] - f32.err_fresh[-1]) <= 0.03, (
        f32.err_fresh[-1], i4ef.err_fresh[-1])
