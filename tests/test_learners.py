"""Unit tests for the online learners (Algorithm 3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.learners import (LinearModel, adaline_update, init_model,
                                 logistic_update, pegasos_update)


def test_init_model_zero():
    m = init_model(5)
    assert m.w.shape == (5,) and float(jnp.abs(m.w).sum()) == 0.0
    assert int(m.t) == 0


def test_pegasos_update_margin_violation():
    m = LinearModel(jnp.zeros(3), jnp.int32(0))
    x = jnp.array([1.0, 0.0, 0.0])
    new = pegasos_update(m, x, 1.0, lam=0.1)
    # t=1, eta=1/(0.1*1)=10; margin 0 < 1 -> w = 0*(1-1) + 10*1*x = 10 x...
    # decay = 1 - eta*lam = 0 -> w = eta*y*x = 10*x
    np.testing.assert_allclose(np.asarray(new.w), [10.0, 0.0, 0.0], atol=1e-6)
    assert int(new.t) == 1


def test_pegasos_update_no_violation_only_decays():
    w0 = jnp.array([5.0, 0.0])
    m = LinearModel(w0, jnp.int32(9))
    x = jnp.array([1.0, 0.0])
    new = pegasos_update(m, x, 1.0, lam=0.1)   # margin = 5 >= 1
    eta = 1.0 / (0.1 * 10)
    np.testing.assert_allclose(np.asarray(new.w), np.asarray((1 - eta * 0.1) * w0),
                               rtol=1e-6)


def test_pegasos_population_matches_loop():
    rng = np.random.default_rng(1)
    N, d = 17, 9
    W = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    t = jnp.asarray(rng.integers(0, 30, N), jnp.int32)
    X = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    y = jnp.sign(jnp.asarray(rng.normal(size=N), jnp.float32))
    pop = pegasos_update(LinearModel(W, t), X, y, lam=0.01)
    for i in range(N):
        one = pegasos_update(LinearModel(W[i], t[i]), X[i], y[i], lam=0.01)
        np.testing.assert_allclose(np.asarray(pop.w[i]), np.asarray(one.w),
                                   rtol=2e-5, atol=1e-6)
        assert int(pop.t[i]) == int(one.t)


def test_adaline_converges_to_regression_target():
    rng = np.random.default_rng(2)
    d = 6
    w_true = rng.normal(size=d)
    m = init_model(d)
    for i in range(3000):
        x = jnp.asarray(rng.normal(size=d), jnp.float32)
        y = float(np.dot(np.asarray(x), w_true))
        m = adaline_update(m, x, y, eta=0.05)
    np.testing.assert_allclose(np.asarray(m.w), w_true, atol=0.15)


def test_logistic_update_direction():
    m = LinearModel(jnp.zeros(2), jnp.int32(0))
    x = jnp.array([1.0, 2.0])
    new = logistic_update(m, x, 1.0, eta=0.1)
    assert float(new.w @ x) > 0  # moved toward classifying +1 correctly
