"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED variant (2-ish
layers, d_model<=512, <=4 experts — same family/pattern/GQA ratio), run one
forward pass and one train step on CPU, assert output shapes and no NaNs.
Decode shapes run one serve_step. The FULL configs are exercised only via
the dry-run (ShapeDtypeStructs, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced_config
from repro.configs import ARCH_IDS
from repro.models import transformer as T
from repro.models import vision as V
from repro.optim import make_optimizer, constant

BATCH, SEQ = 2, 32


def _encoder_input(cfg, batch):
    if cfg.family == "vlm":
        return V.dummy_patch_embeddings(jax.random.key(1), cfg, batch)
    if cfg.family == "audio":
        return V.dummy_frame_embeddings(jax.random.key(1), cfg, batch)
    return None


@pytest.fixture(scope="module")
def smoke(request):
    return {}


def _setup(arch):
    cfg = reduced_config(get_config(arch))
    params = T.init_params(jax.random.key(0), cfg)
    return cfg, params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (BATCH, SEQ)), jnp.int32)
    logits, aux = T.forward(params, cfg, toks,
                            encoder_out=_encoder_input(cfg, BATCH))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_reduces_loss_direction(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)), jnp.int32)
    enc = _encoder_input(cfg, BATCH)

    def loss_fn(p):
        return T.lm_loss(p, cfg, toks, labels, encoder_out=enc)[0]

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: zero/NaN grads"
    opt = make_optimizer("sgd", constant(1e-2), grad_clip=1.0)
    new_params, _ = opt.update(grads, opt.init(params), params, jnp.int32(0))
    loss1 = float(loss_fn(new_params))
    assert np.isfinite(loss1)
    assert loss1 <= float(loss0) + 0.2, f"{arch}: loss exploded {loss0}->{loss1}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step(arch):
    cfg, params = _setup(arch)
    cache = T.init_cache(cfg, BATCH, SEQ)
    if cfg.family in ("vlm", "audio"):
        # cross-KV slots filled with zeros is fine for a smoke step
        pass
    tok = jnp.zeros((BATCH,), jnp.int32)
    logits, cache2 = T.decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache must change somewhere (state was written)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed, f"{arch}: decode step did not write its cache"


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-780m",
                                  "recurrentgemma-9b", "mixtral-8x22b"])
def test_windowed_cache_long_context(arch):
    """long_500k semantics at smoke scale: cache window < sequence."""
    cfg, params = _setup(arch)
    window = 16
    cache = T.init_cache(cfg, BATCH, 64, window=window)
    tok = jnp.zeros((BATCH,), jnp.int32)
    for i in range(window + 4):   # roll past the ring boundary
        logits, cache = T.decode_step(params, cfg, tok, cache, jnp.int32(i))
    assert bool(jnp.isfinite(logits).all())
