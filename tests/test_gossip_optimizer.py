"""Layer-B gossip optimizer: semantics + convergence + mesh runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GossipConfig
from repro.core.gossip_optimizer import (GossipState, gossip_merge,
                                         linear_gossip_mesh_step,
                                         make_gossip_train_step,
                                         peer_disagreement, perms_for_step,
                                         stack_for_peers, unstack_mean)
from repro.optim import constant, make_optimizer


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    l = jnp.mean((pred - batch["y"]) ** 2)
    return l, {}


def _run(merge, steps=60, n_peers=8, schedule="hypercube", lr=0.1, seed=0):
    key = jax.random.key(seed)
    w_true = jax.random.normal(key, (12,))
    params = {"w": jnp.zeros((12,)), "b": jnp.zeros(())}
    sp = stack_for_peers(params, n_peers)
    opt = make_optimizer("sgd", constant(lr), grad_clip=0)
    gc = GossipConfig(schedule=schedule, merge=merge)
    # perm is a static (compile-time) partner schedule — see gossip_merge
    fn = jax.jit(make_gossip_train_step(quad_loss, opt, n_peers, gc),
                 static_argnums=(2, 3))
    state = GossipState(sp, opt.init(sp), jnp.zeros((), jnp.int32))
    loss = None
    for s in range(steps):
        k = jax.random.fold_in(key, s)
        x = jax.random.normal(k, (n_peers, 16, 12))
        batch = {"x": x, "y": x @ w_true}
        perm, _ = perms_for_step(gc, s, n_peers)
        state, loss, _ = fn(state, batch, tuple(int(x) for x in perm))
    return state, float(loss), w_true


@pytest.mark.parametrize("merge", ["mu", "um"])
def test_gossip_converges_with_low_disagreement(merge):
    state, loss, w_true = _run(merge)
    assert loss < 1e-3
    assert float(peer_disagreement(state.params)) < 1e-2
    err = float(jnp.linalg.norm(unstack_mean(state.params)["w"] - w_true))
    assert err < 0.05


def test_rw_diverges_across_peers_more_than_mu():
    """No merging (RW) leaves peers on independent SGD paths — disagreement
    must exceed the gossiped run's (the paper's merging argument)."""
    st_mu, _, _ = _run("mu", steps=30)
    st_rw, _, _ = _run("rw", steps=30)
    assert float(peer_disagreement(st_rw.params)) > \
        float(peer_disagreement(st_mu.params))


def test_gossip_merge_is_pairwise_average():
    params = {"w": jnp.arange(8.0)[:, None] * jnp.ones((8, 3))}
    perm = (1, 0, 3, 2, 5, 4, 7, 6)
    merged = gossip_merge(params, perm)
    expect0 = (0.0 + 1.0) / 2
    np.testing.assert_allclose(np.asarray(merged["w"][0]), expect0, rtol=1e-6)
    # total mass conserved (pairwise averaging is doubly stochastic)
    np.testing.assert_allclose(float(merged["w"].sum()),
                               float(params["w"].sum()), rtol=1e-6)


def test_perms_for_step_pod_schedule():
    gc = GossipConfig(pod_every=2)
    perm, pod = perms_for_step(gc, 0, 8, n_pods=2)
    assert pod is None                        # step 0: (0+1) % 2 != 0
    perm, pod = perms_for_step(gc, 1, 8, n_pods=2)
    assert pod is not None
    pod = np.asarray(pod)
    assert np.all(pod[pod] == np.arange(8))   # cross-pod pairing is involutive
    assert np.all((pod >= 4) == (np.arange(8) < 4))  # pairs across pods


def test_linear_gossip_mesh_step_shard_map():
    """The paper's protocol with peers = mesh devices via shard_map."""
    from jax.sharding import Mesh, PartitionSpec as PS
    from jax.experimental.shard_map import shard_map
    import functools

    mesh = jax.make_mesh((1,), ("data",))
    d = 8
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(1, 4, d)), jnp.float32)
    y = jnp.ones((1, 4), jnp.float32)
    w0 = jnp.zeros((1, d))
    t0 = jnp.zeros((1,), jnp.int32)

    def per_device(w, t, X_l, y_l):
        # strip the local peer dim of size 1, run the protocol step, restore
        w2, t2 = linear_gossip_mesh_step(w[0], t[0], X_l[0], y_l[0],
                                         [(0, 0)], lam=1e-2, variant="mu",
                                         axis="data")
        return w2[None], t2[None]

    fn = shard_map(per_device, mesh=mesh,
                   in_specs=(PS("data"), PS("data"), PS("data"), PS("data")),
                   out_specs=(PS("data"), PS("data")))
    w, t = fn(w0, t0, X, y)
    assert w.shape == (1, d)
    assert int(t[0]) == 1
    assert bool(jnp.isfinite(w).all())
