"""repro-lint: every rule fires on a planted violation, stays quiet on a
clean fixture, and the suppression mechanism demands a reason.

Fixtures are written under ``tmp_path`` with repo-mimicking relative paths
(rules scope themselves by ``ModuleInfo.relpath``), so the linter runs
against them exactly as it runs against the real tree. The final test is
the real gate: ``python tools/lint/run.py`` over the live repo must exit 0
— the codebase itself is the clean fixture. The retrace budget math
(``tools/lint/retrace_guard.check_budgets``) is unit-tested here too; the
run itself lives in ``tools/run_tests.sh --bench-smoke``.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from lint.engine import run_lint, suppressions, ModuleInfo  # noqa: E402
from lint.retrace_guard import BUDGETS, check_budgets, diff_counts  # noqa: E402
from lint.rules import RULES  # noqa: E402


def lint_fixture(tmp_path, relpath, source, rule=None):
    """Write ``source`` at ``tmp_path/relpath`` and lint it."""
    fp = tmp_path / relpath
    fp.parent.mkdir(parents=True, exist_ok=True)
    fp.write_text(textwrap.dedent(source))
    rules = [RULES[rule]] if rule else list(RULES.values())
    return run_lint([fp], tmp_path, rules)


# ---------------------------------------------------------------------------
# rule 1: rng-discipline
# ---------------------------------------------------------------------------


def test_rng_unregistered_draw_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax

        def sneaky_jitter(key, w):
            noise = jax.random.normal(key, w.shape)
            return w + 0.01 * noise
        """, rule="rng-discipline")
    assert [v.rule for v in vs] == ["rng-discipline"]
    assert "sneaky_jitter" in vs[0].message
    assert "normal" in vs[0].message


def test_rng_registered_site_is_clean(tmp_path):
    # (core/simulation.py, cycle_core) is in the allowlist with exactly
    # split/randint/bernoulli — the positive control for the registry key
    vs = lint_fixture(tmp_path, "src/repro/core/simulation.py", """\
        import jax

        def cycle_core(state, key):
            k_recv, k_dst, k_delay, k_drop = jax.random.split(key, 4)
            dst = jax.random.randint(k_dst, (4,), 0, 4)
            drop = jax.random.bernoulli(k_drop, 0.5, (4,))
            return dst, drop
        """, rule="rng-discipline")
    assert vs == []


def test_rng_registered_site_wrong_fn_fires(tmp_path):
    # cycle_core may split/randint/bernoulli — not uniform
    vs = lint_fixture(tmp_path, "src/repro/core/simulation.py", """\
        import jax

        def cycle_core(state, key):
            return jax.random.uniform(key, (4,))
        """, rule="rng-discipline")
    assert [v.rule for v in vs] == ["rng-discipline"]


def test_rng_out_of_scope_dir_ignored(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/data/synthetic.py", """\
        import jax

        def sample(key):
            return jax.random.normal(key, (4,))
        """, rule="rng-discipline")
    assert vs == []


def test_rng_key_plumbing_not_a_draw(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax

        def reseed(seed):
            return jax.random.key(seed)
        """, rule="rng-discipline")
    assert vs == []


# ---------------------------------------------------------------------------
# rule 2: shardmap-spec-arity
# ---------------------------------------------------------------------------

SHARDMAP_HEADER = "    from repro.sharding.compat import shard_map_compat\n\n"


def test_shardmap_fixed_width_mismatch_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py",
                      SHARDMAP_HEADER + """\
    def apply(mesh, ps, a, b, c):
        def inner(x, y, z):
            return (x, y, z)
        f = shard_map_compat(inner, mesh=mesh,
                             in_specs=(ps,) * 2,
                             out_specs=(ps,) * 3)
        return f(a, b, c)
    """, rule="shardmap-spec-arity")
    assert [v.rule for v in vs] == ["shardmap-spec-arity"]
    assert "2 fixed spec(s)" in vs[0].message
    assert "3 positional" in vs[0].message


def test_shardmap_spec_arithmetic_resolves_clean(tmp_path):
    # the engine's real idiom: (ps,) * 8 + (ps2,) * 3 + dynamic varargs term
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py",
                      SHARDMAP_HEADER + """\
    def apply(mesh, ps, ps2, args, meta):
        def inner(a, b, c, d, e, f, g, h, i, j, k, *rest):
            return (a, b, c, d, e, f, g, h)
        fn = shard_map_compat(inner, mesh=mesh,
                              in_specs=(ps,) * 8 + (ps2,) * 3
                              + (ps,) * len(meta),
                              out_specs=(ps,) * 8)
        return fn(*args)
    """, rule="shardmap-spec-arity")
    assert vs == []


def test_shardmap_dynamic_term_without_varargs_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py",
                      SHARDMAP_HEADER + """\
    def apply(mesh, ps, meta, a, b):
        def inner(x, y):
            return (x, y)
        f = shard_map_compat(inner, mesh=mesh,
                             in_specs=(ps,) * 2 + (ps,) * len(meta),
                             out_specs=(ps,) * 2)
        return f(a, b)
    """, rule="shardmap-spec-arity")
    assert len(vs) == 1
    assert "no *varargs" in vs[0].message


def test_shardmap_out_specs_vs_returns_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py",
                      SHARDMAP_HEADER + """\
    def apply(mesh, ps, a, b):
        def inner(x, y):
            return (x, y)
        f = shard_map_compat(inner, mesh=mesh,
                             in_specs=(ps,) * 2,
                             out_specs=(ps,) * 3)
        return f(a, b)
    """, rule="shardmap-spec-arity")
    assert len(vs) == 1
    assert "returns a 2-tuple" in vs[0].message


# ---------------------------------------------------------------------------
# rule 3: merge-dtype-purity
# ---------------------------------------------------------------------------


def test_merge_mixed_dtype_arith_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax.numpy as jnp

        def merge(w_local, msg):
            w = w_local.astype(jnp.float32)
            payload = msg.astype(jnp.bfloat16)
            return 0.5 * (w + payload)
        """, rule="merge-dtype-purity")
    assert [v.rule for v in vs] == ["merge-dtype-purity"]
    assert "astype" in vs[0].message


def test_merge_explicit_astype_is_clean(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax.numpy as jnp

        def merge(w_local, msg):
            w = w_local.astype(jnp.float32)
            payload = msg.astype(jnp.bfloat16).astype(jnp.float32)
            return 0.5 * (w + payload)
        """, rule="merge-dtype-purity")
    assert vs == []


def test_merge_out_of_scope_file_ignored(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/cache.py", """\
        import jax.numpy as jnp

        def merge(w_local, msg):
            w = w_local.astype(jnp.float32)
            payload = msg.astype(jnp.bfloat16)
            return w + payload
        """, rule="merge-dtype-purity")
    assert vs == []


# ---------------------------------------------------------------------------
# rule 4: tracer-leak
# ---------------------------------------------------------------------------


def test_tracer_branch_in_scan_body_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        from jax import lax

        def run(xs, carry0):
            def body(carry, x):
                if x > 0:
                    carry = carry + x
                return carry, x
            return lax.scan(body, carry0, xs)
        """, rule="tracer-leak")
    assert [v.rule for v in vs] == ["tracer-leak"]
    assert "`if`" in vs[0].message


def test_tracer_float_coercion_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        from jax import lax

        def run(xs, carry0):
            def body(carry, x):
                scale = float(x)
                return carry * scale, x
            return lax.scan(body, carry0, xs)
        """, rule="tracer-leak")
    assert len(vs) == 1
    assert "float() coercion" in vs[0].message


def test_tracer_leak_in_callee_fires(tmp_path):
    # the taint follows the call into a same-module helper
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        from jax import lax

        def helper(v):
            if v > 0:
                return v
            return -v

        def run(xs, carry0):
            def body(carry, x):
                return carry + helper(x), x
            return lax.scan(body, carry0, xs)
        """, rule="tracer-leak")
    assert len(vs) == 1
    assert vs[0].rule == "tracer-leak"


def test_tracer_static_branches_are_clean(tmp_path):
    # shape reads, config compares, len() of python containers: all static
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        from jax import lax

        def run(xs, carry0, mode, meta):
            def body(carry, x):
                if mode == "compact":
                    carry = carry * 2
                if x.shape[0] > 1:
                    carry = carry + 1
                for _ in range(len(meta)):
                    carry = carry + x
                return carry, x
            return lax.scan(body, carry0, xs)
        """, rule="tracer-leak")
    assert vs == []


def test_tracer_outside_scan_is_clean(tmp_path):
    # plain python branching on values is fine outside traced bodies
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        def host_side(x):
            if x > 0:
                return float(x)
            return 0.0
        """, rule="tracer-leak")
    assert vs == []


# ---------------------------------------------------------------------------
# rule 5: codec-literal
# ---------------------------------------------------------------------------


def test_codec_unknown_literal_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        def launch(cfg_cls):
            return cfg_cls(wire_dtype="int3")
        """, rule="codec-literal")
    assert [v.rule for v in vs] == ["codec-literal"]
    assert "'int3'" in vs[0].message


def test_codec_registered_literals_clean(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        from repro.core.wire_codec import WIRE_CODECS, get_codec

        def launch(cfg_cls):
            get_codec("int8_sr")
            codec = WIRE_CODECS["ternary_ef"]
            return cfg_cls(wire_dtype="bf16")
        """, rule="codec-literal")
    assert vs == []


def test_codec_get_codec_unknown_fires(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        from repro.core.wire_codec import get_codec

        def launch():
            return get_codec("fp8")
        """, rule="codec-literal")
    assert len(vs) == 1
    assert "get_codec()" in vs[0].message


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_with_reason_silences(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax

        def jitter(key, w):
            n = jax.random.normal(key, w.shape)  # lint: disable=rng-discipline(noise ablation study)
            return w + n
        """, rule="rng-discipline")
    assert vs == []


def test_suppression_without_reason_is_a_violation(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax

        def jitter(key, w):
            n = jax.random.normal(key, w.shape)  # lint: disable=rng-discipline
            return w + n
        """, rule="rng-discipline")
    # the draw is still reported AND the bare suppression is its own error
    assert sorted(v.rule for v in vs) == ["rng-discipline", "suppression"]


def test_suppression_wrong_rule_does_not_silence(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/merge.py", """\
        import jax

        def jitter(key, w):
            n = jax.random.normal(key, w.shape)  # lint: disable=tracer-leak(wrong rule)
            return w + n
        """, rule="rng-discipline")
    assert [v.rule for v in vs] == ["rng-discipline"]


def test_syntax_error_reported_not_raised(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        def broken(:
        """)
    assert [v.rule for v in vs] == ["parse"]


def test_clean_fixture_all_rules(tmp_path):
    vs = lint_fixture(tmp_path, "src/repro/core/engine.py", """\
        import jax.numpy as jnp
        from jax import lax

        def run(xs, carry0):
            def body(carry, x):
                return carry + x.astype(jnp.float32), x
            return lax.scan(body, carry0, xs)
        """)
    assert vs == []


# ---------------------------------------------------------------------------
# the real gate: the repo itself lints clean, and the CLI exits nonzero on
# a planted violation
# ---------------------------------------------------------------------------


def test_repo_lints_clean():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint" / "run.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro-lint: OK" in proc.stdout


def test_cli_exits_nonzero_on_violation(tmp_path):
    fp = tmp_path / "src" / "repro" / "core" / "bad.py"
    fp.parent.mkdir(parents=True)
    fp.write_text("import jax\n\n"
                  "def f(key):\n"
                  "    return jax.random.normal(key, (4,))\n")
    # run.py resolves relpaths against the real repo root, so plant the
    # file inside it only via the engine API above; here we drive the CLI
    # with an in-repo fixture under a throwaway name
    target = REPO / "src" / "repro" / "core" / "_lint_probe_tmp.py"
    target.write_text(fp.read_text())
    try:
        proc = subprocess.run(
            [sys.executable, str(REPO / "tools" / "lint" / "run.py"),
             str(target)],
            capture_output=True, text=True)
    finally:
        target.unlink()
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "[rng-discipline]" in proc.stdout


# ---------------------------------------------------------------------------
# CONTRACTS.md stays honest
# ---------------------------------------------------------------------------


def test_every_rule_has_a_contract_line():
    for name, rule in RULES.items():
        assert rule.contract, f"rule {name} has an empty contract string"


def test_contracts_doc_lists_every_rule():
    doc = (REPO / "docs" / "CONTRACTS.md").read_text()
    for name in RULES:
        assert f"`{name}`" in doc, f"docs/CONTRACTS.md missing rule {name}"


# ---------------------------------------------------------------------------
# retrace budget math
# ---------------------------------------------------------------------------


def test_budgets_pass_within_limits():
    assert check_budgets({"simulation.simulate_cycle": 1,
                          "sharded_engine._draw_chunk": 1}, BUDGETS) == []


def test_budgets_fail_when_exceeded():
    errs = check_budgets({"simulation.simulate_cycle": 3}, BUDGETS)
    assert len(errs) == 1
    assert "retracing" in errs[0]


def test_budgets_fail_on_unbudgeted_source():
    errs = check_budgets({"sharded_engine.chunk_fn[0:new/path/x/y]": 1},
                         BUDGETS)
    assert len(errs) == 1
    assert "unbudgeted" in errs[0]


def test_budgets_normalize_chunk_fn_instance_index():
    # two instances of the same config label aggregate onto one budget key
    errs = check_budgets(
        {"sharded_engine.chunk_fn[0:mu/pegasos/dense/f32]": 1,
         "sharded_engine.chunk_fn[3:mu/pegasos/dense/f32]": 1},
        {"sharded_engine.chunk_fn[mu/pegasos/dense/f32]": 1})
    assert len(errs) == 1
    assert "2 compile(s) > budget 1" in errs[0]


def test_warm_rerun_diff_flags_growth():
    cold = {"simulation.simulate_cycle": 1}
    assert diff_counts(cold, {"simulation.simulate_cycle": 1}) == []
    errs = diff_counts(cold, {"simulation.simulate_cycle": 2,
                              "simulation._eval": 1})
    assert len(errs) == 2
    assert all("warm rerun" in e for e in errs)
