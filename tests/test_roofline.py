"""Roofline machinery unit tests (HLO collective parser, MODEL_FLOPS)."""
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, get_config
from repro.launch.roofline import (model_flops_for, parse_collectives,
                                   _shape_bytes)

HLO = """
HloModule jit_step
  %all-reduce.1 = f32[16,4096,2048]{2,1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[2048,9496]{1,0} all-gather(%w), replica_groups=[16,16]<=[256], dimensions={0}
  %reduce-scatter.3 = f32[128]{0} reduce-scatter(%g), replica_groups={{0,1}}, to_apply=%add
  %all-to-all.4 = bf16[16,1280,5120]{2,1,0} all-to-all(%buf), replica_groups={{0,1,2,3,4,5,6,7}}
  %collective-permute.5 = f32[64]{0} collective-permute(%p), source_target_pairs={{0,1}}
  %cp-start = (f32[8]{0}, f32[8]{0}) collective-permute-start(%q), source_target_pairs={{0,1}}
  ROOT %t = f32[] constant(0)
"""


def test_shape_bytes():
    assert _shape_bytes("f32", "16,4096,2048") == 16 * 4096 * 2048 * 4
    assert _shape_bytes("bf16", "8") == 16
    assert _shape_bytes("pred", "") == 1


def test_parse_collectives_ops_and_groups():
    st = parse_collectives(HLO)
    assert st.count["all-reduce"] == 1
    assert st.count["all-gather"] == 1
    assert st.count["reduce-scatter"] == 1
    assert st.count["all-to-all"] == 1
    assert st.count["collective-permute"] >= 1
    ar = 16 * 4096 * 2048 * 4
    assert st.per_op["all-reduce"] == ar
    # all-gather operand = result / group-size (iota groups [16,16])
    ag = 2048 * 9496 * 2
    assert st.per_op["all-gather"] == ag // 16
    # reduce-scatter operand = result * group-size
    assert st.per_op["reduce-scatter"] == 128 * 4 * 2
    # wire model: all-reduce = 2*res*(g-1)/g
    assert st.wire_bytes > 0


def test_model_flops_dense_vs_moe():
    dense = get_config("qwen3-8b")
    moe = get_config("mixtral-8x22b")
    sh = INPUT_SHAPES["train_4k"]
    f_dense = model_flops_for(dense, sh)
    toks = sh.global_batch * sh.seq_len
    np.testing.assert_allclose(f_dense, 6.0 * dense.param_count() * toks)
    # MoE active params far below total
    assert moe.active_param_count() < 0.45 * moe.param_count()
    f_moe = model_flops_for(moe, sh)
    assert f_moe == 6.0 * moe.active_param_count() * toks


def test_param_counts_plausible():
    cases = {
        "qwen3-8b": (7e9, 10e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "llama3-405b": (3.7e11, 4.4e11),
        "mamba2-780m": (6e8, 9e8),
        "mixtral-8x22b": (1.2e11, 1.6e11),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n:.3e} outside [{lo:.0e},{hi:.0e}]"


def test_decode_model_flops_counts_one_token():
    cfg = get_config("qwen3-1.7b")
    sh = INPUT_SHAPES["decode_32k"]
    f = model_flops_for(cfg, sh)
    assert f == 2.0 * cfg.active_param_count() * sh.global_batch
