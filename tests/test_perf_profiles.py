"""§Perf machinery: decode sharding profiles, grouped MoE dispatch,
gossip merge exchange dtypes. Pure-logic + 1-device tests (no 512-device
env needed)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.config.base import MoEConfig
from repro.core.gossip_optimizer import gossip_merge
from repro.models import layers as L
from repro.models.moe import moe_ffn, moe_spec
from repro.sharding.rules import cache_pspecs, default_rules, partition_spec


# ---------------------------------------------------------------------------
# cache profiles (the decode hillclimb, EXPERIMENTS.md §Perf A-1)
# ---------------------------------------------------------------------------


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)


def test_cache_context_profile_shards_length_and_batch_over_model():
    kv = {"k": jax.ShapeDtypeStruct((36, 128, 32768, 8, 128), jnp.bfloat16)}
    ps = cache_pspecs(kv, _FakeMesh(), profile="context")["k"]
    assert ps[2] == "data"     # KV length over data (context parallel)
    # batch over model — attention-parallel across the model axis; sharding
    # head_dim instead was measured 135x worse (EXPERIMENTS.md §Perf A-3b)
    assert ps[1] == "model"


def test_cache_batch_profile_shards_batch():
    kv = {"k": jax.ShapeDtypeStruct((36, 128, 32768, 8, 128), jnp.bfloat16)}
    ps = cache_pspecs(kv, _FakeMesh(), profile="batch")["k"]
    assert ps[1] == "data"


def test_cache_context_falls_back_to_batch_when_length_indivisible():
    # whisper cross cache: 1500 frames % 16 != 0
    kv = {"ck": jax.ShapeDtypeStruct((128, 1500, 16, 64), jnp.bfloat16)}
    ps = cache_pspecs(kv, _FakeMesh(), profile="context")["ck"]
    assert ps[0] == "data"                 # batch fallback


def test_inference_rules_2d_ffn():
    rules = default_rules(inference=True)
    sizes = {"data": 16, "model": 16}
    ps = partition_spec((4096, 12288), ("embed", "ffn"), sizes, rules)
    assert ps == PS(None, ("model", "data"))   # 2D where divisible
    ps = partition_spec((16384, 128, 128), ("embed", "heads", "head_dim"),
                        sizes, rules)
    # heads=128 cannot take 256 -> falls back to model; head_dim takes data
    assert ps == PS(None, "model", "data")


# ---------------------------------------------------------------------------
# grouped MoE dispatch == ungrouped when nothing drops (B-1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_dispatch_matches_ungrouped(groups):
    m1 = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                   capacity_factor=8.0, dispatch_groups=1)
    mg = dataclasses.replace(m1, dispatch_groups=groups)
    params = L.init_params(jax.random.key(0), moe_spec(16, m1, "swiglu"))
    x = jax.random.normal(jax.random.key(1), (4, 8, 16), jnp.float32)
    o1, a1 = moe_ffn(params, m1, x, "swiglu")
    og, ag = moe_ffn(params, mg, x, "swiglu")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(og),
                               rtol=2e-5, atol=2e-5)
    assert float(a1["drop_fraction"]) == 0.0 == float(ag["drop_fraction"])


def test_grouped_dispatch_capacity_is_per_group():
    # tight capacity: grouped capacity must be computed from group tokens,
    # not global tokens (the global-capacity bug of EXPERIMENTS.md §Perf B-1)
    m = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                  capacity_factor=1.0, dispatch_groups=4)
    params = L.init_params(jax.random.key(0), moe_spec(8, m, "gelu"))
    x = jax.random.normal(jax.random.key(1), (4, 16, 8), jnp.float32)
    out, aux = moe_ffn(params, m, x, "gelu")
    assert out.shape == x.shape
    assert 0.0 <= float(aux["drop_fraction"]) < 0.7


# ---------------------------------------------------------------------------
# gossip merge (C-2/C-3)
# ---------------------------------------------------------------------------


def test_gossip_merge_static_perm_take_path():
    params = {"w": jnp.arange(16.0)[:, None] * jnp.ones((16, 4))}
    perm = tuple(np.arange(16) ^ 1)
    merged = gossip_merge(params, perm)
    np.testing.assert_allclose(np.asarray(merged["w"][0]), 0.5)
    np.testing.assert_allclose(float(merged["w"].sum()),
                               float(params["w"].sum()), rtol=1e-6)


def test_gossip_merge_bf16_exchange_close_to_f32():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)}
    perm = tuple(np.arange(8) ^ 1)
    a = gossip_merge(params, perm)
    b = gossip_merge(params, perm, exchange_dtype=jnp.bfloat16)
    # the partner contribution is quantized; bound the relative error
    err = float(jnp.max(jnp.abs(a["w"] - b["w"])))
    assert err < 0.02
    # self-contribution is NOT quantized: merging with identity perm in
    # bf16 still averages x with quantize(x) -> error bounded by bf16 eps
    ident = tuple(range(8))
    c = gossip_merge(params, ident, exchange_dtype=jnp.bfloat16)
    assert float(jnp.max(jnp.abs(c["w"] - params["w"]))) < 0.02


def test_gossip_merge_rejects_mismatched_mesh_size_gracefully():
    # peer axis size != len(perm) -> falls back to the take path
    params = {"w": jnp.ones((4, 8))}
    out = gossip_merge(params, (1, 0, 3, 2), mesh=None, peer_axes=("data",))
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)


# ---------------------------------------------------------------------------
# windowed chunked attention (SWA hillclimb)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("win,chunk", [(8, 16), (16, 8), (64, 16), (None, 16), (7, 16)])
def test_chunked_sdpa_windowed_key_slicing_matches_full(win, chunk):
    from repro.config.base import AttentionConfig
    from repro.models import attention as A
    rng = np.random.default_rng(0)
    S = 64
    a = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                        sliding_window=win, causal=True)
    q = jnp.asarray(rng.normal(size=(2, S, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, S, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, S, 2, 8)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = A._grouped_sdpa(q, k, v, a, pos, pos, jnp.float32)
    out = A._chunked_sdpa(q, k, v, a, pos, jnp.float32, chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
