"""Telemetry subsystem: bitwise invisibility, stream parity, histograms.

The contract under test (docs/CONTRACTS.md + docs/OBSERVABILITY.md):
telemetry is a **pure read**. Arming a :class:`repro.core.telemetry.
Telemetry` must leave error curves and all protocol totals bitwise
identical on BOTH engines, across failure scenarios and wire codecs; and
because the streams are reads of the same protocol, the reference engine
and the sharded engine must emit bitwise-equal metric streams at a
matched seed — the metric stream is itself a cross-engine parity surface.
The message-economy balance invariant (PR 1's run-total identity) must
hold per cycle from the streams alone."""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.configs.gossip_linear import (GossipLinearConfig,
                                         with_failure_scenario)
from repro.core.simulation import message_wire_bytes, run_simulation
from repro.core.telemetry import (METRIC_STREAMS, SPAN_NAMES, TRACKS,
                                  LatencyHistogram, Telemetry, best_of,
                                  maybe_span)


def toy(n=256, d=8, seed=0):
    from repro.data.synthetic import make_linear_dataset
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 64, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


def small_cfg(n_nodes=256, scenario="clean", **kw):
    base = dict(name="telemetry-toy", dim=8, n_nodes=n_nodes, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu", cache_size=4)
    base.update(kw)
    return with_failure_scenario(GossipLinearConfig(**base), scenario)


def totals(res):
    return (res.err_fresh, res.err_voted, res.sent_total,
            res.delivered_total, res.lost_total, res.overflow_total,
            res.wire_bytes_total)


KW = dict(cycles=25, eval_every=10, seed=0, k_rounds=2)


# ---------------------------------------------------------------- invisibility


@pytest.mark.parametrize("engine", ["reference", "sharded"])
@pytest.mark.parametrize("scenario", ["clean", "extreme"])
@pytest.mark.parametrize("wire", [None, "int4"])
def test_armed_run_is_bitwise_invisible(engine, scenario, wire):
    """telemetry=None vs an armed Telemetry: identical curves + totals."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(scenario=scenario, wire_dtype=wire)
    plain = run_simulation(cfg, X, y, Xt, yt, engine=engine, **KW)
    armed = run_simulation(cfg, X, y, Xt, yt, engine=engine,
                           telemetry=Telemetry(), **KW)
    assert totals(plain) == totals(armed)
    assert plain.ef_residual_norm == armed.ef_residual_norm


# --------------------------------------------------------------- stream parity


@pytest.mark.parametrize("scenario", ["clean", "extreme"])
def test_reference_and_sharded_emit_equal_streams(scenario):
    """Every registered parity stream: reference == sharded, bitwise."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(scenario=scenario)
    tels = {}
    for engine in ("reference", "sharded"):
        tels[engine] = Telemetry(label=engine)
        run_simulation(cfg, X, y, Xt, yt, engine=engine,
                       telemetry=tels[engine], **KW)
    for name, spec in METRIC_STREAMS.items():
        a = tels["reference"].stream_array(name)
        b = tels["sharded"].stream_array(name)
        assert spec.parity, name
        assert np.array_equal(a, b), (name, a, b)
        # eval points: every eval_every cycles plus the final cycle
        n_evals = KW["cycles"] // KW["eval_every"] + (
            1 if KW["cycles"] % KW["eval_every"] else 0)
        expect = KW["cycles"] if spec.cadence == "cycle" else n_evals
        assert a.size == expect, (name, a.size)


def test_stream_parity_under_faults_and_defense():
    """fault_stats streams (corrupted/gated/clipped) agree cross-engine
    under a 10% sign_flip adversary with the norm_clip defense, and sum
    to the run totals both engines report."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(scenario="extreme", fault_model="sign_flip",
                    byzantine_frac=0.1, defense="norm_clip")
    tels, results = {}, {}
    for engine in ("reference", "sharded"):
        tels[engine] = Telemetry()
        results[engine] = run_simulation(cfg, X, y, Xt, yt, engine=engine,
                                         telemetry=tels[engine], **KW)
    for name in ("corrupted", "gated", "clipped"):
        a = tels["reference"].stream_array(name)
        b = tels["sharded"].stream_array(name)
        assert np.array_equal(a, b), name
        assert a.sum() == results["reference"].fault_stats[name]
    assert tels["reference"].stream_array("corrupted").sum() > 0


def test_ef_residual_stream_matches_result():
    """EF codecs: the eval-cadence residual stream's last value equals the
    result's terminal ef_residual_norm; non-EF codecs emit zeros."""
    X, y, Xt, yt = toy()
    for wire, has_ef in [("int4_ef", True), (None, False)]:
        tel = Telemetry()
        res = run_simulation(small_cfg(wire_dtype=wire), X, y, Xt, yt,
                             engine="sharded", telemetry=tel, **KW)
        ef = tel.stream_array("ef_residual_rms")
        assert ef.size == 3
        if has_ef:
            assert ef[-1] == res.ef_residual_norm > 0.0
        else:
            assert not ef.any()


# ------------------------------------------------------------ balance invariant


@pytest.mark.parametrize("engine", ["reference", "sharded"])
def test_message_economy_balance_from_streams(engine):
    """Per cycle: cumsum(sent - delivered - lost - overflow) == in_flight,
    in_flight stays non-negative and ends at the undelivered remainder;
    wire_bytes == sent x per-message bytes."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(scenario="extreme")
    tel = Telemetry()
    res = run_simulation(cfg, X, y, Xt, yt, engine=engine, telemetry=tel,
                         **KW)
    sent = tel.stream_array("sent")
    flow = np.cumsum(sent - tel.stream_array("delivered")
                     - tel.stream_array("lost")
                     - tel.stream_array("overflow"))
    in_flight = tel.stream_array("in_flight")
    assert np.array_equal(flow, in_flight)
    assert (in_flight >= 0).all()
    assert sent.sum() == res.sent_total
    assert in_flight[-1] == (res.sent_total - res.delivered_total
                             - res.lost_total - res.overflow_total)
    bytes_per_msg = message_wire_bytes(cfg.dim, cfg.wire_dtype)
    assert np.array_equal(tel.stream_array("wire_bytes"),
                          sent * bytes_per_msg)


def test_emit_rejects_unregistered_stream():
    tel = Telemetry()
    with pytest.raises(KeyError):
        tel.emit("not_a_stream", 1)


# ------------------------------------------------------------------- histogram


def test_histogram_percentiles_exact_on_constant_samples():
    h = LatencyHistogram()
    for _ in range(100):
        h.record(0.004)
    assert h.count == 100
    assert h.p50 == h.p99 == h.p999 == 0.004
    assert h.mean == pytest.approx(0.004)


def test_histogram_percentiles_ordered_and_bounded():
    rng = np.random.default_rng(3)
    vals = rng.lognormal(-6.0, 1.5, 5000)
    h = LatencyHistogram()
    h.record_many(vals)
    assert h.min_value == vals.min() and h.max_value == vals.max()
    assert (h.min_value <= h.p50 <= h.p90 <= h.p99 <= h.p999
            <= h.max_value)
    # fixed log buckets: the p50 estimate lands within one bucket (~33%
    # relative width at 8 buckets/decade) of the exact percentile
    exact = np.percentile(vals, 50)
    assert abs(h.p50 - exact) / exact < 0.4


def test_histogram_merge_is_exact_bucket_addition():
    rng = np.random.default_rng(4)
    a, b = LatencyHistogram(), LatencyHistogram()
    va, vb = rng.uniform(1e-5, 1e-2, 200), rng.uniform(1e-4, 1e-1, 300)
    a.record_many(va)
    b.record_many(vb)
    both = LatencyHistogram()
    both.record_many(np.concatenate([va, vb]))
    a.merge(b)
    assert np.array_equal(a.counts, both.counts)
    assert a.count == both.count == 500
    assert a.p99 == both.p99

    empty = LatencyHistogram()
    assert empty.p50 == 0.0 and empty.mean == 0.0


def test_best_of_returns_min_and_result():
    calls = []
    best, secs, result = best_of(lambda: calls.append(0) or len(calls),
                                 repeats=3)
    assert result == 3 and len(secs) == 3 and best == min(secs)


# ------------------------------------------------------------------ spans/trace


def test_maybe_span_unarmed_is_noop():
    with maybe_span(None, "route_chunk"):
        pass  # no Telemetry object: nullcontext, nothing recorded


def test_span_track_validation():
    tel = Telemetry()
    with pytest.raises(ValueError):
        tel.span("cycle", track="not_a_track")


def test_chrome_trace_schema(tmp_path):
    """Exported traces are valid Chrome trace-event JSON: every span is a
    complete event on a named track thread, the streams ride as counter
    events and in otherData, and tools/trace_report.py summarizes it."""
    X, y, Xt, yt = toy()
    tel = Telemetry(label="schema-test")
    run_simulation(small_cfg(scenario="extreme"), X, y, Xt, yt,
                   engine="sharded", telemetry=tel, **KW)
    fp = tel.export_chrome_trace(tmp_path / "trace.json")
    payload = json.loads(fp.read_text())

    events = payload["traceEvents"]
    assert payload["displayTimeUnit"] == "ms"
    thread_names = {e["args"]["name"] for e in events
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    assert thread_names == set(TRACKS)
    spans = [e for e in events if e["ph"] == "X"]
    assert spans and len(spans) == len(tel.spans)
    for e in spans:
        assert e["name"] in SPAN_NAMES
        assert e["dur"] >= 0 and e["cat"] in TRACKS
        assert "compiles" in e["args"]
    counters = {e["name"] for e in events if e["ph"] == "C"}
    cycle_streams = {n for n, s in METRIC_STREAMS.items()
                     if s.cadence == "cycle"}
    assert counters == cycle_streams

    other = payload["otherData"]
    assert set(other["streams"]) == set(METRIC_STREAMS)
    assert other["annotations"]["runs"][0]["engine"] == "sharded"

    import subprocess
    import sys
    from pathlib import Path
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, str(repo / "tools" / "trace_report.py"), str(fp)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "balance invariant OK" in proc.stdout


def test_span_names_documented():
    """Every span name the engines emit is in the SPAN_NAMES convention
    table (the docs/OBSERVABILITY.md naming contract)."""
    X, y, Xt, yt = toy()
    from repro.launch.gossip_serve import GossipServer
    tel = Telemetry()
    srv = GossipServer(batch_size=16, telemetry=tel)

    def hook(cycle, snap):
        srv.serve_hook(cycle, snap)
        srv.submit(Xt[:16])

    for engine in ("reference", "sharded"):
        run_simulation(small_cfg(), X, y, Xt, yt, engine=engine,
                       serve_hook=hook, telemetry=tel, **KW)
    srv.flush()
    emitted = {s.name for s in tel.spans}
    assert emitted <= set(SPAN_NAMES)
    assert {"cycle", "eval", "route_chunk", "chunk_dispatch",
            "snapshot", "snapshot_adopt", "serve_batch"} <= emitted
    # the serving histogram is shared into the telemetry object
    assert tel.histograms["serve_batch_latency"].count == len(srv.batches)


def test_serve_stats_histogram_backed():
    """GossipServer.stats() derives its percentiles from the shared
    LatencyHistogram and carries the sparse bucket dump."""
    X, y, Xt, yt = toy()
    from repro.launch.gossip_serve import GossipServer
    srv = GossipServer(batch_size=16)

    def hook(cycle, snap):
        srv.serve_hook(cycle, snap)
        srv.submit(Xt[:16])

    run_simulation(small_cfg(), X, y, Xt, yt, engine="sharded",
                   serve_hook=hook, **KW)
    srv.flush()
    s = srv.stats()
    assert s.batches == srv.hist.count > 0
    assert s.p50_latency_s == srv.hist.p50
    assert s.p90_latency_s == srv.hist.p90
    assert s.p999_latency_s == srv.hist.p999
    assert s.latency_hist["count"] == s.batches
    assert sum(s.latency_hist["bucket_counts"]) == s.batches


def test_multi_run_arming_concatenates_streams():
    """One Telemetry across two sequential runs (the robustness-sweep
    --trace mode): streams concatenate in run order."""
    X, y, Xt, yt = toy()
    tel = Telemetry()
    for scenario in ("clean", "extreme"):
        run_simulation(small_cfg(scenario=scenario), X, y, Xt, yt,
                       engine="sharded", telemetry=tel, **KW)
    assert tel.stream_array("sent").size == 2 * KW["cycles"]
    assert len(tel.annotations["runs"]) == 2
