"""End-to-end behaviour tests for the paper's system.

The paper's headline claims, executed on CPU at reduced scale:
  1. gossip learning converges to the quality of centralized Pegasos;
  2. merging (MU) converges much faster than independent random walks (RW);
  3. the system keeps converging under extreme failures (drop/delay/churn);
  4. the Layer-B gossip optimizer trains a transformer to the same loss
     region as exact all-reduce data parallelism;
  5. end-to-end serving produces identical results to the training-side
     forward pass (consistency across the stack).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core.ensemble import run_sequential_pegasos
from repro.core.simulation import run_simulation
from repro.data.synthetic import make_linear_dataset


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    X, y = make_linear_dataset(rng, 256, 24, noise=0.03, separation=3.0)
    return X[:192], y[:192], X[192:], y[192:]


def cfg(**kw):
    base = dict(name="sys", dim=24, n_nodes=192, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def test_gossip_reaches_centralized_quality(data):
    X, y, Xt, yt = data
    _, pts = run_sequential_pegasos(X, y, Xt, yt, iters=5000, lam=1e-3)
    central = pts[-1][1]
    res = run_simulation(cfg(), X, y, Xt, yt, cycles=120, eval_every=120,
                         seed=0)
    assert res.err_voted[-1] <= central + 0.08, \
        f"gossip {res.err_voted[-1]} vs centralized {central}"


def test_mu_much_faster_than_rw(data):
    """Fig. 1: at a fixed early cycle budget MU is far ahead of RW."""
    X, y, Xt, yt = data
    early = 25
    mu = run_simulation(cfg(variant="mu"), X, y, Xt, yt, cycles=early,
                        eval_every=early, seed=1)
    rw = run_simulation(cfg(variant="rw"), X, y, Xt, yt, cycles=early,
                        eval_every=early, seed=1)
    assert mu.err_fresh[-1] < rw.err_fresh[-1] - 0.03, \
        f"MU {mu.err_fresh[-1]} not clearly ahead of RW {rw.err_fresh[-1]}"


def test_extreme_failures_slow_but_do_not_break(data):
    X, y, Xt, yt = data
    ok = run_simulation(cfg(), X, y, Xt, yt, cycles=100, eval_every=100, seed=2)
    af = run_simulation(cfg(drop_prob=0.5, delay_max_cycles=10,
                            online_fraction=0.9),
                        X, y, Xt, yt, cycles=100, eval_every=100, seed=2)
    assert af.err_fresh[-1] < 0.30          # still converging
    assert ok.err_fresh[-1] <= af.err_fresh[-1] + 0.05  # failures never help


def test_gossip_transformer_matches_allreduce_loss():
    from repro.launch.train import train
    _, h_ar = train("qwen3-1.7b", reduced=True, steps=40, batch=8, seq_len=32,
                    lr=3e-3, dist="allreduce", log_every=40, seed=0,
                    d_model=128)
    _, h_go = train("qwen3-1.7b", reduced=True, steps=40, batch=8, seq_len=32,
                    lr=3e-3, dist="gossip", n_peers=4, merge="mu",
                    log_every=40, seed=0, d_model=128)
    ar, go = h_ar[-1][1], h_go[-1][1]
    assert abs(ar - go) < 0.8, f"allreduce {ar} vs gossip {go}"
    assert h_go[-1][2] < 0.3  # peers agree


def test_serve_matches_training_forward():
    from repro.config import get_config, reduced_config
    from repro.launch.serve import DecodeServer
    from repro.models import transformer as T
    cfg_ = reduced_config(get_config("qwen3-1.7b"))
    cfg_ = cfg_.replace(compute_dtype=jnp.float32)
    params = T.init_params(jax.random.key(0), cfg_)
    srv = DecodeServer(cfg_, params, batch=2, max_len=24)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg_.vocab_size, (2, 12))
    logits, _ = srv.prefill(prompts)
    full, _ = T.forward(params, cfg_, jnp.asarray(prompts, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, -1]), atol=2e-3)
