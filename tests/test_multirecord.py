"""Section II's multi-record generalization: gossip learning with k local
records per node still converges, and its advantage over independent random
walks *shrinks* as k grows (the paper's own caveat: "its advantages to known
approaches become less significant" when local data suffices)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core.simulation import run_simulation
from repro.data.synthetic import make_linear_dataset


def _dataset(n_nodes, k, d=24, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n_nodes * k + 400, d, noise=0.05,
                               separation=3.0)
    Xt, yt = X[-400:], y[-400:]
    Xn = X[:n_nodes * k].reshape(n_nodes, k, d)
    yn = y[:n_nodes * k].reshape(n_nodes, k)
    return Xn, yn, Xt, yt


def _cfg(variant, d):
    return GossipLinearConfig("multirecord", dim=d, n_nodes=0, n_test=0,
                              class_ratio=(1, 1), lam=1e-2, variant=variant)


def test_multirecord_converges():
    X, y, Xt, yt = _dataset(256, 4)
    res = run_simulation(_cfg("mu", 24), X, y, Xt, yt, cycles=60,
                         eval_every=60, seed=0)
    assert res.err_fresh[-1] < 0.15


def test_single_record_reduces_to_2d_path():
    # (N, 1, d) must behave like (N, d): same protocol, k=1 round robin
    X, y, Xt, yt = _dataset(256, 1)
    r3 = run_simulation(_cfg("mu", 24), X, y, Xt, yt, cycles=30,
                        eval_every=30, seed=0)
    r2 = run_simulation(_cfg("mu", 24), X[:, 0], y[:, 0], Xt, yt, cycles=30,
                        eval_every=30, seed=0)
    assert abs(r3.err_fresh[-1] - r2.err_fresh[-1]) < 1e-6


def test_multirecord_sharded_engine_parity():
    """(N, k, d) nodes on the sharded engine: the ``rec = clock % k``
    round-robin must stay aligned with the reference engine across chunk
    boundaries (clock lives in the scan carry), including under the extreme
    failure scenario and wire quantization."""
    import dataclasses
    X, y, Xt, yt = _dataset(96, 3)
    cfg = dataclasses.replace(_cfg("mu", 24), drop_prob=0.4,
                              delay_max_cycles=5, online_fraction=0.9)
    # eval_every=7 with k=3 records puts chunk boundaries at clocks that are
    # not multiples of k — the rotation must resume mid-stride
    kw = dict(cycles=21, eval_every=7, seed=9)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert ref.cycles == sh.cycles
    for a, b in zip(ref.err_fresh, sh.err_fresh):
        assert abs(a - b) <= 0.02, (ref.err_fresh, sh.err_fresh)
    assert (ref.sent_total, ref.delivered_total, ref.lost_total) == \
        (sh.sent_total, sh.delivered_total, sh.lost_total)

    cfg_q = dataclasses.replace(cfg, wire_dtype="bf16")
    ref_q = run_simulation(cfg_q, X, y, Xt, yt, **kw)
    sh_q = run_simulation(cfg_q, X, y, Xt, yt, engine="sharded", **kw)
    for a, b in zip(ref_q.err_fresh, sh_q.err_fresh):
        assert abs(a - b) <= 0.02, (ref_q.err_fresh, sh_q.err_fresh)


@pytest.mark.slow
def test_gossip_advantage_shrinks_with_local_records():
    """Paper §II: with more local data the RW (local-learning-like) baseline
    closes the gap to MU."""
    gaps = []
    for k in (1, 8):
        X, y, Xt, yt = _dataset(384, k, seed=1)
        mu = run_simulation(_cfg("mu", 24), X, y, Xt, yt, cycles=40,
                            eval_every=40, seed=0).err_fresh[-1]
        rw = run_simulation(_cfg("rw", 24), X, y, Xt, yt, cycles=40,
                            eval_every=40, seed=0).err_fresh[-1]
        gaps.append(rw - mu)
    assert gaps[1] < gaps[0] + 0.02   # advantage does not grow with k
