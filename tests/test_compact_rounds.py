"""Round-1 compaction (compact_all) property tests.

The sharded engine's delivery-proportional paths — ``compact`` (round 1
dense, rounds >= 2 packed) and ``compact_all`` (every round packed over the
round-1 receiver set) — must be *invisible*: for any failure scenario, any
wire dtype and any packing choice, the curves and the message economy equal
the reference engine's bitwise. The scenarios here are drawn from a seeded
rng (property-style, reproducible without hypothesis) and cover the sparse
regimes the compaction targets, zero-delivery cycles, odd N and forced
mid-run fallbacks."""
import dataclasses

import numpy as np
import pytest

from repro.configs.gossip_linear import (FAILURE_SCENARIOS,
                                         GossipLinearConfig,
                                         with_failure_scenario)
from repro.core.sharded_engine import (pack_compact_all, shard_list_width,
                                       _pack_index_lists)
from repro.core.simulation import run_simulation
from repro.data.synthetic import make_linear_dataset


def cfg_for(n, d=12, **kw):
    base = dict(name="prop", dim=d, n_nodes=n, n_test=48,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def toy(n, d=12, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 48, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


def assert_bitwise(ref, sh):
    assert ref.cycles == sh.cycles
    assert ref.err_fresh == sh.err_fresh, (ref.err_fresh, sh.err_fresh)
    assert ref.err_voted == sh.err_voted
    assert (ref.sent_total, ref.delivered_total, ref.lost_total,
            ref.overflow_total) == (sh.sent_total, sh.delivered_total,
                                    sh.lost_total, sh.overflow_total)
    assert ref.delivered_per_cycle == sh.delivered_per_cycle


# one random scenario per wire dtype, drawn from a fixed-seed rng: the
# sweep covers every wire dtype under a different (drop, online, delay,
# parity-of-N, k_rounds) point of the sparse-delivery region
def _scenarios():
    rng = np.random.default_rng(1234)
    out = []
    for wire in [None, "bf16", "f16", "int8", "int8_sr"]:
        out.append(dict(
            wire=wire,
            drop=float(rng.uniform(0.3, 0.9)),
            online=float(rng.uniform(0.1, 0.6)),
            delay=int(rng.integers(2, 11)),
            n=int(rng.integers(40, 90)) * 2 + int(rng.integers(0, 2)),
            k_rounds=int(rng.integers(2, 6)),
            seed=int(rng.integers(0, 1000)),
        ))
    return out


@pytest.mark.parametrize("sc", _scenarios(),
                         ids=lambda sc: f"{sc['wire'] or 'f32'}-n{sc['n']}")
def test_sparse_delivery_compaction_bitwise(sc):
    """Random sparse scenarios, every wire dtype: the auto-compacted
    sharded engine reproduces the reference engine bitwise."""
    X, y, Xt, yt = toy(sc["n"], seed=sc["seed"])
    cfg = cfg_for(sc["n"], drop_prob=sc["drop"], online_fraction=sc["online"],
                  delay_max_cycles=sc["delay"], wire_dtype=sc["wire"])
    kw = dict(cycles=24, eval_every=8, seed=sc["seed"],
              k_rounds=sc["k_rounds"])
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert_bitwise(ref, sh)
    # sparse regimes must actually exercise a compacted packing
    modes = sh.compaction["chunk_modes"]
    assert modes["compact"] + modes["compact_all"] > 0, modes


def test_zero_delivery_cycles_bitwise():
    """drop = 1.0: every message is dropped, every cycle delivers nothing —
    the compact tables are all padding and must stay inert."""
    n = 33                                     # odd N on top
    X, y, Xt, yt = toy(n)
    cfg = cfg_for(n, drop_prob=1.0, delay_max_cycles=4, online_fraction=0.5)
    kw = dict(cycles=12, eval_every=6, seed=7)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert_bitwise(ref, sh)
    assert sh.delivered_total == 0
    assert sh.delivered_per_cycle == [0] * 12
    assert sh.compaction["round1_occupancy_max"] == 0.0


@pytest.mark.parametrize("mode", ["dense", "compact", "compact_all"])
@pytest.mark.parametrize("wire", [None, "int8_sr"])
def test_forced_packing_modes_agree(mode, wire):
    """Every forced packing (dense / compact / compact_all) produces the
    same bits — the packing is an execution detail, never protocol."""
    n = 96
    X, y, Xt, yt = toy(n)
    cfg = with_failure_scenario(
        cfg_for(n, wire_dtype=wire), "extreme")
    kw = dict(cycles=20, eval_every=10, seed=3, k_rounds=4)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                        compact_mode=mode, **kw)
    assert_bitwise(ref, sh)
    assert sh.compaction["chunk_modes"][mode] == len(sh.cycles)


def test_forced_compact_all_to_dense_fallback_mid_run(monkeypatch):
    """A mid-run chunk whose round-1 receiver list goes near-full must
    leave compact_all for a cheaper packing without disturbing parity."""
    from repro.core import sharded_engine as se

    n = 64
    X, y, Xt, yt = toy(n)
    cfg = with_failure_scenario(cfg_for(n), "sparse-d0.8-o0.1")
    kw = dict(cycles=24, eval_every=8, seed=5)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)

    orig = se._HostRouter.route_chunk
    calls = []

    def fake(self, dsts, arrivals, online_rows, clock0, k_rounds,
             per_cycle_stats=False):
        src_slot, stats, multi, recv = orig(self, dsts, arrivals,
                                            online_rows, clock0, k_rounds,
                                            per_cycle_stats=per_cycle_stats)
        if len(calls) == 1:           # middle chunk: claim full receiver set
            full = [np.arange(self.n, dtype=np.int32)] * len(recv)
            multi, recv = full, full
        calls.append(0)
        return src_slot, stats, multi, recv

    monkeypatch.setattr(se._HostRouter, "route_chunk", fake)
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded", **kw)
    assert_bitwise(ref, sh)
    modes = sh.compaction["chunk_modes"]
    assert modes["dense"] == 1                 # the forced chunk fell back
    assert modes["compact_all"] >= 1           # the sparse chunks did not


def test_sparse_scenario_prefers_compact_all():
    """In the Fig. 5-7 sparse regimes the occupancy-based cost model must
    actually pick the delivery-proportional packing."""
    n = 256
    X, y, Xt, yt = toy(n)
    cfg = with_failure_scenario(cfg_for(n), "sparse-d0.8-o0.1")
    sh = run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                        cycles=30, eval_every=10, seed=2)
    modes = sh.compaction["chunk_modes"]
    assert modes["compact_all"] == len(sh.cycles), sh.compaction
    assert sh.compaction["round1_occupancy_max"] <= 0.25


def test_pack_compact_all_covers_every_round():
    """The fully compacted tables must encode exactly the dense table:
    every receive at the receiver's packed position, padding inert."""
    rng = np.random.default_rng(0)
    T, K, n = 3, 4, 32
    src_slot = np.full((T, K, n), -1, np.int32)
    for t in range(T):
        nodes = rng.choice(n, size=10, replace=False)
        for j, node in enumerate(nodes):
            depth = 1 + (j % K)                # winner rounds fill in order
            src_slot[t, :depth, node] = rng.integers(0, 64, size=depth)
    recv = [np.flatnonzero(src_slot[t, 0] >= 0).astype(np.int32)
            for t in range(T)]
    t_w, r_w, dst_w = (a.astype(np.int32) for a in np.nonzero(src_slot >= 0))
    win = (t_w, r_w, dst_w, src_slot[t_w, r_w, dst_w])
    width = max(r.size for r in recv) + 3      # over-wide: padding inert
    ridx, rslot = pack_compact_all(win, recv, T, K, n, width)
    assert ridx.shape == (T, width) and rslot.shape == (T, K, width)
    for t in range(T):
        r = recv[t]
        assert np.array_equal(ridx[t, :r.size], r)
        assert np.all(ridx[t, r.size:] == -1)
        assert np.all(rslot[t, :, r.size:] == -1)
        for k in range(K):
            assert np.array_equal(rslot[t, k, :r.size], src_slot[t, k, r])


def test_shard_aligned_packing():
    """Per-shard packing: shard s's receivers land in its own column block,
    so under a node mesh each device's table slice references only its own
    nodes; the per-shard width is the max shard population."""
    n, shards = 32, 4                          # shard size 8
    lists = [np.array([0, 1, 9, 30, 31], np.int32),
             np.array([], np.int32),
             np.array([8, 15, 16, 17, 18], np.int32)]
    w = shard_list_width(lists, n, shards)
    assert w == 3                              # shard 2 of cycle 2 has 3
    packed = _pack_index_lists(lists, n, w, shards)
    assert packed.shape == (3, shards * w)
    for t, r in enumerate(lists):
        got = packed[t][packed[t] >= 0]
        assert np.array_equal(np.sort(got), r)
        for s in range(shards):
            seg = packed[t, s * w:(s + 1) * w]
            seg = seg[seg >= 0]
            assert np.all((seg >= s * 8) & (seg < (s + 1) * 8))
    # shards=1 degenerates to the longest list
    assert shard_list_width(lists, n, 1) == 5


def test_failure_scenarios_registry():
    assert set(FAILURE_SCENARIOS) >= {"clean", "extreme", "sparse-d0.8-o0.1"}
    cfg = with_failure_scenario(cfg_for(16), "sparse-d0.5-o0.3")
    assert (cfg.drop_prob, cfg.delay_max_cycles, cfg.online_fraction) \
        == (0.5, 10, 0.3)
    base = cfg_for(16)
    assert with_failure_scenario(base, "clean") == dataclasses.replace(
        base, drop_prob=0.0, delay_max_cycles=1, online_fraction=1.0)
    with pytest.raises(ValueError, match="unknown failure scenario"):
        with_failure_scenario(base, "bogus")
