"""Theorem 1 empirical validation (the MU convergence bound)."""
import numpy as np
import pytest

from repro.core.theory import mu_chain_regret, solve_w_star, svm_objective
from repro.data.synthetic import make_linear_dataset


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 150, 12, noise=0.02, separation=3.0)
    return X, y


def test_w_star_is_near_optimal(problem):
    X, y = problem
    lam = 0.01
    w_star = solve_w_star(X, y, lam)
    f_star = float(svm_objective(w_star, X, y, lam))
    rng = np.random.default_rng(1)
    for _ in range(20):
        w = w_star + 0.05 * rng.normal(size=w_star.shape)
        assert float(svm_objective(np.asarray(w, np.float32), X, y, lam)) \
            >= f_star - 1e-4


def test_theorem1_bound_holds(problem):
    X, y = problem
    tr = mu_chain_regret(X, y, lam=0.01, steps=250, seed=0)
    assert tr.holds, "Theorem 1 bound violated"
    # the bound decays ~ log t / t; the empirical average regret must track it
    assert tr.avg_regret[-1] <= tr.bound[-1]
    assert tr.bound[-1] < tr.bound[9]


def test_average_regret_decreases(problem):
    X, y = problem
    tr = mu_chain_regret(X, y, lam=0.01, steps=300, seed=1)
    assert tr.avg_regret[-1] < tr.avg_regret[19]
