"""Dependency-free coverage of Section V's equivalences (Eq. 7 / Eq. 8).

``tests/test_merge_properties.py`` explores the same claims with hypothesis;
that module skips entirely when hypothesis is not installed, so the seeded,
parametrized checks here keep the paper's core mathematical equivalences
covered on a bare environment.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.learners import LinearModel, adaline_update, pegasos_update
from repro.core.merge import create_model_mu, create_model_um, merge


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("k,d", [(2, 4), (7, 5)])
def test_eq7_weighted_vote_equals_sign_of_average(seed, k, d):
    """Eq. (7): voting with weights |<w,x>| == sign of the averaged score."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(k, d)).astype(np.float32)
    x = rng.normal(size=(d,)).astype(np.float32)
    scores = W @ x
    weighted = np.mean(np.abs(scores) * np.sign(scores))
    mean_score = np.mean(scores)
    assert np.sign(weighted) == np.sign(mean_score) or np.isclose(
        mean_score, 0.0, atol=1e-6)
    # and the averaged *model* produces exactly that mean score (Eq. 6)
    np.testing.assert_allclose(np.mean(W, axis=0) @ x, mean_score,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("y", [-1.0, 1.0])
def test_eq8_adaline_update_commutes_with_averaging(seed, y):
    """Eq. (8): Adaline's linear activation makes update/merge commute."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(6, 4)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    eta = float(rng.uniform(0.01, 0.5))
    upd = [adaline_update(LinearModel(jnp.asarray(w), jnp.int32(0)), x, y, eta).w
           for w in W]
    avg_of_upd = np.mean(np.stack([np.asarray(u) for u in upd]), axis=0)
    wbar = LinearModel(jnp.asarray(np.mean(W, axis=0)), jnp.int32(0))
    upd_of_avg = np.asarray(adaline_update(wbar, x, y, eta).w)
    np.testing.assert_allclose(avg_of_upd, upd_of_avg, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("seed", range(8))
def test_pegasos_um_equals_mu_when_same_hinge_branch(seed):
    """Section V-B: Pegasos UM == MU iff all ancestors share the hinge branch."""
    rng = np.random.default_rng(seed)
    w1 = rng.normal(size=(4,)).astype(np.float32)
    w2 = rng.normal(size=(4,)).astype(np.float32)
    x = rng.normal(size=(4,)).astype(np.float32)
    y = float(rng.choice([-1.0, 1.0]))
    t = int(rng.integers(1, 20))
    lam = 0.1
    m1 = LinearModel(jnp.asarray(w1), jnp.int32(t))
    m2 = LinearModel(jnp.asarray(w2), jnp.int32(t))
    xs = jnp.asarray(x)
    upd = lambda m, xx, yy: pegasos_update(m, xx, yy, lam)
    mu = create_model_mu(upd, m1, m2, xs, y)
    um = create_model_um(upd, m1, m2, xs, y)
    viol1 = float(y * (w1 @ x)) < 1.0
    viol2 = float(y * (w2 @ x)) < 1.0
    violbar = float(y * (((w1 + w2) / 2.0) @ x)) < 1.0
    if viol1 == viol2 == violbar:
        np.testing.assert_allclose(np.asarray(mu.w), np.asarray(um.w),
                                   rtol=1e-4, atol=1e-5)


def test_merge_semantics():
    m = merge(LinearModel(jnp.asarray([1.0, 3.0]), jnp.int32(2)),
              LinearModel(jnp.asarray([3.0, -1.0]), jnp.int32(7)))
    np.testing.assert_allclose(np.asarray(m.w), [2.0, 1.0])
    assert int(m.t) == 7
