"""Adversarial-fault subsystem: registry properties, corruption/defense
unit semantics, the k_fault no-consumption contract (fault-free runs stay
bitwise identical), and the bitwise cross-engine parity of every fault
model across the reference engine, all three sharded packings and the
Pallas interpret path — with the defense screen active.

The fault contract (``repro.core.faults``): a seed-chosen Byzantine subset
corrupts every model it sends (model-kind faults rewrite the transmitted
weights before the wire encode; the wire-kind ``bitflip`` flips one bit of
the encoded payload after it), and the receive path may screen each
incoming payload per merge round against the receiver's current lastModel.
Fault draws ride ``fault_key = fold_in(cycle_key, FAULT_FOLD)`` — derived,
never consumed from the pinned ``split(key, 4)`` sequence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import (FAILURE_SCENARIOS,
                                         GossipLinearConfig,
                                         with_failure_scenario)
from repro.core.cache import ModelCache, cache_oldest, voted_predict
from repro.core.faults import (AMPLIFY_GAMMA, DEFENSES, FAULT_MODELS,
                               NORM_CLIP_FLOOR, NORM_CLIP_MULT,
                               SIGN_FLIP_GAMMA, apply_defense,
                               bitflip_payload, byzantine_mask,
                               check_defense, corrupt_model, fault_key,
                               get_fault)
from repro.core.simulation import run_simulation
from repro.data.synthetic import make_linear_dataset

MODEL_FAULTS = [n for n, f in FAULT_MODELS.items() if f.kind == "model"]


@pytest.fixture(scope="module", autouse=True)
def _release_compile_caches():
    """The parity-matrix tests below compile a few hundred distinct engine
    signatures (fault x codec x packing); drop them at module teardown so
    the single-process tier-1 run stays within the box's native compile
    budget (the accumulated executables crash XLA's compiler late in the
    suite otherwise)."""
    yield
    jax.clear_caches()


def small_cfg(n_nodes=128, **kw):
    base = dict(name="toy", dim=16, n_nodes=n_nodes, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


def toy(n=128, d=16, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_linear_dataset(rng, n + 64, d, noise=0.05, separation=3.0)
    return X[:n], y[:n], X[n:], y[n:]


# ---------------------------------------------------------------------------
# registry + config plumbing
# ---------------------------------------------------------------------------


def test_registry_names_and_kinds():
    assert set(FAULT_MODELS) == {"sign_flip", "amplify", "zero",
                                 "random_payload", "stale_replay", "bitflip"}
    kinds = {n: f.kind for n, f in FAULT_MODELS.items()}
    assert kinds == {"sign_flip": "model", "amplify": "model",
                     "zero": "model", "random_payload": "model",
                     "stale_replay": "model", "bitflip": "wire"}
    assert get_fault(None) is None and get_fault("") is None
    with pytest.raises(ValueError, match="unknown fault model"):
        get_fault("gaussian")
    assert DEFENSES == ("none", "norm_clip", "cosine_gate")
    assert check_defense("norm_clip") == "norm_clip"
    with pytest.raises(ValueError, match="unknown defense"):
        check_defense("median")


def test_config_fails_fast_on_bad_fault_knobs():
    X, y, Xt, yt = toy(n=32)
    kw = dict(cycles=2, eval_every=2, seed=0)
    with pytest.raises(ValueError, match="unknown fault model"):
        run_simulation(small_cfg(n_nodes=32, fault_model="nope",
                                 byzantine_frac=0.1), X, y, Xt, yt, **kw)
    with pytest.raises(ValueError, match="unknown defense"):
        run_simulation(small_cfg(n_nodes=32, defense="median"),
                       X, y, Xt, yt, **kw)
    with pytest.raises(ValueError, match="byzantine_frac"):
        run_simulation(small_cfg(n_nodes=32, fault_model="zero",
                                 byzantine_frac=1.5), X, y, Xt, yt, **kw)


def test_with_failure_scenario_validates_override_keys(monkeypatch):
    """Regression: a typo'd key in a scenario dict used to surface only as
    dataclasses.replace's generic TypeError — now it fails loudly naming
    the offending keys before any replace happens."""
    cfg = small_cfg()
    with pytest.raises(ValueError, match="unknown failure scenario"):
        with_failure_scenario(cfg, "extreme-typo")
    monkeypatch.setitem(FAILURE_SCENARIOS, "bad-scenario",
                        dict(drop_prob=0.5, drop_probz=0.9))
    with pytest.raises(ValueError, match="drop_probz"):
        with_failure_scenario(cfg, "bad-scenario")
    # every registered scenario applies cleanly (the validation is not
    # rejecting legitimate keys)
    for name in ("clean", "extreme", "sparse-d0.8-o0.1"):
        assert with_failure_scenario(cfg, name).name == cfg.name


def test_byzantine_mask_properties():
    m = byzantine_mask(seed=5, n=1000, frac=0.1)
    assert m.dtype == bool and m.shape == (1000,) and m.sum() == 100
    np.testing.assert_array_equal(m, byzantine_mask(5, 1000, 0.1))
    assert not np.array_equal(m, byzantine_mask(6, 1000, 0.1))
    assert byzantine_mask(5, 1000, 0.0).sum() == 0
    assert byzantine_mask(5, 64, 1.0).sum() == 64
    with pytest.raises(ValueError, match="byzantine_frac"):
        byzantine_mask(5, 10, -0.1)


def test_fault_key_derives_without_consuming():
    """The k_fault contract: fold_in gives a deterministic side key and
    leaves the parent key's split sequence untouched."""
    key = jax.random.key(42)
    before = jax.random.key_data(jax.random.split(key, 4))
    kf = fault_key(key)
    assert not np.array_equal(jax.random.key_data(kf),
                              jax.random.key_data(key))
    np.testing.assert_array_equal(
        jax.random.key_data(kf), jax.random.key_data(fault_key(key)))
    after = jax.random.key_data(jax.random.split(key, 4))
    np.testing.assert_array_equal(before, after)


# ---------------------------------------------------------------------------
# corruption semantics (unit level)
# ---------------------------------------------------------------------------


def test_corrupt_model_touches_only_byzantine_rows():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
    t = jnp.arange(8, dtype=jnp.int32) + 10
    byz = jnp.asarray([True, False] * 4)
    key = fault_key(jax.random.key(0))
    old_w, old_t = 0.5 * w, t - 7
    for name in MODEL_FAULTS:
        cw, ct = corrupt_model(get_fault(name), byz, key, w, t,
                               old_w=old_w, old_t=old_t)
        np.testing.assert_array_equal(np.asarray(cw)[1::2],
                                      np.asarray(w)[1::2])
        np.testing.assert_array_equal(np.asarray(ct)[1::2],
                                      np.asarray(t)[1::2])
    cw, _ = corrupt_model(get_fault("sign_flip"), byz, key, w, t)
    np.testing.assert_allclose(np.asarray(cw)[0],
                               -SIGN_FLIP_GAMMA * np.asarray(w)[0])
    cw, _ = corrupt_model(get_fault("amplify"), byz, key, w, t)
    np.testing.assert_allclose(np.asarray(cw)[0],
                               AMPLIFY_GAMMA * np.asarray(w)[0])
    cw, _ = corrupt_model(get_fault("zero"), byz, key, w, t)
    assert np.all(np.asarray(cw)[0] == 0.0)
    cw, ct = corrupt_model(get_fault("stale_replay"), byz, key, w, t,
                           old_w=old_w, old_t=old_t)
    np.testing.assert_array_equal(np.asarray(cw)[0], np.asarray(old_w)[0])
    assert int(ct[0]) == int(old_t[0])
    cw, _ = corrupt_model(get_fault("random_payload"), byz, key, w, t)
    scale = np.abs(np.asarray(w)[0]).max()
    assert np.all(np.abs(np.asarray(cw)[0]) <= scale + 1e-6)
    with pytest.raises(ValueError, match="not a model-kind"):
        corrupt_model(get_fault("bitflip"), byz, key, w, t)


def test_corrupt_model_subset_matches_dense_gather():
    """compact_all parity mechanism: random_payload on a sender subset
    regenerates bitwise the dense draw at those global rows."""
    n, d = 32, 9
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    t = jnp.zeros((n,), jnp.int32)
    byz = jnp.asarray(rng.random(n) < 0.5)
    key = fault_key(jax.random.key(7))
    fault = get_fault("random_payload")
    dense, _ = corrupt_model(fault, byz, key, w, t)
    rows = jnp.asarray([3, 0, 31, 17, 8])
    sub, _ = corrupt_model(fault, byz[rows], key, w[rows], t[rows],
                           rows=rows, n_total=n)
    np.testing.assert_array_equal(np.asarray(dense)[np.asarray(rows)],
                                  np.asarray(sub))


@pytest.mark.parametrize("dtype,cols", [(jnp.float32, 6), (jnp.uint8, 5),
                                        (jnp.float16, 4)])
def test_bitflip_flips_exactly_one_bit(dtype, cols):
    rng = np.random.default_rng(2)
    if dtype == jnp.uint8:
        payload = jnp.asarray(rng.integers(0, 255, size=(10, cols)), dtype)
    else:
        payload = jnp.asarray(rng.normal(size=(10, cols)), dtype)
    byz = jnp.asarray(rng.random(10) < 0.5)
    out = bitflip_payload(byz, fault_key(jax.random.key(3)), payload)
    assert out.dtype == payload.dtype
    itemsize = np.dtype(payload.dtype).itemsize
    uint = {1: np.uint8, 2: np.uint16, 4: np.uint32}[itemsize]
    a = np.asarray(jax.lax.bitcast_convert_type(payload, uint))
    b = np.asarray(jax.lax.bitcast_convert_type(out, uint))
    diffbits = np.array([bin(int(x)).count("1")
                         for x in (a ^ b).astype(np.uint64).ravel()]
                        ).reshape(a.shape).sum(axis=-1)
    np.testing.assert_array_equal(diffbits, np.asarray(byz).astype(int))


def test_bitflip_subset_matches_dense_gather():
    n, cols = 24, 5
    rng = np.random.default_rng(3)
    payload = jnp.asarray(rng.integers(0, 255, size=(n, cols)), jnp.uint8)
    byz = jnp.asarray(rng.random(n) < 0.6)
    key = fault_key(jax.random.key(11))
    dense = bitflip_payload(byz, key, payload)
    rows = jnp.asarray([23, 1, 12, 0, 7, 19])
    sub = bitflip_payload(byz[rows], key, payload[rows], rows=rows,
                          n_total=n)
    np.testing.assert_array_equal(np.asarray(dense)[np.asarray(rows)],
                                  np.asarray(sub))


def test_cache_oldest_picks_slot_ptr_minus_count():
    w = jnp.arange(2 * 3 * 2, dtype=jnp.float32).reshape(2, 3, 2)
    cache = ModelCache(w, jnp.asarray([[5, 6, 7], [8, 9, 10]], jnp.int32),
                       ptr=jnp.asarray([4, 1], jnp.int32),
                       count=jnp.asarray([3, 1], jnp.int32))
    ow, ot = cache_oldest(cache)
    # node 0: slot (4-3)%3 = 1; node 1: slot (1-1)%3 = 0
    np.testing.assert_array_equal(np.asarray(ow),
                                  np.asarray(w)[[0, 1], [1, 0]])
    np.testing.assert_array_equal(np.asarray(ot), [6, 8])


# ---------------------------------------------------------------------------
# defense semantics (unit level)
# ---------------------------------------------------------------------------


def test_defense_none_is_structural_noop():
    w = jnp.full((4, 3), 1e6, jnp.float32)
    valid = jnp.asarray([True, True, False, True])
    mw, vm, gated, clipped = apply_defense("none", w, valid, jnp.zeros_like(w))
    assert mw is w and np.array_equal(np.asarray(vm), np.asarray(valid))
    assert not gated.any() and not clipped.any()


def test_norm_clip_bounds_and_preserves():
    rng = np.random.default_rng(4)
    recv = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    msg = jnp.concatenate([recv[:3] * 100.0, recv[3:] * 0.5])
    valid = jnp.ones(6, bool)
    mw, vm, gated, clipped = apply_defense("norm_clip", msg, valid, recv)
    assert np.array_equal(np.asarray(clipped), [True] * 3 + [False] * 3)
    assert not gated.any() and vm.all()
    thr = np.maximum(NORM_CLIP_MULT * np.linalg.norm(np.asarray(recv),
                                                     axis=-1),
                     NORM_CLIP_FLOOR)
    norms = np.linalg.norm(np.asarray(mw), axis=-1)
    np.testing.assert_allclose(norms[:3], thr[:3], rtol=1e-5)
    # in-bound messages pass through bitwise untouched
    np.testing.assert_array_equal(np.asarray(mw)[3:], np.asarray(msg)[3:])


def test_norm_clip_floor_lets_early_messages_flow():
    """Zero-init receivers (||recv|| = 0) must still accept honest small
    messages — the FLOOR keeps the warm-up phase alive."""
    msg = jnp.full((2, 4), 0.3, jnp.float32)
    mw, vm, gated, clipped = apply_defense(
        "norm_clip", msg, jnp.ones(2, bool), jnp.zeros_like(msg))
    assert vm.all() and not clipped.any()
    np.testing.assert_array_equal(np.asarray(mw), np.asarray(msg))


def test_defenses_gate_non_finite_payloads():
    msg = jnp.asarray([[1.0, jnp.nan], [jnp.inf, 0.0], [1.0, 1.0]],
                      jnp.float32)
    recv = jnp.ones_like(msg)
    valid = jnp.ones(3, bool)
    for defense in ("norm_clip", "cosine_gate"):
        _, vm, gated, _ = apply_defense(defense, msg, valid, recv)
        assert np.array_equal(np.asarray(vm)[:2], [False, False]), defense
        assert np.asarray(vm)[2] and np.array_equal(
            np.asarray(gated)[:2], [True, True]), defense


def test_cosine_gate_rejects_anti_aligned_only():
    recv = jnp.asarray(np.random.default_rng(5).normal(size=(3, 16)),
                       jnp.float32)
    msg = jnp.stack([-4.0 * recv[0], recv[1], recv[2]
                     + 0.01 * jnp.ones(16)])
    _, vm, gated, clipped = apply_defense("cosine_gate", msg,
                                          jnp.ones(3, bool), recv)
    assert np.array_equal(np.asarray(vm), [False, True, True])
    assert np.array_equal(np.asarray(gated), [True, False, False])
    assert not clipped.any()


def test_defense_real_mask_ignores_pad_lanes():
    """The Pallas padded-width contract: garbage beyond d_real must not
    change any defense decision or rescale."""
    msg = jnp.asarray([[3.0, 4.0, 1e30, jnp.nan]], jnp.float32)
    recv = jnp.asarray([[1.0, 0.0, 1e30, 7.0]], jnp.float32)
    real = jnp.asarray([[True, True, False, False]])
    valid = jnp.ones(1, bool)
    got = apply_defense("norm_clip", msg, valid, recv, real=real)
    exp = apply_defense("norm_clip", msg[:, :2], valid, recv[:, :2])
    assert bool(got[1][0]) == bool(exp[1][0])
    assert bool(got[3][0]) == bool(exp[3][0])
    _, vm_g, _, _ = apply_defense("cosine_gate", msg, valid, recv, real=real)
    _, vm_e, _, _ = apply_defense("cosine_gate", msg[:, :2], valid,
                                  recv[:, :2])
    assert bool(vm_g[0]) == bool(vm_e[0])


# ---------------------------------------------------------------------------
# fault-free bitwise identity (the k_fault no-consumption contract, end
# to end) + cross-engine parity for every fault model
# ---------------------------------------------------------------------------


ENGINES = dict(
    dense=dict(engine="sharded", compact_mode="dense"),
    compact=dict(engine="sharded", compact_mode="compact"),
    compact_all=dict(engine="sharded", compact_mode="compact_all"),
    pallas=dict(engine="sharded", use_pallas=True, interpret=True),
)


@pytest.mark.parametrize("wire", [None, "int8_sr", "ternary_ef"])
def test_fault_machinery_at_zero_frac_is_bitwise_invisible(wire):
    """Pin the acceptance bar: enabling the fault code path with an empty
    Byzantine set must reproduce the fault-free run BIT FOR BIT on every
    engine path — i.e. fault draws never consume from the pinned
    ``split(key, 4)`` sequence and all injection sites are no-ops."""
    X, y, Xt, yt = toy()
    kw = dict(cycles=20, eval_every=10, seed=3)
    base = small_cfg(drop_prob=0.5, delay_max_cycles=10,
                     online_fraction=0.9, wire_dtype=wire)
    armed = dataclasses.replace(base, fault_model="sign_flip",
                                byzantine_frac=0.0, defense="none")
    for name, ekw in [("ref", {})] + list(ENGINES.items()):
        off = run_simulation(base, X, y, Xt, yt, **kw, **ekw)
        on = run_simulation(armed, X, y, Xt, yt, **kw, **ekw)
        assert off.err_fresh == on.err_fresh, (wire, name)
        assert off.err_voted == on.err_voted, (wire, name)
        assert off.ef_residual_norm == on.ef_residual_norm, (wire, name)
        assert on.fault_stats == {"corrupted": 0, "gated": 0, "clipped": 0}
        assert off.fault_stats == on.fault_stats


@pytest.mark.parametrize("wire", [None, "int8", "int4"])
@pytest.mark.parametrize("fault", sorted(FAULT_MODELS))
def test_fault_bitwise_parity_all_engines(fault, wire):
    """Acceptance bar for every fault model: for a fixed seed the error
    curves AND the fault counters agree bitwise across the reference
    engine, all three sharded packings and the Pallas interpret path —
    with the norm_clip screen active (the defended merge is part of the
    parity contract, not an afterthought)."""
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9,
                    wire_dtype=wire, fault_model=fault, byzantine_frac=0.1,
                    defense="norm_clip")
    kw = dict(cycles=20, eval_every=10, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    assert ref.fault_stats["corrupted"] > 0
    for name, ekw in ENGINES.items():
        r = run_simulation(cfg, X, y, Xt, yt, **kw, **ekw)
        assert ref.err_fresh == r.err_fresh, (fault, wire, name)
        assert ref.err_voted == r.err_voted, (fault, wire, name)
        assert ref.ef_residual_norm == r.ef_residual_norm, (fault, wire,
                                                           name)
        assert ref.fault_stats == r.fault_stats, (fault, wire, name)


def test_cosine_gate_parity_and_counters():
    X, y, Xt, yt = toy()
    cfg = small_cfg(drop_prob=0.5, delay_max_cycles=10, online_fraction=0.9,
                    fault_model="sign_flip", byzantine_frac=0.2,
                    defense="cosine_gate")
    kw = dict(cycles=20, eval_every=10, seed=3)
    ref = run_simulation(cfg, X, y, Xt, yt, **kw)
    assert ref.fault_stats["gated"] > 0 and ref.fault_stats["clipped"] == 0
    for name, ekw in ENGINES.items():
        r = run_simulation(cfg, X, y, Xt, yt, **kw, **ekw)
        assert ref.err_fresh == r.err_fresh, name
        assert ref.fault_stats == r.fault_stats, name


def test_faulty_run_is_reproducible():
    X, y, Xt, yt = toy(n=64)
    cfg = small_cfg(n_nodes=64, drop_prob=0.3, delay_max_cycles=4,
                    fault_model="random_payload", byzantine_frac=0.25,
                    defense="norm_clip")
    kw = dict(cycles=16, eval_every=8, seed=9, engine="sharded")
    a = run_simulation(cfg, X, y, Xt, yt, **kw)
    b = run_simulation(cfg, X, y, Xt, yt, **kw)
    assert a.err_fresh == b.err_fresh and a.fault_stats == b.fault_stats


# ---------------------------------------------------------------------------
# the defense actually defends (poisoned-cache property)
# ---------------------------------------------------------------------------


def test_norm_clip_bounds_poisoned_cache_votes():
    """VOTEDPREDICT poisoned-cache property, at the real defense site
    (``apply_receives``): one merge round carries a huge anti-aligned
    payload. Undefended, that payload dominates every later merge — the
    cache fills with sign-reversed models and the majority vote flips.
    With the per-round norm_clip screen the poison enters norm-bounded,
    the honest rounds re-dominate, and the voted predictions track the
    poison-free chain far more closely."""
    from repro.core.cache import init_cache
    from repro.core.learners import make_update
    from repro.core.simulation import apply_receives

    rng = np.random.default_rng(6)
    n, d, m, K = 8, 8, 64, 4
    w_star = rng.normal(size=d).astype(np.float32)
    X = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.sign(x @ jnp.asarray(w_star))
    last_w = jnp.asarray(w_star + 0.05 * rng.normal(size=(n, d)),
                         jnp.float32)
    last_t = jnp.full((n,), 20, jnp.int32)
    honest = jnp.asarray(
        w_star + 0.05 * rng.normal(size=(K, n, d)), jnp.float32)
    dirty = honest.at[1].set(-200.0 * honest[1])   # round-2 poison
    msg_t = jnp.full((K, n), 20, jnp.int32)
    valid = jnp.ones((K, n), bool)
    upd = make_update("pegasos", lam=0.01)

    def chain(msg_w, defense):
        lw, lt, cache, gated, clipped = apply_receives(
            last_w, last_t, init_cache(n, K, d), msg_w, msg_t, valid, x, y,
            variant="mu", update=upd, defense=defense)
        return np.asarray(voted_predict(cache, X)), clipped

    votes_clean, _ = chain(honest, "none")
    votes_dirty, cl_none = chain(dirty, "none")
    votes_defended, cl_clip = chain(dirty, "norm_clip")
    assert not np.asarray(cl_none).any() and np.asarray(cl_clip).any()
    agree_dirty = (votes_dirty == votes_clean).mean()
    agree_defended = (votes_defended == votes_clean).mean()
    # voting itself absorbs part of the attack (the poison touches 2 of K
    # cache slots: its own round's merge and the next round's lastModel),
    # so the defended gain is a margin, not a rescue from zero — measured
    # 0.78 vs 0.54 agreement on this fixed seed, pinned with slack
    assert agree_defended > agree_dirty + 0.1, (agree_dirty, agree_defended)


def test_norm_clip_recovers_voted_error_end_to_end():
    """The acceptance property at toy scale (the N=10^4 version lives in
    BENCH_robustness.json): under a 30% sign-flip attack on the extreme
    scenario, the undefended voted error collapses while norm_clip holds
    it near the fault-free level."""
    X, y, Xt, yt = toy()
    kw = dict(cycles=40, eval_every=20, seed=3)
    base = small_cfg(drop_prob=0.5, delay_max_cycles=10,
                     online_fraction=0.9, fault_model="sign_flip",
                     byzantine_frac=0.3)
    none = run_simulation(base, X, y, Xt, yt, **kw)
    clip = run_simulation(dataclasses.replace(base, defense="norm_clip"),
                          X, y, Xt, yt, **kw)
    # measured on this seed: voted 0.334 undefended vs 0.153 defended
    assert clip.err_voted[-1] + 0.1 < none.err_voted[-1], (
        none.err_voted[-1], clip.err_voted[-1])
    assert clip.err_fresh[-1] + 0.1 < none.err_fresh[-1]


def test_fault_stats_scale_with_byzantine_frac():
    X, y, Xt, yt = toy(n=64)
    kw = dict(cycles=10, eval_every=10, seed=1, engine="sharded")
    lo = run_simulation(small_cfg(n_nodes=64, fault_model="amplify",
                                  byzantine_frac=0.1, defense="norm_clip"),
                        X, y, Xt, yt, **kw)
    hi = run_simulation(small_cfg(n_nodes=64, fault_model="amplify",
                                  byzantine_frac=0.4, defense="norm_clip"),
                        X, y, Xt, yt, **kw)
    assert hi.fault_stats["corrupted"] > lo.fault_stats["corrupted"] > 0
    assert hi.fault_stats["clipped"] >= lo.fault_stats["clipped"] > 0
