"""Checkpoint round-trip + data-pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLMDataset
from repro.data.synthetic import make_linear_dataset, paper_dataset


def test_checkpoint_roundtrip_with_bf16(tmp_path):
    state = {
        "params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                   "b": jnp.float32(1.5)},
        "opt": {"m": jnp.ones((3, 4), jnp.float32)},
        "step": jnp.int32(7),
    }
    save_checkpoint(tmp_path, 7, state)
    assert latest_step(tmp_path) == 7
    got = restore_checkpoint(tmp_path, 7, state)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_multiple_steps(tmp_path):
    s = {"w": jnp.zeros(3)}
    save_checkpoint(tmp_path, 10, s)
    save_checkpoint(tmp_path, 20, s)
    assert latest_step(tmp_path) == 20


def test_linear_dataset_class_ratio_and_floor():
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 4000, 20, noise=0.05, separation=3.0,
                               class_ratio=(3, 1))
    pos = (y > 0).mean()
    assert 0.65 < pos < 0.85   # ~0.75 requested (minus flips)
    assert X.dtype == np.float32 and X.shape == (4000, 20)


def test_paper_datasets_match_table1_geometry():
    for name, (n_tr, n_te, d) in {
        "spambase": (4140, 461, 57),
        "malicious-urls": (10_000, 2000, 10),
    }.items():
        X, y, Xt, yt, cfg = paper_dataset(name, seed=0)
        assert X.shape == (n_tr, d) and Xt.shape == (n_te, d)
        assert set(np.unique(y)) <= {-1.0, 1.0}


def test_lm_dataset_deterministic_and_shaped():
    a = SyntheticLMDataset(vocab_size=256, seq_len=32, batch_size=4, seed=3)
    b = SyntheticLMDataset(vocab_size=256, seq_len=32, batch_size=4, seed=3)
    ba, bb = next(a), next(b)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    assert ba["tokens"].shape == (4, 32)
    # labels are tokens shifted left by one
    full_a = np.concatenate([ba["tokens"], ba["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], ba["labels"])
    assert ba["tokens"].max() < 256


def test_lm_dataset_has_learnable_structure():
    ds = SyntheticLMDataset(vocab_size=512, seq_len=128, batch_size=8, seed=0)
    b = next(ds)
    toks = b["tokens"]
    # copy-back spans mean repeated bigrams occur far above chance
    bigrams = toks[:, :-1] * 512 + toks[:, 1:]
    uniq = len(np.unique(bigrams)) / bigrams.size
    assert uniq < 0.9
