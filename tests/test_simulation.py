"""Protocol-simulator invariants + paper-claim system tests."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gossip_linear import GossipLinearConfig
from repro.core import cache as cache_mod
from repro.core.peer_sampling import (hypercube_partner, perfect_matching,
                                      uniform_peers)
from repro.core.simulation import churn_trace, init_state, run_simulation
from repro.data.synthetic import make_linear_dataset

import jax


def small_cfg(**kw):
    base = dict(name="toy", dim=16, n_nodes=64, n_test=64,
                class_ratio=(1, 1), lam=1e-3, variant="mu")
    base.update(kw)
    return GossipLinearConfig(**base)


@pytest.fixture(scope="module")
def toy_data():
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, 128, 16, noise=0.02, separation=3.0)
    return X[:64], y[:64], X[64:], y[64:]


def test_uniform_peers_never_self():
    for seed in range(5):
        dst = np.asarray(uniform_peers(jax.random.key(seed), 33))
        assert np.all(dst != np.arange(33))
        assert dst.min() >= 0 and dst.max() < 33


def test_perfect_matching_is_involution():
    dst = np.asarray(perfect_matching(jax.random.key(0), 40))
    assert np.all(dst[dst] == np.arange(40))
    assert np.all(dst != np.arange(40))


@pytest.mark.parametrize("n", [3, 7, 33])
def test_perfect_matching_odd_population(n):
    """Odd N: no perfect matching exists; exactly one node self-maps (idles)."""
    for seed in range(4):
        dst = np.asarray(perfect_matching(jax.random.key(seed), n))
        assert np.all(dst[dst] == np.arange(n))       # still an involution
        assert int((dst == np.arange(n)).sum()) == 1  # exactly one idle node


@pytest.mark.parametrize("n", [32, 33])
def test_run_simulation_matching_sampler_both_parities(n, toy_data):
    """Regression: sampler="matching" used to crash for odd N."""
    X, y, Xt, yt = toy_data
    res = run_simulation(small_cfg(n_nodes=n), X[:n], y[:n], Xt, yt,
                         cycles=10, eval_every=10, seed=0,
                         sampler="matching")
    assert len(res.err_fresh) == 1
    if n % 2 == 0:
        assert res.sent_total == n * 10        # every node sends every cycle
    else:
        assert res.sent_total == (n - 1) * 10  # the unpaired node idles


def test_hypercube_partner_mixes():
    n = 16
    seen = set()
    for s in range(4):
        p = hypercube_partner(s, n)
        assert np.all(p[p] == np.arange(n))
        seen.add(tuple(p))
    assert len(seen) == 4  # four distinct dimensions


def test_churn_trace_online_fraction():
    rng = np.random.default_rng(0)
    m = churn_trace(rng, 500, 400, 0.9)
    frac = m.mean()
    assert 0.84 < frac < 0.96


def test_churn_trace_v2_contract():
    """The vectorized v2 sampler: versioned, deterministic per generator
    state, correct shape/dtype, and exact short-circuits at the edges."""
    from repro.core.simulation import CHURN_TRACE_VERSION
    assert CHURN_TRACE_VERSION == 2
    a = churn_trace(np.random.default_rng(5), 300, 120, 0.8)
    b = churn_trace(np.random.default_rng(5), 300, 120, 0.8)
    assert a.shape == (120, 300) and a.dtype == np.bool_
    assert np.array_equal(a, b)
    assert churn_trace(np.random.default_rng(0), 7, 4, 1.0).all()
    assert churn_trace(np.random.default_rng(0), 7, 0, 0.5).shape == (0, 7)


def test_churn_trace_sessions_alternate():
    """Lognormal sessions are >= 1 cycle, so single-cycle flickering exists
    but a node is never 'offline' for zero cycles — each maximal run in the
    trace has length >= 1 trivially; the real invariant worth pinning is the
    stationary fraction at a second operating point."""
    rng = np.random.default_rng(2)
    m = churn_trace(rng, 400, 600, 0.5, mean_online=20.0)
    assert 0.40 < m.mean() < 0.60


def test_cache_ring_buffer():
    c = cache_mod.init_cache(2, 3, 4)
    for i in range(5):
        w = jnp.full((2, 4), float(i + 1))
        c = cache_mod.cache_add(c, jnp.array([True, i % 2 == 0]),
                                w, jnp.full((2,), i + 1, jnp.int32))
    w, t = cache_mod.freshest(c)
    assert float(w[0, 0]) == 5.0
    assert int(c.count[0]) == 3  # capped at cache size


def test_cache_wraparound_uses_only_recent_models():
    """Past the wrap point, freshest/voted_predict see the C most recent
    models only — stale pre-wrap entries must not influence the vote."""
    C, d = 3, 1
    c = cache_mod.init_cache(1, C, d)
    # 2C+1 adds: first four vote +1, last three vote -1. If any stale model
    # survived the wrap, the -1 majority below would flip.
    signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0]
    for i, s in enumerate(signs):
        c = cache_mod.cache_add(c, jnp.array([True]),
                                jnp.full((1, d), s),
                                jnp.full((1,), i + 1, jnp.int32))
    w, t = cache_mod.freshest(c)
    assert float(w[0, 0]) == -1.0 and int(t[0]) == len(signs)
    assert int(c.count[0]) == C          # count clamps at C past the wrap
    X = jnp.ones((1, d))
    voted = cache_mod.voted_predict(c, X)
    assert float(voted[0, 0]) == -1.0    # majority over the last C == -1
    # ring contents are exactly the last C models, in some rotation
    assert sorted(np.asarray(c.t[0]).tolist()) == [5, 6, 7]


def test_voted_predict_count_clamp_partial_cache():
    """count < C: the vote divides by count and ignores unwritten slots."""
    C, d = 4, 1
    c = cache_mod.init_cache(1, C, d)     # slot 0: the zero init model
    c = cache_mod.cache_add(c, jnp.array([True]), jnp.full((1, d), -1.0),
                            jnp.ones((1,), jnp.int32))
    assert int(c.count[0]) == 2
    X = jnp.ones((1, d))
    # votes: zero model scores 0 -> +1; -1 model -> -1. p_ratio = 1/2 -> +1
    # (sign convention: ties go positive); unwritten slots would make it 3/4.
    assert float(cache_mod.voted_predict(c, X)[0, 0]) == 1.0
    c = cache_mod.cache_add(c, jnp.array([True]), jnp.full((1, d), -2.0),
                            jnp.full((1,), 2, jnp.int32))
    assert float(cache_mod.voted_predict(c, X)[0, 0]) == -1.0  # 1/3 < 1/2


def test_mu_converges_and_beats_rw(toy_data):
    X, y, Xt, yt = toy_data
    res_mu = run_simulation(small_cfg(variant="mu"), X, y, Xt, yt,
                            cycles=40, eval_every=40, seed=1)
    res_rw = run_simulation(small_cfg(variant="rw"), X, y, Xt, yt,
                            cycles=40, eval_every=40, seed=1)
    assert res_mu.err_fresh[-1] < res_rw.err_fresh[-1] + 0.02
    assert res_mu.err_fresh[-1] < 0.2


def test_voting_helps_rw(toy_data):
    """Fig. 3's claim: local voting significantly improves RW."""
    X, y, Xt, yt = toy_data
    res = run_simulation(small_cfg(variant="rw"), X, y, Xt, yt,
                         cycles=30, eval_every=30, seed=2)
    assert res.err_voted[-1] <= res.err_fresh[-1] + 0.02


def test_failure_robustness_still_converges(toy_data):
    """Fig. 1 lower row: extreme drop+delay slows but does not break MU."""
    X, y, Xt, yt = toy_data
    hard = small_cfg(variant="mu", drop_prob=0.5, delay_max_cycles=10,
                     online_fraction=0.9)
    res = run_simulation(hard, X, y, Xt, yt, cycles=80, eval_every=80, seed=3)
    assert res.err_fresh[-1] < 0.35  # converging despite 50% drop + 10Δ delay


def test_similarity_increases(toy_data):
    X, y, Xt, yt = toy_data
    res = run_simulation(small_cfg(variant="mu"), X, y, Xt, yt,
                         cycles=60, eval_every=20, seed=4)
    assert res.similarity[-1] > res.similarity[0] - 0.05
    assert res.similarity[-1] > 0.5  # models converge to each other


def test_message_accounting():
    """delivered + overflow <= sent (drops/offline account for the rest)."""
    from repro.core.simulation import simulate_cycle
    import jax
    n, d = 32, 8
    X = jnp.zeros((n, d))
    y = jnp.ones((n,))
    st = init_state(n, d, 4, 1)
    online = jnp.ones((n,), bool)
    sent = delivered = 0
    key = jax.random.key(0)
    for c in range(10):
        key, sub = jax.random.split(key)
        st, stats = simulate_cycle(st, X, y, online, sub, variant="mu",
                                   learner="pegasos", lam=1e-2, eta=0.1,
                                   drop=0.0, delay_max=1, k_rounds=6,
                                   sampler="uniform")
        sent += int(stats["sent"])
        delivered += int(stats["delivered"]) + int(stats["overflow"])
    # all sent messages from cycles 0..8 must be delivered by cycle 9
    assert delivered >= sent - n  # last cycle's sends still in flight


def test_message_economy_with_churn_balances_exactly():
    """Every sent message is exactly one of delivered / lost (destination
    offline at arrival) / overflow (beyond K rounds) / still in flight —
    the per-cycle economy adds up with no silent discards."""
    from repro.core.simulation import simulate_cycle
    n, d, D, cycles = 64, 8, 5, 30
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(np.sign(rng.normal(size=n) + 0.1), jnp.float32)
    online_mat = churn_trace(rng, n, cycles, 0.7)
    st = init_state(n, d, 4, D)
    key = jax.random.key(2)
    sent = delivered = lost = overflow = 0
    for c in range(cycles):
        key, sub = jax.random.split(key)
        st, stats = simulate_cycle(st, X, y, jnp.asarray(online_mat[c]), sub,
                                   variant="mu", learner="pegasos", lam=1e-2,
                                   eta=0.1, drop=0.3, delay_max=D,
                                   k_rounds=2, sampler="uniform")
        sent += int(stats["sent"])
        delivered += int(stats["delivered"])
        lost += int(stats["lost"])
        overflow += int(stats["overflow"])
        in_flight = int((np.asarray(st.buf_arrival) > c).sum())
        assert sent == delivered + lost + overflow + in_flight
    assert lost > 0          # churn at 70% online actually loses messages
    assert delivered > 0
