"""Property-based tests (hypothesis) for Section V's equivalences.

These are the paper's core mathematical claims:
  Eq. (6): regression voting == prediction of the averaged model;
  Eq. (7): weighted-vote classification == sign of the averaged model score;
  Eq. (8): Adaline update of the average == average of the updates;
  Pegasos: the same commutation holds iff both ancestors classify the
           example the same way (the UM-vs-MU discussion of Section V-B).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based tests need hypothesis; "
           "tests/test_merge_equivalences.py covers Eq. (7)/(8) without it")
from hypothesis import given, settings, strategies as st
from hypothesis.extra.numpy import arrays

from repro.core.learners import LinearModel, adaline_update, pegasos_update
from repro.core.merge import create_model_mu, create_model_um, merge

FLOATS = st.floats(-3.0, 3.0, allow_nan=False, width=32)


def vecs(n, d):
    return arrays(np.float32, (n, d), elements=FLOATS)


@settings(max_examples=30, deadline=None)
@given(W=vecs(5, 4), x=arrays(np.float32, (4,), elements=FLOATS))
def test_eq6_average_model_equals_mean_vote(W, x):
    scores = W @ x
    avg_model_score = np.mean(W, axis=0) @ x
    np.testing.assert_allclose(np.mean(scores), avg_model_score,
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(W=vecs(7, 5), x=arrays(np.float32, (5,), elements=FLOATS))
def test_eq7_weighted_vote_equals_sign_of_average(W, x):
    scores = W @ x
    # weighted vote: weights |<w,x>|, votes sgn<w,x>  ->  sgn(mean score)
    weighted = np.mean(np.abs(scores) * np.sign(scores))
    assert np.sign(weighted) == np.sign(np.mean(scores)) or np.isclose(
        np.mean(scores), 0.0, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(W=vecs(6, 4), x=arrays(np.float32, (4,), elements=FLOATS),
       y=st.sampled_from([-1.0, 1.0]), eta=st.floats(0.01, 0.5))
def test_eq8_adaline_update_commutes_with_averaging(W, x, y, eta):
    xs = jnp.asarray(x)
    # update every model then average
    upd = [adaline_update(LinearModel(jnp.asarray(w), jnp.int32(0)), xs, y, eta).w
           for w in W]
    avg_of_upd = np.mean(np.stack([np.asarray(u) for u in upd]), axis=0)
    # update the averaged model
    wbar = LinearModel(jnp.asarray(np.mean(W, axis=0)), jnp.int32(0))
    upd_of_avg = np.asarray(adaline_update(wbar, xs, y, eta).w)
    np.testing.assert_allclose(avg_of_upd, upd_of_avg, rtol=1e-4, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(w1=arrays(np.float32, (4,), elements=FLOATS),
       w2=arrays(np.float32, (4,), elements=FLOATS),
       x=arrays(np.float32, (4,), elements=FLOATS),
       y=st.sampled_from([-1.0, 1.0]),
       t=st.integers(1, 20))
def test_pegasos_um_equals_mu_iff_same_classification(w1, w2, x, y, t):
    """Section V-B: update/merge commute exactly when both ancestors
    classify (x, y) the same way (same hinge-branch)."""
    lam = 0.1
    m1 = LinearModel(jnp.asarray(w1), jnp.int32(t))
    m2 = LinearModel(jnp.asarray(w2), jnp.int32(t))
    xs = jnp.asarray(x)
    upd = lambda m, xx, yy: pegasos_update(m, xx, yy, lam)
    mu = create_model_mu(upd, m1, m2, xs, y)
    um = create_model_um(upd, m1, m2, xs, y)
    viol1 = float(y * (w1 @ x)) < 1.0
    viol2 = float(y * (w2 @ x)) < 1.0
    wbar = (w1 + w2) / 2.0
    violbar = float(y * (wbar @ x)) < 1.0
    if viol1 == viol2 == violbar:
        np.testing.assert_allclose(np.asarray(mu.w), np.asarray(um.w),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(w1=arrays(np.float32, (3,), elements=FLOATS),
       w2=arrays(np.float32, (3,), elements=FLOATS),
       t1=st.integers(0, 50), t2=st.integers(0, 50))
def test_merge_semantics(w1, w2, t1, t2):
    m = merge(LinearModel(jnp.asarray(w1), jnp.int32(t1)),
              LinearModel(jnp.asarray(w2), jnp.int32(t2)))
    np.testing.assert_allclose(np.asarray(m.w), (w1 + w2) / 2, rtol=1e-6,
                               atol=1e-30)  # atol for subnormal inputs
    assert int(m.t) == max(t1, t2)
