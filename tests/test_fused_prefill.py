"""Fused prefill == token-by-token decode_step prefill.

One full-sequence pass must produce the same decode cache (KV slots, SSM /
RG-LRU states) and next-token logits as feeding the prompt through the
recurrent ``decode_step`` — across the architecture families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced_config
from repro.models import transformer as T
from repro.models import vision as V
from repro.utils.tree import tree_leaves_with_path

ARCHS = ["qwen3-1.7b", "mamba2-780m", "recurrentgemma-9b",
         "whisper-medium", "llama-3.2-vision-11b"]


def _encoder_out(cfg, batch):
    if cfg.family == "vlm":
        return V.dummy_patch_embeddings(jax.random.key(9), cfg, batch)
    if cfg.family == "audio":
        return V.dummy_frame_embeddings(jax.random.key(9), cfg, batch)
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_fused_prefill_matches_sequential_decode(arch):
    cfg = reduced_config(get_config(arch), vocab=256)
    batch, P, max_len = 2, 10, 24
    params = T.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, P)),
                          jnp.int32)
    enc = _encoder_out(cfg, batch)

    # sequential: decode_step per prompt token (cross KV attached first,
    # the way DecodeServer does)
    cache = T.init_cache(cfg, batch, max_len)
    if enc is not None:
        _, fused0 = T.prefill(params, cfg, prompts[:, :1], max_len,
                              encoder_out=enc)
        # copy ONLY the cross-source entries (they are position-independent)
        def put_cross(seq, fus):
            for scope in ("blocks", "tail"):
                if scope not in seq:
                    continue
                for lk, lv in seq[scope].items():
                    for ck in ("ck", "cv"):
                        if ck in lv:
                            lv[ck] = fus[scope][lk][ck]
        put_cross(cache, fused0)
    logits_seq = None
    for i in range(P):
        logits_seq, cache = T.decode_step(params, cfg, prompts[:, i], cache,
                                          jnp.int32(i))

    logits_fused, cache_fused = T.prefill(params, cfg, prompts, max_len,
                                          encoder_out=enc)

    np.testing.assert_allclose(np.asarray(logits_fused),
                               np.asarray(logits_seq), rtol=0.08, atol=0.08)
    flat_s = tree_leaves_with_path(cache)
    flat_f = dict(tree_leaves_with_path(cache_fused))
    checked = 0
    for path, leaf_s in flat_s:
        leaf_f = flat_f[path]
        assert leaf_f.shape == leaf_s.shape, path
        np.testing.assert_allclose(np.asarray(leaf_f, np.float32),
                                   np.asarray(leaf_s, np.float32),
                                   rtol=0.08, atol=0.08,
                                   err_msg=str(path))
        checked += 1
    assert checked >= 2


def test_fused_prefill_ring_window():
    """Prompt longer than the window: the fused cache must hold the LAST
    `window` positions at ring slots, matching sequential decode."""
    cfg = reduced_config(get_config("qwen3-1.7b"), vocab=128)
    batch, P, win = 1, 13, 8
    params = T.init_params(jax.random.key(1), cfg)
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, 128, (batch, P)), jnp.int32)

    cache = T.init_cache(cfg, batch, win, window=win)
    logits_seq = None
    for i in range(P):
        logits_seq, cache = T.decode_step(params, cfg, prompts[:, i], cache,
                                          jnp.int32(i), window=win)
    logits_fused, cache_fused = T.prefill(params, cfg, prompts, win,
                                          window=win)
    np.testing.assert_allclose(np.asarray(logits_fused),
                               np.asarray(logits_seq), rtol=0.08, atol=0.08)
    for (p1, a), (p2, b) in zip(tree_leaves_with_path(cache_fused),
                                tree_leaves_with_path(cache)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.08, atol=0.08, err_msg=str(p1))
