"""Integration: step-by-step decode must reproduce the full-sequence forward.

The strongest correctness check of the serving path: for each family, run
forward() on a token sequence and compare its per-position logits with the
logits produced by feeding the same tokens one-by-one through decode_step
with a KV/recurrent cache. (MoE archs are excluded from exact comparison:
capacity-based dropping depends on the token population by design.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced_config
from repro.models import transformer as T
from repro.models import vision as V

SEQ = 24


def _roundtrip(arch, atol, with_encoder=False):
    cfg = reduced_config(get_config(arch))
    cfg = cfg.replace(compute_dtype=jnp.float32, param_dtype=jnp.float32)
    params = T.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, SEQ)), jnp.int32)

    enc = None
    if with_encoder:
        enc = (V.dummy_patch_embeddings(jax.random.key(1), cfg, 1)
               if cfg.family == "vlm"
               else V.dummy_frame_embeddings(jax.random.key(1), cfg, 1))
    full_logits, _ = T.forward(params, cfg, toks, encoder_out=enc)

    cache = T.init_cache(cfg, 1, SEQ)
    if with_encoder:
        _fill_cross_kv(cfg, params, cache, enc)
    step_logits = []
    for i in range(SEQ):
        lg, cache = T.decode_step(params, cfg, toks[:, i], cache, jnp.int32(i))
        step_logits.append(lg)
    step_logits = jnp.stack(step_logits, axis=1)

    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), atol=atol,
                               err_msg=f"{arch}: decode != forward")


def _fill_cross_kv(cfg, params, cache, enc):
    src = enc
    if cfg.family == "audio":
        from repro.models.encdec import encoder_forward
        src = encoder_forward(params["encoder"], cfg, enc)
    for j, kind in enumerate(cfg.layer_pattern):
        if kind not in ("cross", "selfcross"):
            continue
        lc = cache["blocks"][f"l{j}"]
        nb = lc["ck"].shape[0]
        cks, cvs = [], []
        for i in range(nb):
            lp = jax.tree.map(lambda p: p[i], params["blocks"])[f"l{j}"]
            k = jnp.einsum("bsd,dhk->bshk", src, lp["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", src, lp["cross_attn"]["wv"])
            if cfg.attention.qk_norm:
                from repro.models.layers import rmsnorm
                k = rmsnorm(lp["cross_attn"]["k_norm"], k)
            cks.append(k.astype(lc["ck"].dtype))
            cvs.append(v.astype(lc["cv"].dtype))
        lc["ck"] = jnp.stack(cks)
        lc["cv"] = jnp.stack(cvs)


def test_dense_gqa_qknorm():
    _roundtrip("qwen3-1.7b", atol=2e-3)


def test_dense_swa():
    _roundtrip("mixtral-8x22b".replace("mixtral", "mixtral"), atol=None) \
        if False else None  # mixtral is MoE; SWA covered by recurrentgemma


def test_ssm_mamba2():
    _roundtrip("mamba2-780m", atol=4e-3)


def test_hybrid_recurrentgemma():
    _roundtrip("recurrentgemma-9b", atol=4e-3)


def test_vlm_cross_attention():
    _roundtrip("llama-3.2-vision-11b", atol=2e-3, with_encoder=True)


def test_audio_encdec():
    _roundtrip("whisper-medium", atol=2e-3, with_encoder=True)


def test_dense_llama405b_family():
    # the 405b family at smoke scale (plain GQA rope, untied head)
    _roundtrip("llama3-405b", atol=2e-3)
