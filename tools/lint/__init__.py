"""repro-lint: contract-enforcing static analysis for the gossip repo.

``python tools/lint/run.py`` walks the source tree with the AST rules in
:mod:`lint.rules` (registry ``RULES``) and exits non-zero on any violation.
Per-line suppressions are ``# lint: disable=RULE(reason)`` — the reason is
mandatory. The invariants the rules encode are written up in
docs/CONTRACTS.md, whose rule table is cross-checked against ``RULES`` both
ways by tools/check_docs.py.
"""
