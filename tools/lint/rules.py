"""The repro-lint rules — one class per contract (docs/CONTRACTS.md).

``RULES`` maps rule name -> instance; the CONTRACTS.md rule table mirrors
this registry and tools/check_docs.py cross-checks the two both ways, the
same mechanism that keeps the ENGINES.md codec table honest.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

from lint.engine import ModuleInfo, Rule, Violation
from lint.rng_allowlist import RNG_ALLOWED

REPO = Path(__file__).resolve().parent.parent.parent


def _walk_scoped(node: ast.AST, stack: Tuple[str, ...] = ()
                 ) -> Iterator[Tuple[ast.AST, Tuple[str, ...]]]:
    """Yield (node, enclosing-def-qualname-tuple) for every descendant."""
    for child in ast.iter_child_nodes(node):
        yield child, stack
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield from _walk_scoped(child, stack + (child.name,))
        else:
            yield from _walk_scoped(child, stack)


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _walk_own(node: ast.AST) -> Iterator[ast.AST]:
    """Descendants of ``node`` that belong to its own scope — does not
    descend into nested def/lambda bodies (they get their own pass)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        yield from _walk_own(child)


def _scope_lookup(table: dict, stack: Tuple[str, ...], name: str):
    """Innermost-first lookup of ``name`` along the enclosing-def chain."""
    for cut in range(len(stack), -1, -1):
        hit = table.get((stack[:cut], name))
        if hit is not None:
            return hit
    return None


class _ModuleIndex:
    """Scoped def/assignment tables of one module, shared across rules."""

    def __init__(self, mod: ModuleInfo):
        self.defs: dict = {}        # (scope_tuple, name) -> FunctionDef
        self.def_scope: dict = {}   # id(FunctionDef) -> its INNER scope
        self.assigns: dict = {}     # (scope_tuple, name) -> last value node
        for node, stack in _walk_scoped(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[(stack, node.name)] = node
                self.def_scope[id(node)] = stack + (node.name,)
            elif (isinstance(node, ast.Assign) and len(node.targets) == 1
                  and isinstance(node.targets[0], ast.Name)):
                self.assigns[(stack, node.targets[0].id)] = node.value

    def resolve_fn(self, expr: ast.AST, stack: Tuple[str, ...]
                   ) -> List[ast.FunctionDef]:
        """Resolve a callable expression to local def(s): unwraps
        ``functools.partial(f, ...)``, follows one plain rebinding and the
        ``body = {...: fn}[mode]`` dict-dispatch idiom."""
        if (isinstance(expr, ast.Call)
                and _call_name(expr.func) == "partial" and expr.args):
            expr = expr.args[0]
        if not isinstance(expr, ast.Name):
            return []
        d = _scope_lookup(self.defs, stack, expr.id)
        if d is not None:
            return [d]
        val = _scope_lookup(self.assigns, stack, expr.id)
        if isinstance(val, ast.Subscript) and isinstance(val.value, ast.Dict):
            out = []
            for v in val.value.values:
                if isinstance(v, ast.Name):
                    d = _scope_lookup(self.defs, stack, v.id)
                    if d is not None:
                        out.append(d)
            return out
        if isinstance(val, ast.Name):
            d = _scope_lookup(self.defs, stack, val.id)
            if d is not None:
                return [d]
        return []


# ---------------------------------------------------------------------------
# rule 1: rng-discipline
# ---------------------------------------------------------------------------

# key plumbing, not draws: these never advance a threefry counter
_RNG_PLUMBING = {"key", "key_data", "wrap_key_data", "PRNGKey"}


def _rng_fn(func: ast.AST) -> Optional[str]:
    """'split' for ``jax.random.split`` / ``jrandom.split``-style calls."""
    if not isinstance(func, ast.Attribute):
        return None
    v = func.value
    if (isinstance(v, ast.Attribute) and v.attr == "random"
            and isinstance(v.value, ast.Name) and v.value.id == "jax"):
        return func.attr
    if isinstance(v, ast.Name) and v.id in ("jrandom", "jr"):
        return func.attr
    return None


class RngDiscipline(Rule):
    name = "rng-discipline"
    contract = ("every jax.random draw in core/ and kernels/ is a "
                "registered site of the pinned per-cycle threefry "
                "draw sequence (tools/lint/rng_allowlist.py)")
    SCOPE = ("src/repro/core/", "src/repro/kernels/")

    def check(self, mod: ModuleInfo) -> List[Violation]:
        if not mod.relpath.startswith(self.SCOPE):
            return []
        rel = mod.relpath[len("src/repro/"):]
        out = []
        for node, stack in _walk_scoped(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = _rng_fn(node.func)
            if fn is None or fn in _RNG_PLUMBING:
                continue
            qual = ".".join(stack) or "<module>"
            if fn in RNG_ALLOWED.get((rel, qual), ()):
                continue
            out.append(Violation(
                self.name, mod.relpath, node.lineno,
                f"unregistered jax.random.{fn} in {qual} — an extra draw "
                "shifts every later threefry counter and breaks "
                "cross-engine bitwise parity; register the site in "
                "tools/lint/rng_allowlist.py naming its draw-sequence "
                "contract"))
        return out


# ---------------------------------------------------------------------------
# rule 2: shardmap-spec-arity
# ---------------------------------------------------------------------------


def _spec_width(node: ast.AST) -> Optional[Tuple[int, int]]:
    """(fixed_entries, dynamic_terms) of a spec-tuple expression, resolving
    the ``(ps,) * 8 + (ps,) * len(meta)`` arithmetic; None = not a tuple
    expression (a single broadcast spec matches any arity)."""
    if isinstance(node, ast.Tuple):
        return len(node.elts), 0
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        a, b = _spec_width(node.left), _spec_width(node.right)
        if a is None or b is None:
            return None
        return a[0] + b[0], a[1] + b[1]
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        tup, k = node.left, node.right
        if not isinstance(tup, ast.Tuple):
            tup, k = node.right, node.left
        if not isinstance(tup, ast.Tuple):
            return None
        if isinstance(k, ast.Constant) and isinstance(k.value, int):
            return len(tup.elts) * k.value, 0
        return 0, 1                   # (ps,) * len(meta): a dynamic term
    return None


def _own_returns(fn: ast.FunctionDef) -> List[ast.Return]:
    """Return statements of ``fn`` itself, not of defs nested inside it."""
    outs: List[ast.Return] = []

    def rec(n):
        for c in ast.iter_child_nodes(n):
            if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                continue
            if isinstance(c, ast.Return):
                outs.append(c)
            rec(c)

    rec(fn)
    return outs


class ShardmapSpecArity(Rule):
    name = "shardmap-spec-arity"
    contract = ("shard_map_compat spec-tuple widths match the wrapped "
                "function's parameter/return arity, dynamic "
                "(ps,) * len(x) terms matching *varargs")

    def check(self, mod: ModuleInfo) -> List[Violation]:
        if not mod.relpath.startswith("src/"):
            return []
        idx = _ModuleIndex(mod)
        out = []
        for node, stack in _walk_scoped(mod.tree):
            if (not isinstance(node, ast.Call)
                    or _call_name(node.func) != "shard_map_compat"
                    or not node.args):
                continue
            fns = idx.resolve_fn(node.args[0], stack)
            if len(fns) != 1:
                continue              # unresolvable target: nothing to check
            fn = fns[0]
            nparams = len(fn.args.posonlyargs) + len(fn.args.args)
            vararg = fn.args.vararg is not None
            kw = {k.arg: k.value for k in node.keywords}
            in_w = _spec_width(kw.get("in_specs"))
            if in_w is not None:
                fixed, dyn = in_w
                if fixed != nparams:
                    out.append(Violation(
                        self.name, mod.relpath, node.lineno,
                        f"in_specs has {fixed} fixed spec(s) but "
                        f"{fn.name}() takes {nparams} positional "
                        "parameter(s) — a silent arity drift is exactly how "
                        "a new carry lane loses its sharding"))
                elif dyn > 0 and not vararg:
                    out.append(Violation(
                        self.name, mod.relpath, node.lineno,
                        f"in_specs has a dynamic (spec,) * len(...) term "
                        f"but {fn.name}() takes no *varargs"))
                elif vararg and dyn == 0:
                    out.append(Violation(
                        self.name, mod.relpath, node.lineno,
                        f"{fn.name}() takes *{fn.args.vararg.arg} but "
                        "in_specs carries no dynamic (spec,) * len(...) "
                        "term for it"))
            out_w = _spec_width(kw.get("out_specs"))
            if out_w is not None and out_w[1] == 0:
                rets = _own_returns(fn)
                tuple_rets = [r for r in rets
                              if isinstance(r.value, ast.Tuple)]
                # only checkable when every return is a literal tuple of
                # one consistent width
                widths = {len(r.value.elts) for r in tuple_rets}
                if (tuple_rets and len(tuple_rets) == len(rets)
                        and len(widths) == 1 and widths != {out_w[0]}):
                    out.append(Violation(
                        self.name, mod.relpath, node.lineno,
                        f"out_specs has {out_w[0]} spec(s) but {fn.name}() "
                        f"returns a {widths.pop()}-tuple"))
        return out


# ---------------------------------------------------------------------------
# rule 3: merge-dtype-purity
# ---------------------------------------------------------------------------

_F32_NAMES = {"float32", "f32"}
_WIRE_NAMES = {"bfloat16", "float16", "int8", "uint8", "int16", "uint16",
               "int4", "f16", "bf16", "half"}
_ARITH = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod,
          ast.Pow, ast.MatMult)


def _dtype_class(node: ast.AST) -> Optional[str]:
    """'f32' / 'wire' for a dtype-naming expression (jnp.float32, 'int8')."""
    attr = None
    if isinstance(node, ast.Attribute):
        attr = node.attr
    elif isinstance(node, ast.Name):
        attr = node.id
    elif isinstance(node, ast.Constant) and isinstance(node.value, str):
        attr = node.value
    if attr in _F32_NAMES:
        return "f32"
    if attr in _WIRE_NAMES:
        return "wire"
    return None


def _dtype_of(node: ast.AST, env: dict) -> Optional[str]:
    """Forward dtype estimate: 'f32' | 'wire' | 'neutral' (python scalar,
    weak-typed in jax) | None (unknown — never flagged)."""
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.Constant):
        return ("neutral" if isinstance(node.value, (int, float))
                and not isinstance(node.value, bool) else None)
    if isinstance(node, (ast.Subscript, ast.UnaryOp)):
        inner = node.value if isinstance(node, ast.Subscript) else node.operand
        return _dtype_of(inner, env)
    if isinstance(node, ast.BinOp):
        a, b = _dtype_of(node.left, env), _dtype_of(node.right, env)
        for strong in ("f32", "wire"):
            if strong in (a, b):
                return strong if {a, b} <= {strong, "neutral"} else None
        return None
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "astype" and node.args:
                return _dtype_class(node.args[0])
            if f.attr in ("zeros", "ones", "full", "empty", "asarray",
                          "array"):
                for k in node.keywords:
                    if k.arg == "dtype":
                        return _dtype_class(k.value)
                if len(node.args) >= 2:
                    return _dtype_class(node.args[-1])
                return None
            if f.attr == "where" and len(node.args) == 3:
                a = _dtype_of(node.args[1], env)
                b = _dtype_of(node.args[2], env)
                return a if a == b else None
            # jnp.float16(x)-style dtype constructors
            if isinstance(f.value, ast.Name) and f.value.id in ("jnp", "np"):
                return _dtype_class(ast.Name(id=f.attr))
    return None


class MergeDtypePurity(Rule):
    name = "merge-dtype-purity"
    contract = ("merge arithmetic runs in f32: no +,-,*,/ mixing a "
                "wire-dtype operand (bf16/f16/int lanes) into f32 math "
                "without an explicit .astype")
    SCOPE = ("src/repro/core/merge.py", "src/repro/core/wire_codec.py",
             "src/repro/core/gossip_optimizer.py",
             "src/repro/core/simulation.py",
             "src/repro/core/sharded_engine.py", "src/repro/kernels/")

    def check(self, mod: ModuleInfo) -> List[Violation]:
        if not mod.relpath.startswith(self.SCOPE):
            return []
        out: List[Violation] = []
        for node, stack in _walk_scoped(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_fn(node, mod, out)
        return out

    def _check_fn(self, fn, mod: ModuleInfo, out: List[Violation]) -> None:
        env: dict = {}
        seen: set = set()

        def stmts(body):
            for st in body:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue          # nested defs get their own pass
                self._check_exprs(st, env, seen, mod, out)
                if isinstance(st, ast.Assign):
                    val = _dtype_of(st.value, env)
                    for tgt in st.targets:
                        if isinstance(tgt, ast.Name):
                            env[tgt.id] = val
                        elif isinstance(tgt, ast.Tuple):
                            for el in tgt.elts:
                                if isinstance(el, ast.Name):
                                    env[el.id] = None
                elif isinstance(st, ast.AugAssign) and \
                        isinstance(st.target, ast.Name):
                    env[st.target.id] = None
                for attr in ("body", "orelse", "finalbody"):
                    sub = getattr(st, attr, None)
                    if sub:
                        stmts(sub)

        stmts(fn.body)

    def _check_exprs(self, st, env, seen, mod, out) -> None:
        for sub in _walk_own(st):
            if (isinstance(sub, ast.BinOp) and isinstance(sub.op, _ARITH)
                    and (sub.lineno, sub.col_offset) not in seen):
                a = _dtype_of(sub.left, env)
                b = _dtype_of(sub.right, env)
                if {a, b} == {"f32", "wire"}:
                    seen.add((sub.lineno, sub.col_offset))
                    out.append(Violation(
                        self.name, mod.relpath, sub.lineno,
                        "arithmetic mixes a wire-dtype operand into f32 "
                        "math — implicit promotion silently changes the "
                        "merge result; insert an explicit "
                        ".astype(jnp.float32)"))


# ---------------------------------------------------------------------------
# rule 4: tracer-leak
# ---------------------------------------------------------------------------


def _is_static(node: ast.AST, taint: set, static: set) -> bool:
    """True when the expression's VALUE is fixed at trace time (shapes,
    dtypes, python containers, config), so branching/len() on it is legal
    inside a scan body or kernel."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id not in taint or node.id in static
    if isinstance(node, ast.Attribute):
        return (node.attr in ("shape", "ndim", "dtype", "size")
                or _is_static(node.value, taint, static))
    if isinstance(node, (ast.Tuple, ast.List, ast.Dict, ast.Set,
                         ast.ListComp, ast.GeneratorExp, ast.DictComp,
                         ast.JoinedStr)):
        return True                   # container structure is trace-static
    if isinstance(node, ast.Subscript):
        return _is_static(node.value, taint, static)
    if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare)):
        return all(_is_static(c, taint, static)
                   for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))
    if isinstance(node, ast.UnaryOp):
        return _is_static(node.operand, taint, static)
    if isinstance(node, ast.Call):
        fname = _call_name(node.func)
        if fname in ("len", "range", "enumerate", "zip", "list", "tuple",
                     "dict", "int", "min", "max", "sorted"):
            return all(_is_static(a, taint, static) for a in node.args)
    return False


def _tainted_names(node: ast.AST, taint: set, static: set) -> List[str]:
    """Tainted (traced) names inside ``node``, pruning subtrees whose value
    is trace-static: ``is``/``is not`` comparisons (``x is None`` tests the
    python binding, not the value) and ``.shape``/``.ndim``/``.dtype``/
    ``.size`` attribute reads."""
    hits: List[str] = []

    def rec(n):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            return
        if isinstance(n, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops):
            return
        if isinstance(n, ast.Compare) and all(
                isinstance(op, (ast.In, ast.NotIn)) for op in n.ops) and \
                isinstance(n.left, ast.Constant) and \
                isinstance(n.left.value, str):
            return                    # '"key" in pytree': structural test
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                       "dtype", "size"):
            return
        if isinstance(n, ast.Name) and n.id in taint and n.id not in static:
            hits.append(n.id)
        for c in ast.iter_child_nodes(n):
            rec(c)

    rec(node)
    return hits


def _callee_taint(call: ast.Call, fndef: ast.FunctionDef, taint: set,
                  static: set) -> frozenset:
    """Which of ``fndef``'s parameters receive a traced value at this call
    site — static config passed positionally stays untainted."""
    params = [a.arg for a in fndef.args.posonlyargs + fndef.args.args]
    kwonly = {a.arg for a in fndef.args.kwonlyargs}
    t: set = set()

    def dirty(expr):
        return bool(_tainted_names(expr, taint, static)) and \
            not _is_static(expr, taint, static)

    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            if dirty(arg.value):      # *args forwarding: conservative
                t.update(params[i:])
                if fndef.args.vararg is not None:
                    t.add(fndef.args.vararg.arg)
            continue
        if dirty(arg):
            if i < len(params):
                t.add(params[i])
            elif fndef.args.vararg is not None:
                t.add(fndef.args.vararg.arg)
    for kw in call.keywords:
        if kw.arg is not None and dirty(kw.value) and \
                (kw.arg in params or kw.arg in kwonly):
            t.add(kw.arg)
    return frozenset(t)


class TracerLeak(Rule):
    name = "tracer-leak"
    contract = ("no python branching or float/int/bool/len coercion on "
                "traced values inside lax.scan bodies and Pallas kernel "
                "functions (including their same-module callees)")
    OPS = ("float", "int", "bool", "len")

    def check(self, mod: ModuleInfo) -> List[Violation]:
        if not mod.relpath.startswith("src/"):
            return []
        idx = _ModuleIndex(mod)
        queue: List[Tuple[ast.FunctionDef, frozenset]] = []
        for node, stack in _walk_scoped(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node.func)
            if name in ("scan", "pallas_call") and node.args:
                for fn in idx.resolve_fn(node.args[0], stack):
                    # every positional param of a scan body / kernel fn
                    # carries a tracer (carry/xs slices, Refs); keyword-only
                    # params are static config bound via functools.partial
                    seed = {a.arg for a in fn.args.posonlyargs
                            + fn.args.args}
                    if fn.args.vararg is not None:
                        seed.add(fn.args.vararg.arg)
                    queue.append((fn, frozenset(seed)))
        out: List[Violation] = []
        analyzed: Dict[int, frozenset] = {}
        flagged: set = set()
        while queue:
            fn, taint_in = queue.pop()
            prev = analyzed.get(id(fn), frozenset())
            if taint_in <= prev:
                continue
            analyzed[id(fn)] = prev | taint_in
            queue.extend(self._check_fn(fn, prev | taint_in, idx, mod, out,
                                        flagged))
        return out

    def _check_fn(self, fn, taint_in: frozenset, idx: _ModuleIndex,
                  mod: ModuleInfo, out: List[Violation], flagged: set
                  ) -> List[Tuple[ast.FunctionDef, frozenset]]:
        taint = set(taint_in)
        static: set = set()
        scope = idx.def_scope[id(fn)]
        callees: List[Tuple[ast.FunctionDef, frozenset]] = []

        def flag(node, what):
            key = (node.lineno, node.col_offset, what)
            if key in flagged:
                return                # fn re-analyzed with a wider taint
            flagged.add(key)
            out.append(Violation(
                self.name, mod.relpath, node.lineno,
                f"{what} on a traced value inside a scan body / kernel fn "
                "— this reads the tracer at trace time and either crashes "
                "or silently bakes in one branch"))

        def mark_target(tgt, is_static):
            if isinstance(tgt, ast.Name):
                (static.add if is_static else taint.add)(tgt.id)
                if not is_static:
                    static.discard(tgt.id)
            elif isinstance(tgt, ast.Starred):
                # *rest of a tuple unpack is a real python list at trace
                # time: its truthiness/len are static
                if isinstance(tgt.value, ast.Name):
                    static.add(tgt.value.id)
                    taint.add(tgt.value.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    mark_target(el, is_static)

        def visit(body):
            for st in body:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if isinstance(st, (ast.If, ast.While)):
                    if _tainted_names(st.test, taint, static):
                        flag(st, "python `if`/`while`")
                elif isinstance(st, ast.For):
                    dirty_iter = bool(_tainted_names(st.iter, taint, static))
                    if dirty_iter:
                        flag(st, "python `for` iteration")
                    # iterating a static container yields static items
                    mark_target(st.target, not dirty_iter)
                for sub in _walk_own(st):
                    if isinstance(sub, ast.IfExp) and \
                            _tainted_names(sub.test, taint, static):
                        flag(sub, "conditional expression")
                    if not isinstance(sub, ast.Call):
                        continue
                    name = _call_name(sub.func)
                    if name in self.OPS and any(
                            _tainted_names(a, taint, static)
                            for a in sub.args) and not all(
                            _is_static(a, taint, static) for a in sub.args):
                        flag(sub, f"{name}() coercion")
                    elif name is not None:
                        callees.extend(
                            (d, _callee_taint(sub, d, taint, static))
                            for d in idx.resolve_fn(sub.func, scope))
                if isinstance(st, ast.Assign):
                    is_static = _is_static(st.value, taint, static)
                    dirty = bool(_tainted_names(st.value, taint, static))
                    for tgt in st.targets:
                        if is_static or not dirty:
                            mark_target(tgt, True)
                        else:
                            mark_target(tgt, False)
                for attr in ("body", "orelse", "finalbody"):
                    sub_body = getattr(st, attr, None)
                    if sub_body:
                        visit(sub_body)

        visit(fn.body)
        return callees


# ---------------------------------------------------------------------------
# rule 5: codec-literal
# ---------------------------------------------------------------------------

_codec_names_cache: Optional[frozenset] = None


def _codec_names() -> frozenset:
    """The registered wire-codec names, imported from the live registry
    (same both-ways philosophy as check_docs's codec table gate)."""
    global _codec_names_cache
    if _codec_names_cache is None:
        sys.path.insert(0, str(REPO / "src"))
        try:
            from repro.core.wire_codec import WIRE_CODECS
            _codec_names_cache = frozenset(WIRE_CODECS)
        finally:
            sys.path.pop(0)
    return _codec_names_cache


class CodecLiteral(Rule):
    name = "codec-literal"
    contract = ("every string literal flowing into a wire_dtype/codec "
                "parameter names a codec registered in WIRE_CODECS")
    KWARGS = {"wire_dtype", "exchange_dtype", "wire"}

    def check(self, mod: ModuleInfo) -> List[Violation]:
        if not mod.relpath.startswith(("src/repro/", "benchmarks/")):
            return []
        out: List[Violation] = []

        def bad(node, literal, where):
            out.append(Violation(
                self.name, mod.relpath, node.lineno,
                f"{where} names {literal!r}, which is not a registered "
                f"wire codec ({', '.join(sorted(_codec_names()))})"))

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if (kw.arg in self.KWARGS
                            and isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, str)
                            and kw.value.value not in _codec_names()):
                        bad(kw.value, kw.value.value, f"{kw.arg}=")
                if (_call_name(node.func) == "get_codec" and node.args
                        and isinstance(node.args[0], ast.Constant)
                        and isinstance(node.args[0].value, str)
                        and node.args[0].value not in _codec_names()):
                    bad(node, node.args[0].value, "get_codec()")
            elif (isinstance(node, ast.Subscript)
                  and _call_name(node.value) == "WIRE_CODECS"
                  and isinstance(node.slice, ast.Constant)
                  and isinstance(node.slice.value, str)
                  and node.slice.value not in _codec_names()):
                bad(node, node.slice.value, "WIRE_CODECS[...]")
        return out


RULES: Dict[str, Rule] = {r.name: r for r in (
    RngDiscipline(), ShardmapSpecArity(), MergeDtypePurity(), TracerLeak(),
    CodecLiteral())}
