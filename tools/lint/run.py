#!/usr/bin/env python
"""repro-lint CLI: machine-check the repo's parity contracts.

    python tools/lint/run.py                  # lint src/repro + benchmarks
    python tools/lint/run.py path/to/file.py  # lint specific files
    python tools/lint/run.py --rule tracer-leak

Exit status is non-zero when any violation survives its per-line
suppressions (``# lint: disable=RULE(reason)`` — the reason is mandatory).
The rules and the invariants they enforce are documented in
docs/CONTRACTS.md; tools/run_tests.sh runs this before pytest in every
mode, like tools/check_docs.py.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

TOOLS = Path(__file__).resolve().parent.parent
REPO = TOOLS.parent
sys.path.insert(0, str(TOOLS))

from lint.engine import run_lint          # noqa: E402
from lint.rules import RULES              # noqa: E402

DEFAULT_DIRS = ("src/repro", "benchmarks")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", help="files to lint (default: "
                    + ", ".join(DEFAULT_DIRS))
    ap.add_argument("--rule", action="append", choices=sorted(RULES),
                    help="run only these rules (may repeat)")
    args = ap.parse_args(argv)

    files = ([Path(p) for p in args.paths] if args.paths else
             sorted(fp for d in DEFAULT_DIRS
                    for fp in (REPO / d).rglob("*.py")))
    rules = ([RULES[r] for r in args.rule] if args.rule
             else list(RULES.values()))
    violations = run_lint(files, REPO, rules)
    for v in violations:
        print(v)
    if violations:
        print(f"repro-lint: {len(violations)} violation(s)")
        return 1
    print(f"repro-lint: OK ({len(files)} files, {len(rules)} rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
