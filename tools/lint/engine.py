"""The repro-lint rule engine: parsed modules, violations, suppressions.

A rule is an object with a ``name`` and a ``check(ModuleInfo) -> [Violation]``
method; the engine parses each file once, runs every rule over it, and
filters the results through per-line suppression comments:

    risky_call()    # lint: disable=rng-discipline(prototype noise study)

The parenthesised reason is mandatory — a bare ``# lint: disable=RULE`` is
itself reported (rule name ``suppression``), so every silenced site carries
its justification in the diff. Rules scope themselves by the module's
repo-relative path (``ModuleInfo.relpath``), which is what lets the test
suite replay them against planted fixtures under a temp root.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Set, Tuple

SUPPRESS = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z][A-Za-z0-9_-]*)\s*(?:\(\s*([^)]*?)\s*\))?")


@dataclasses.dataclass
class Violation:
    rule: str
    relpath: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.relpath}:{self.line}: [{self.rule}] {self.message}"


class ModuleInfo:
    """One parsed source file plus its repo-relative path for rule scoping."""

    def __init__(self, path: Path, repo_root: Path):
        self.path = Path(path)
        self.relpath = (self.path.resolve()
                        .relative_to(Path(repo_root).resolve()).as_posix())
        self.source = self.path.read_text()
        self.tree = ast.parse(self.source, filename=str(path))
        self.lines = self.source.splitlines()


class Rule:
    """Base rule: subclasses set ``name``/``contract`` and implement
    ``check``. ``contract`` is the one-line invariant the rule enforces,
    mirrored into the docs/CONTRACTS.md rule table."""

    name: str = ""
    contract: str = ""

    def check(self, mod: ModuleInfo) -> List[Violation]:
        raise NotImplementedError


def suppressions(mod: ModuleInfo) -> Tuple[Dict[int, Set[str]],
                                           List[Violation]]:
    """Per-line suppressed rule names, plus violations for reason-less
    suppression comments (which are never honored)."""
    supp: Dict[int, Set[str]] = {}
    errs: List[Violation] = []
    for lineno, text in enumerate(mod.lines, 1):
        for m in SUPPRESS.finditer(text):
            rule, reason = m.group(1), m.group(2)
            if not reason:
                errs.append(Violation(
                    "suppression", mod.relpath, lineno,
                    f"suppression of {rule!r} carries no reason — write "
                    f"# lint: disable={rule}(why this site is sanctioned)"))
                continue
            supp.setdefault(lineno, set()).add(rule)
    return supp, errs


def run_lint(files, repo_root, rules) -> List[Violation]:
    """Run ``rules`` over ``files``; returns surviving violations sorted by
    (path, line). Suppression comments must sit on the violating line."""
    out: List[Violation] = []
    for fp in files:
        try:
            mod = ModuleInfo(Path(fp), repo_root)
        except SyntaxError as exc:
            out.append(Violation("parse", str(fp), exc.lineno or 0,
                                 f"syntax error: {exc.msg}"))
            continue
        supp, errs = suppressions(mod)
        out.extend(errs)
        for rule in rules:
            for v in rule.check(mod):
                if rule.name in supp.get(v.line, set()):
                    continue
                out.append(v)
    out.sort(key=lambda v: (v.relpath, v.line, v.rule))
    return out
