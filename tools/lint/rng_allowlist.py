"""Sanctioned ``jax.random`` call sites for the rng-discipline rule.

Both engines replay ONE pinned threefry draw sequence (docs/CONTRACTS.md):
per cycle, ``split(key, 4) -> (k_recv, k_dst, k_delay, k_drop)``, then the
destination draw from ``k_dst``, the delay draw from ``k_delay`` and the
drop draw from ``k_drop`` — in that order, with ``k_recv`` reserved for the
stochastic-rounding wire noise. An extra (or re-ordered) draw anywhere in
the hot path shifts every later threefry counter and breaks cross-engine
bitwise parity *silently* — the run still converges, just not identically.
So every draw inside ``src/repro/core`` and ``src/repro/kernels`` must be
registered here, keyed by ``(path relative to src/repro, def-qualname)``
with the set of ``jax.random`` functions that site may call; the comment on
each entry names the draw-sequence contract it belongs to.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Tuple

RNG_ALLOWED: Dict[Tuple[str, str], FrozenSet[str]] = {
    # reference engine, per-cycle sequence: the 4-way split and the
    # dst/delay/drop draws — THE sequence every other engine replays
    ("core/simulation.py", "cycle_core"):
        frozenset({"split", "randint", "bernoulli"}),
    # reference driver key chain: key, sub = split(key) once per cycle;
    # key_schedule replays it bitwise on device
    ("core/simulation.py", "run_simulation"): frozenset({"split"}),
    # sharded engine: device-side replay of the driver key chain
    ("core/sharded_engine.py", "key_schedule.body"): frozenset({"split"}),
    # sharded engine control plane: scanned replica of cycle_core's
    # split/dst/delay/drop order (bit-for-bit, see _draw_chunk docstring)
    ("core/sharded_engine.py", "_draw_chunk.body"):
        frozenset({"split", "randint", "bernoulli"}),
    # send-side SR noise: re-derives the reference engine's k_recv
    # (slot 0 of the per-cycle 4-way split) from the scanned key data
    ("core/sharded_engine.py", "_build_chunk_fn.chunk_fn.send"):
        frozenset({"split"}),
    # same k_recv derivation on the sender-subset (compact_all) path
    ("core/sharded_engine.py", "_build_chunk_fn.chunk_fn.send_compact"):
        frozenset({"split"}),
    # peer sampling consumes the per-cycle k_dst slot — one draw, no more
    ("core/peer_sampling.py", "uniform_peers"): frozenset({"randint"}),
    ("core/peer_sampling.py", "perfect_matching"): frozenset({"permutation"}),
    # int8_sr wire noise from k_recv (the slot the float codecs leave
    # unused), uniform over the full (N, d) block
    ("core/wire_codec.py", "quantize_wire"): frozenset({"uniform"}),
    # adversarial fault stream: k_fault = fold_in(cycle key, FAULT_FOLD)
    # DERIVES a side key without consuming from the pinned 4-way split, so
    # fault-free runs keep the exact pre-fault threefry counters
    ("core/faults.py", "fault_key"): frozenset({"fold_in"}),
    # random_payload resample from k_fault; the subset path goes through
    # sr_noise_for_rows so sender-subset draws match the dense gather
    ("core/faults.py", "corrupt_model"): frozenset({"uniform"}),
    # one uniform per message from k_fault picks the wire bit to flip
    ("core/faults.py", "bitflip_payload"): frozenset({"uniform"}),
    # centralized baselines (Section V): their own key chains, not part of
    # the gossip draw sequence but pinned for reproducibility all the same
    ("core/ensemble.py", "run_weighted_bagging"):
        frozenset({"split", "randint"}),
    ("core/ensemble.py", "run_sequential_pegasos"):
        frozenset({"split", "randint"}),
    # core/serving.py has NO entry on purpose: the serving tier draws its
    # query-assignment randomness from a host-side numpy stream
    # (serving.assign_queries), never from jax.random — a serving draw in
    # the threefry chain would shift the pinned per-cycle counters and
    # break cross-engine parity exactly the way this allowlist exists to
    # prevent. Keep it that way: a jax.random call appearing in
    # core/serving.py should fail this rule, not get registered here.
    # core/telemetry.py likewise has NO entry on purpose: telemetry is a
    # pure read (docs/CONTRACTS.md) — spans and histograms are
    # perf_counter host timing, metric streams re-read state the engines
    # already computed, and the armed collection paths in
    # core/simulation.py / core/sharded_engine.py add zero draws (the
    # emit_streams statics only widen what the existing fns RETURN). Any
    # jax.random call appearing in core/telemetry.py should fail this
    # rule, not get registered here.
}
