#!/usr/bin/env python
"""Retrace budget gate: fail when the engines compile more than they should.

The engines are built so that compilation cost is *bounded*: the reference
engine jits one cycle fn and one eval fn; the sharded engine compiles one
``_draw_chunk`` signature per (chunk length, N, scenario statics) and one
``chunk_fn`` signature per (chunk length, packed widths, N) — with the
sticky power-of-two width bucketing keeping the widths axis at O(log N)
signatures. A change that breaks any of this (chunk-length churn, widths
that never go sticky, a dtype flapping between chunks, a host scalar
smuggled into a traced signature) does not fail a test — every run still
converges — it just silently recompiles every chunk, and shows up weeks
later as a bench regression.

This gate makes that failure loud. It runs a small reference + sharded
suite (dense f32 / compact_all int8, the two packing extremes crossed with
the widest dtype gap), reads the jit compile-cache sizes via
``sharded_engine.retrace_counts()`` and ``_cache_size()`` on the reference
fns, and fails if

* any compile source exceeds its pinned ``BUDGETS`` entry (cold check),
* any compile source is missing from ``BUDGETS`` entirely (a new jitted fn
  must declare its budget here), or
* an identical warm rerun compiles *anything* (steady state must be
  zero-compile — the property the benchmarks' min-of-two timing relies on).

    PYTHONPATH=src python tools/lint/retrace_guard.py            # gate
    PYTHONPATH=src python tools/lint/retrace_guard.py --print-counts

Run by ``tools/run_tests.sh --bench-smoke`` next to the bench-regression
check; the contract is documented in docs/CONTRACTS.md.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path
from typing import Dict, List

REPO = Path(__file__).resolve().parent.parent.parent
sys.path.insert(0, str(REPO / "src"))

# chunk_fn labels carry a creation index ("3:scan/pegasos/dense/f32") so
# retrace_counts() never collides; budgets are pinned on the stable part
_IDX = re.compile(r"\[\d+:")

# Pinned compile budgets for the mini-suite below, keyed by normalized
# compile source. Raising a number here is an explicit, reviewed act —
# justify it in the commit message (e.g. a new chunk signature axis).
BUDGETS: Dict[str, int] = {
    # reference engine: one cycle signature per emit_streams static — the
    # suite runs unarmed AND telemetry-armed legs, so exactly 2; eval
    # shapes are shared with the sharded engine (and unchanged by arming),
    # so _eval compiles once across the whole suite
    "simulation.simulate_cycle": 2,
    "simulation._eval": 1,
    # sharded control plane: one signature per scenario statics
    # (drop/delay/sampler) x chunk length — the suite uses one scenario
    # and one chunk length
    "sharded_engine._draw_chunk": 1,
    # data plane: one signature per chunk length; the f32 dense config
    "sharded_engine.chunk_fn[mu/pegasos/dense/f32]": 1,
    # ... the telemetry-armed variant of the same config (distinct label:
    # armed chunk fns return per-cycle stream arrays, unarmed runs never
    # build it — the bitwise-invisibility contract of docs/CONTRACTS.md)
    "sharded_engine.chunk_fn[mu/pegasos/dense/f32/telem]": 1,
    # ... and the int8 compact_all config: packed widths are sticky
    # power-of-two buckets, so a short run sees at most 2 width buckets
    # before sticking
    "sharded_engine.chunk_fn[mu/pegasos/compact_all/int8]": 2,
    # serving tier: one signature per (N, batch, d) — the suite serves one
    # fixed-shape batch from both engines' snapshots (identical shapes, so
    # each path compiles once); the Pallas voted_predict_batched cache is
    # counted separately from its serve_voted_kernel wrapper
    "serving.serve_fresh": 1,
    "serving.serve_voted": 1,
    "serving.serve_voted_kernel": 1,
    "kernels.voted_predict_batched": 1,
}


def normalize(key: str) -> str:
    """Strip the per-instance index from chunk_fn labels."""
    return _IDX.sub("[", key)


def check_budgets(counts: Dict[str, int],
                  budgets: Dict[str, int]) -> List[str]:
    """Compare observed compile counts against pinned budgets.

    Returns human-readable error strings: over-budget sources, and sources
    with no budget entry at all (every jitted hot-path fn must be pinned).
    Pure function of its arguments — unit-tested in tests/test_lint.py."""
    errors: List[str] = []
    totals: Dict[str, int] = {}
    for key, n in counts.items():
        norm = normalize(key)
        totals[norm] = totals.get(norm, 0) + n
    for key in sorted(totals):
        n = totals[key]
        if key not in budgets:
            if n:
                errors.append(
                    f"{key}: {n} compile(s) from an unbudgeted source — "
                    f"add a pinned entry to retrace_guard.BUDGETS")
        elif n > budgets[key]:
            errors.append(f"{key}: {n} compile(s) > budget {budgets[key]} "
                          f"— the hot path is retracing")
    return errors


def diff_counts(cold: Dict[str, int], warm: Dict[str, int]) -> List[str]:
    """Error strings for every source that compiled during the warm rerun."""
    errors = []
    for key in sorted(warm):
        grew = warm[key] - cold.get(key, 0)
        if grew > 0:
            errors.append(f"{key}: {grew} new compile(s) on an identical "
                          f"warm rerun — steady state must be zero-compile")
    return errors


def snapshot() -> Dict[str, int]:
    """Current compile-cache sizes of every budgeted hot-path fn."""
    from repro.core import serving, sharded_engine, simulation
    from repro.kernels import voted_predict
    counts = dict(sharded_engine.retrace_counts())
    counts["simulation.simulate_cycle"] = \
        simulation.simulate_cycle._cache_size()
    counts["simulation._eval"] = simulation._eval._cache_size()
    counts["serving.serve_fresh"] = serving.serve_fresh._cache_size()
    counts["serving.serve_voted"] = serving.serve_voted._cache_size()
    counts["serving.serve_voted_kernel"] = \
        serving.serve_voted_kernel._cache_size()
    counts["kernels.voted_predict_batched"] = \
        voted_predict.voted_predict_batched._cache_size()
    return counts


def _mini_suite():
    """One reference run + the two sharded packing extremes, tiny sizes."""
    import dataclasses

    import numpy as np

    from repro.configs.gossip_linear import (GossipLinearConfig,
                                             with_failure_scenario)
    from repro.core.simulation import run_simulation
    from repro.data.synthetic import make_linear_dataset

    n, d = 256, 8
    rng = np.random.default_rng(0)
    X, y = make_linear_dataset(rng, n + 128, d, noise=0.07, separation=2.5)
    X, Xt, y, yt = X[:n], X[n:], y[:n], y[n:]
    cfg = with_failure_scenario(
        GossipLinearConfig(name="retrace-guard", dim=d, n_nodes=n,
                           n_test=128, class_ratio=(1, 1), lam=1e-3,
                           variant="mu", cache_size=4),
        "sparse-d0.5-o0.3")
    kw = dict(cycles=20, eval_every=10, seed=0, k_rounds=2)
    # serving tier rides on the reference + dense-sharded runs: both
    # engines' snapshots have identical shapes, and the query batch is
    # fixed-shape, so each serve path must compile exactly once
    from repro.launch.gossip_serve import GossipServer
    srv = GossipServer(batch_size=16, use_kernel=False)
    srv_k = GossipServer(batch_size=16, use_kernel=True)
    Xq = Xt[:16]

    def serve_hook(cycle, snap):
        for s in (srv, srv_k):
            s.serve_hook(cycle, snap)
            s.submit(Xq)

    run_simulation(cfg, X, y, Xt, yt, serve_hook=serve_hook, **kw)
    run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                   compact_rounds=False, serve_hook=serve_hook, **kw)
    cfg_q = dataclasses.replace(cfg, wire_dtype="int8")
    run_simulation(cfg_q, X, y, Xt, yt, engine="sharded",
                   compact_mode="compact_all", **kw)
    # telemetry-armed legs: arming is a compile-time static (emit_streams
    # on the reference cycle fn, a "/telem" chunk-fn variant on the
    # sharded engine), so each armed config costs exactly one extra
    # signature — and a warm armed rerun must compile nothing, like every
    # other leg
    from repro.core.telemetry import Telemetry
    run_simulation(cfg, X, y, Xt, yt, telemetry=Telemetry(), **kw)
    run_simulation(cfg, X, y, Xt, yt, engine="sharded",
                   compact_rounds=False, telemetry=Telemetry(), **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--print-counts", action="store_true",
                    help="show observed compile counts and exit")
    args = ap.parse_args(argv)

    _mini_suite()
    cold = snapshot()
    if args.print_counts:
        for key in sorted(cold):
            print(f"{cold[key]:4d}  {key}")
        return 0

    errors = check_budgets(cold, BUDGETS)
    _mini_suite()                      # identical rerun: must not compile
    errors += diff_counts(cold, snapshot())

    for e in errors:
        print(f"retrace-guard: {e}")
    if errors:
        print(f"retrace-guard: {len(errors)} violation(s)")
        return 1
    total = sum(cold.values())
    print(f"retrace-guard: OK ({total} compiles across "
          f"{len(cold)} sources, all within budget; warm rerun clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
