#!/usr/bin/env python
"""Perf-regression guard: compare fresh benchmark runs against the
committed ``BENCH_*.json`` baselines.

Usage (what ``tools/run_tests.sh --bench-smoke`` does):

    cp BENCH_population_scaling.json /tmp/pop.json     # before the bench
    cp BENCH_wire_quantization.json /tmp/wire.json
    cp BENCH_serving.json /tmp/serving.json
    python -m benchmarks.run --quick \
        --only population_scaling,wire_quantization,serving
    python tools/check_bench_regression.py \
        --pair /tmp/pop.json BENCH_population_scaling.json \
        --pair /tmp/wire.json BENCH_wire_quantization.json \
        --pair /tmp/serving.json BENCH_serving.json [--tolerance 0.4]

``--pair BASELINE CURRENT`` may repeat; the legacy single
``--baseline``/``--current`` spelling still works. Rows are matched on
(engine, scenario, n_nodes, wire_dtype, fault_model, byzantine_frac,
defense) — the wire-quantization rows carry no engine/scenario/fault
columns and match on (N, codec) alone. Cycle counts may differ
between --quick and full runs, but node-cycles/sec is a rate, so the
comparison stays meaningful. A current rate below ``tolerance`` × the
baseline rate fails loudly (exit 1) listing every regressed row; rows only
present on one side are reported but never fail (the sweeps differ between
quick and full mode). The tolerance band is deliberately wide: it catches
"the engine got 2.5× slower" regressions, not CPU-container noise.

Rows whose measured work (``n_nodes × cycles``) falls below
``MIN_NODE_CYCLES`` on either side are reported but never fail: at small N
the "rate" is fixed per-run overhead (host routing, dispatch, eval), and a
20-cycle quick run legitimately amortizes it ~2.5× worse than the 50-cycle
full baseline — a rate mismatch there says nothing about the engine.

Also guards every file's ``parity_bitwise`` probe: any wire codec whose
cross-engine curves stopped being bitwise-identical fails regardless of
speed — for the wire bench that covers the full codec registry, and for
the serving bench the snapshot engine-parity / Pallas-kernel-vs-jnp /
serving-never-perturbs probes, and for the telemetry-overhead bench the
armed-invisibility probes. Rows
carrying a ``retraces`` field (compiles triggered per bench row) are
diffed informationally — the hard compile-count gate is
``tools/lint/retrace_guard.py``.

Files whose ``derived`` block carries ``telemetry_overhead_ratio``
(BENCH_telemetry_overhead.json) get one extra rule: the current armed/
unarmed ratio must not exceed ``OVERHEAD_SLACK`` × the committed
baseline's ratio — so telemetry that silently got more expensive fails
even while both arms individually clear the rate tolerance. (The ≤ 5%
absolute acceptance criterion lives in the committed full-run baseline
itself, recorded as ``derived.overhead_within_ceiling``.)
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


# rate comparisons need the run to be throughput-dominated, not
# overhead-dominated: below ~10^6 node-cycles a run is mostly fixed cost
MIN_NODE_CYCLES = 1_000_000

# the armed/unarmed telemetry ratio may drift this much vs the committed
# baseline before the smoke gate fails (container noise on a ~1.0 ratio)
OVERHEAD_SLACK = 1.10


def row_key(row: dict):
    # fault_model is null for fault-free rows — normalize so mixed keys
    # stay sortable
    return (row.get("engine"), row.get("scenario", "extreme"),
            row.get("n_nodes"), row.get("wire_dtype") or "f32",
            row.get("fault_model") or "none",
            row.get("byzantine_frac") or 0.0,
            row.get("defense") or "none")


def node_cycles(row: dict) -> int:
    return int(row.get("n_nodes") or 0) * int(row.get("cycles") or 0)


def check_pair(base_fp: Path, cur_fp: Path, tolerance: float,
               failures: list) -> None:
    label = cur_fp.name
    cur = json.loads(cur_fp.read_text())    # a broken CURRENT run is an error

    # the parity probes need no baseline — a broken cross-engine bit
    # pattern in the CURRENT run fails even on a fresh tree
    for dtype, ok in cur.get("parity_bitwise", {}).items():
        if not ok:
            failures.append(f"  [{label}] parity_bitwise[{dtype}]: "
                            "cross-engine curves are no longer "
                            "bitwise-identical")

    base = None
    if not base_fp.is_file():
        print(f"check_bench_regression: no baseline at {base_fp} — skipping "
              f"{label} rate comparison (first run on a fresh tree)")
        return
    try:
        base = json.loads(base_fp.read_text())
    except ValueError:
        print(f"check_bench_regression: unparsable baseline at {base_fp} — "
              "treating as missing, skipping rate comparison")
        return

    # telemetry-overhead rule: the armed/unarmed ratio must not creep up
    # relative to the committed baseline (a drift check on a ~1.0 number,
    # independent of how fast the container happens to be today)
    cratio = cur.get("derived", {}).get("telemetry_overhead_ratio")
    bratio = base.get("derived", {}).get("telemetry_overhead_ratio")
    if cratio is not None and bratio is not None:
        verdict = "ok"
        if cratio > OVERHEAD_SLACK * bratio:
            verdict = "REGRESSED"
            failures.append(
                f"  [{label}] telemetry_overhead_ratio: {cratio:.3f}x vs "
                f"baseline {bratio:.3f}x (exceeds {OVERHEAD_SLACK}x slack "
                "— armed telemetry got more expensive)")
        print(f"check_bench_regression: [{label}] telemetry_overhead_ratio "
              f"{cratio:.3f}x vs baseline {bratio:.3f}x ({verdict})")

    base_rows = {row_key(r): r for r in base.get("rows", [])}
    cur_rows = {row_key(r): r for r in cur.get("rows", [])}

    compared = 0
    small = 0
    for key, crow in sorted(cur_rows.items()):
        brow = base_rows.get(key)
        if brow is None:
            continue
        b, c = brow["node_cycles_per_sec"], crow["node_cycles_per_sec"]
        if min(node_cycles(brow), node_cycles(crow)) < MIN_NODE_CYCLES:
            small += 1
            print(f"check_bench_regression: [{label}] "
                  f"{'/'.join(str(k) for k in key)}: "
                  f"{c / b:.2f}x baseline (overhead-dominated run — "
                  "informational)")
            continue
        compared += 1
        verdict = "ok"
        if c < tolerance * b:
            verdict = "REGRESSED"
            failures.append(
                f"  [{label}] {'/'.join(str(k) for k in key)}: "
                f"{c:,.0f} node-cycles/s vs baseline {b:,.0f} "
                f"(ratio {c / b:.2f} < tolerance {tolerance})")
        print(f"check_bench_regression: [{label}] "
              f"{'/'.join(str(k) for k in key)}: "
              f"{c / b:.2f}x baseline ({verdict})")
    # retrace counts are informational here (quick vs full sweeps warm
    # different caches); the hard gate is tools/lint/retrace_guard.py
    for key, crow in sorted(cur_rows.items()):
        brow = base_rows.get(key)
        rb = (brow or {}).get("retraces")
        rc = crow.get("retraces")
        if rb is not None and rc is not None and rc > rb:
            print(f"check_bench_regression: [{label}] "
                  f"{'/'.join(str(k) for k in key)}: retraces {rb} -> {rc} "
                  "(informational — see tools/lint/retrace_guard.py)")
    skipped = len(cur_rows) - compared - small
    if skipped:
        print(f"check_bench_regression: [{label}] {skipped} row(s) without "
              "a baseline counterpart (sweep mismatch) — informational only")

    if compared == 0:
        print(f"check_bench_regression: [{label}] WARNING — no comparable "
              "rows between baseline and current run")
    else:
        print(f"check_bench_regression: [{label}] {compared} rows compared")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", nargs=2, action="append", default=[],
                    metavar=("BASELINE", "CURRENT"),
                    help="baseline/current JSON pair; may repeat")
    ap.add_argument("--baseline", help="legacy single-pair spelling")
    ap.add_argument("--current", default="BENCH_population_scaling.json")
    ap.add_argument("--tolerance", type=float, default=0.4,
                    help="fail when current rate < tolerance * baseline")
    args = ap.parse_args()

    pairs = [(Path(b), Path(c)) for b, c in args.pair]
    if args.baseline:
        pairs.append((Path(args.baseline), Path(args.current)))
    if not pairs:
        ap.error("need --pair (or --baseline/--current)")

    failures: list = []
    for base_fp, cur_fp in pairs:
        check_pair(base_fp, cur_fp, args.tolerance, failures)

    if failures:
        print("check_bench_regression: PERF REGRESSION DETECTED:")
        for f in failures:
            print(f)
        return 1
    print("check_bench_regression: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
