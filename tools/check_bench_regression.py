#!/usr/bin/env python
"""Perf-regression guard: compare a fresh population-scaling bench run
against the committed ``BENCH_population_scaling.json`` baseline.

Usage (what ``tools/run_tests.sh --bench-smoke`` does):

    cp BENCH_population_scaling.json /tmp/baseline.json   # before the bench
    python -m benchmarks.run --quick --only population_scaling
    python tools/check_bench_regression.py \
        --baseline /tmp/baseline.json \
        --current BENCH_population_scaling.json [--tolerance 0.4]

Rows are matched on (engine, scenario, n_nodes, wire_dtype) — cycle counts
may differ between --quick and full runs, but node-cycles/sec is a rate, so
the comparison stays meaningful. A current rate below ``tolerance`` × the
baseline rate fails loudly (exit 1) listing every regressed row; rows only
present on one side are reported but never fail (the sweeps differ between
quick and full mode). The tolerance band is deliberately wide: it catches
"the engine got 2.5× slower" regressions, not CPU-container noise.

Also guards the ``parity_bitwise`` probe: any wire dtype whose cross-engine
curves stopped being bitwise-identical fails regardless of speed.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def row_key(row: dict):
    return (row.get("engine"), row.get("scenario", "extreme"),
            row.get("n_nodes"), row.get("wire_dtype", "f32"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", default="BENCH_population_scaling.json")
    ap.add_argument("--tolerance", type=float, default=0.4,
                    help="fail when current rate < tolerance * baseline")
    args = ap.parse_args()

    base_fp, cur_fp = Path(args.baseline), Path(args.current)
    if not base_fp.is_file():
        print(f"check_bench_regression: no baseline at {base_fp} — skipping "
              "(first run on a fresh tree)")
        return 0
    try:
        base = json.loads(base_fp.read_text())
    except ValueError:
        print(f"check_bench_regression: unparsable baseline at {base_fp} — "
              "treating as missing, skipping")
        return 0
    cur = json.loads(cur_fp.read_text())    # a broken CURRENT run is an error

    base_rows = {row_key(r): r for r in base.get("rows", [])}
    cur_rows = {row_key(r): r for r in cur.get("rows", [])}

    failures = []
    compared = 0
    for key, crow in sorted(cur_rows.items()):
        brow = base_rows.get(key)
        if brow is None:
            continue
        compared += 1
        b, c = brow["node_cycles_per_sec"], crow["node_cycles_per_sec"]
        verdict = "ok"
        if c < args.tolerance * b:
            verdict = "REGRESSED"
            failures.append(
                f"  {'/'.join(str(k) for k in key)}: "
                f"{c:,.0f} node-cycles/s vs baseline {b:,.0f} "
                f"(ratio {c / b:.2f} < tolerance {args.tolerance})")
        print(f"check_bench_regression: {'/'.join(str(k) for k in key)}: "
              f"{c / b:.2f}x baseline ({verdict})")
    skipped = len(cur_rows) - compared
    if skipped:
        print(f"check_bench_regression: {skipped} row(s) without a baseline "
              "counterpart (sweep mismatch) — informational only")

    for dtype, ok in cur.get("parity_bitwise", {}).items():
        if not ok:
            failures.append(f"  parity_bitwise[{dtype}]: cross-engine "
                            "curves are no longer bitwise-identical")

    if compared == 0:
        print("check_bench_regression: WARNING — no comparable rows between "
              "baseline and current run")
    if failures:
        print("check_bench_regression: PERF REGRESSION DETECTED:")
        for f in failures:
            print(f)
        return 1
    print(f"check_bench_regression: OK ({compared} rows within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
