#!/usr/bin/env python
"""Summarize an exported Chrome trace (Telemetry.export_chrome_trace).

Standalone — reads only the JSON file (no engine imports, no jax), so a
trace exported on one machine can be summarized anywhere:

    PYTHONPATH=src python tools/trace_report.py results/trace.json

Prints the per-phase wall-clock table (time, %, span counts, jit
compiles), the compile events, and the per-cycle stream summary
(messages + wire bytes per cycle, message-economy balance check) that
``otherData.streams`` carries. The same numbers an armed run prints live
via ``Telemetry.phase_report()`` — this is the offline twin for committed
trace files. View the trace itself at https://ui.perfetto.dev (open the
JSON file directly).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def summarize(payload: dict) -> str:
    events = payload.get("traceEvents", [])
    other = payload.get("otherData", {})
    lines = []

    label = other.get("label") or "(unlabeled)"
    spans = [e for e in events if e.get("ph") == "X"]
    compiles = [e for e in events if e.get("ph") == "i"
                and e.get("cat") == "compile"]
    lines.append(f"trace: {label} — {len(spans)} spans, "
                 f"{other.get('compile_total', 0)} jit compiles")

    # per-phase table (complete events carry ts/dur in microseconds)
    if spans:
        t0 = min(e["ts"] for e in spans)
        t1 = max(e["ts"] + e["dur"] for e in spans)
        wall = (t1 - t0) / 1e6
        per: dict = {}
        for e in spans:
            name = e["name"]
            secs, cnt, comp = per.get(name, (0.0, 0, 0))
            per[name] = (secs + e["dur"] / 1e6, cnt + 1,
                         comp + int(e.get("args", {}).get("compiles", 0)))
        lines.append(f"phases ({wall:.3f}s spanned wall clock):")
        for name, (secs, cnt, comp) in sorted(per.items(),
                                              key=lambda kv: -kv[1][0]):
            pct = 100.0 * secs / wall if wall > 0 else 0.0
            lines.append(f"  {name:<16} {secs:>9.3f}s {pct:>5.1f}%  "
                         f"x{cnt:<5d} compiles={comp}")
    if compiles:
        lines.append(f"compile events: {len(compiles)}")
        for e in compiles:
            lines.append(f"  {e['ts'] / 1e6:>9.3f}s  {e['name']}")

    # stream summary + the continuously-emitted balance invariant
    streams = other.get("streams", {})
    sent = streams.get("sent", [])
    if sent:
        cycles = len(sent)
        delivered = streams.get("delivered", [])
        wire = streams.get("wire_bytes", [])
        in_flight = streams.get("in_flight", [])
        lines.append(
            f"streams: {cycles} cycles, "
            f"{sum(sent):,} sent ({sum(sent) / cycles:,.0f}/cycle), "
            f"{sum(delivered):,} delivered, "
            f"{sum(wire) / cycles:,.0f} wire B/cycle")
        balance = 0
        ok = True
        for c in range(cycles):
            balance += (sent[c] - delivered[c] - streams["lost"][c]
                        - streams["overflow"][c])
            ok = ok and balance == in_flight[c] and in_flight[c] >= 0
        lines.append(
            f"message economy: in_flight ends at {in_flight[-1]:,}; "
            f"balance invariant {'OK' if ok else 'VIOLATED'}")
        if not ok:
            lines.append("  ERROR: cumulative sent-delivered-lost-overflow "
                         "disagrees with the in_flight stream")
    ef = streams.get("ef_residual_rms", [])
    if ef and any(ef):
        lines.append(f"ef_residual_rms: {ef[0]:.3e} -> {ef[-1]:.3e} "
                     f"over {len(ef)} eval points")

    for name, h in sorted(other.get("histograms", {}).items()):
        if h.get("count"):
            lines.append(
                f"hist {name}: n={h['count']} "
                f"p50={h['p50_s'] * 1e3:.3f}ms p90={h['p90_s'] * 1e3:.3f}ms "
                f"p99={h['p99_s'] * 1e3:.3f}ms "
                f"p999={h['p999_s'] * 1e3:.3f}ms "
                f"({len(h.get('bucket_counts', []))} occupied buckets)")

    for run in other.get("annotations", {}).get("runs", []):
        lines.append("run: " + ", ".join(
            f"{k}={v}" for k, v in run.items()))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON exported by "
                                  "Telemetry.export_chrome_trace")
    args = ap.parse_args(argv)
    fp = Path(args.trace)
    if not fp.exists():
        print(f"trace_report: no such file: {fp}", file=sys.stderr)
        return 2
    payload = json.loads(fp.read_text())
    if "traceEvents" not in payload:
        print(f"trace_report: {fp} is not a Chrome trace "
              f"(no traceEvents key)", file=sys.stderr)
        return 2
    print(summarize(payload))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
