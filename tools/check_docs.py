#!/usr/bin/env python
"""Docs-link checker: fail when a doc references a symbol or file that no
longer exists in the tree.

Scans the markdown docs (docs/*.md, README.md) for inline-code spans and
verifies, with a grep pass over the source tree, that every code-looking
token still resolves:

* path-like tokens (contain "/" or end in .py/.sh/.md/.json) must exist as
  files or directories relative to the repo root;
* dotted names rooted at a package (``repro.core.simulation.run_simulation``)
  must resolve to a module file under src/ (or benchmarks/, tools/), and any
  trailing attribute must appear in that module's source;
* plain identifiers that look like symbols (contain "_" or "." or are
  CamelCase, length >= 4) must appear somewhere in the source corpus.

Everything else (shell flags, config prose, math) is ignored.

It also cross-checks the **wire-codec registry** against the docs: the
codec table in docs/ENGINES.md (fenced by ``wire-codec-table`` markers)
must name every codec registered in ``repro.core.wire_codec.WIRE_CODECS``,
and must not name a codec that is not registered — so the codec docs
cannot go stale in either direction. The **fault-model and defense
tables** in docs/ENGINES.md (``fault-model-table`` / ``defense-table``
markers) are held to the same standard against
``repro.core.faults.FAULT_MODELS`` / ``DEFENSES``, as is the
**repro-lint rule table** in docs/CONTRACTS.md (``lint-rule-table``
markers) against ``tools/lint/rules.RULES``, and the **metric-stream
table** in docs/OBSERVABILITY.md (``metric-stream-table`` markers)
against ``repro.core.telemetry.METRIC_STREAMS``.

Run directly or via tools/run_tests.sh; exits non-zero listing every stale
reference.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "README.md"]
SOURCE_DIRS = ["src", "benchmarks", "tools", "tests", "examples"]
SOURCE_EXT = {".py", ".sh"}

CODE_SPAN = re.compile(r"`([^`]+)`")
TOKEN = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*$")
CAMEL = re.compile(r"^[A-Z][a-z0-9]+[A-Z]")


def source_corpus() -> str:
    parts = []
    for d in SOURCE_DIRS:
        for fp in sorted((REPO / d).rglob("*")):
            if fp.suffix in SOURCE_EXT and fp.is_file():
                parts.append(fp.read_text(errors="ignore"))
    return "\n".join(parts)


def module_file(dotted: str):
    """Longest prefix of a dotted name that is a module/package under the
    import roots; returns (path, remainder_components) or None."""
    comps = dotted.split(".")
    for root in ("src", "."):
        for cut in range(len(comps), 0, -1):
            base = REPO / root / Path(*comps[:cut])
            if base.with_suffix(".py").is_file():
                return base.with_suffix(".py"), comps[cut:]
            if base.is_dir() and (base / "__init__.py").is_file():
                return base / "__init__.py", comps[cut:]
    return None


def looks_like_symbol(tok: str) -> bool:
    return (len(tok) >= 4 and TOKEN.match(tok) is not None
            and ("_" in tok or "." in tok or CAMEL.match(tok) is not None))


def check_token(tok: str, corpus: str):
    """Returns an error string, or None if the token resolves (or is not a
    checkable code token)."""
    tok = tok.strip().rstrip(",.;:")
    # path-like: file.py, docs/ENGINES.md, tools/run_tests.sh, BENCH_x.json
    if "/" in tok or tok.endswith((".py", ".sh", ".md", ".json")):
        path = tok.split(":")[0].rstrip("/")          # strip :line refs
        if not re.fullmatch(r"[\w./-]+", path):
            return None
        if "." not in path and not (REPO / path.split("/")[0]).is_dir():
            return None       # prose like `sent/delivered/lost`, not a path
        if not (REPO / path).exists():
            return f"missing file: {tok}"
        return None
    if not looks_like_symbol(tok):
        return None
    if "." in tok:
        hit = module_file(tok)
        if hit is not None:
            path, rest = hit
            src = path.read_text(errors="ignore")
            missing = [c for c in rest if c not in src]
            if missing:
                return f"symbol {'.'.join(missing)!r} not found in {path.relative_to(REPO)} (from `{tok}`)"
            return None
        # not module-rooted (jax.random.split, cfg.wire_dtype, …):
        # every component should still appear somewhere in the corpus
        tail = tok.split(".")[-1].replace("()", "")
        if len(tail) >= 4 and tail not in corpus:
            return f"symbol not found in source: {tok}"
        return None
    if tok.replace("()", "") not in corpus:
        return f"symbol not found in source: {tok}"
    return None


CODEC_TABLE = re.compile(
    r"<!--\s*wire-codec-table:begin\s*-->(.*?)"
    r"<!--\s*wire-codec-table:end\s*-->", re.S)


def registered_codecs():
    """The wire-codec registry, imported from the source tree (not an
    installed package): the set of names the docs must mirror."""
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.core.wire_codec import WIRE_CODECS
        return set(WIRE_CODECS)
    finally:
        sys.path.pop(0)


def check_codec_registry(errors: list) -> None:
    """Registry <-> docs consistency, both directions."""
    doc = REPO / "docs" / "ENGINES.md"
    text = doc.read_text() if doc.is_file() else ""
    m = CODEC_TABLE.search(text)
    if not m:
        errors.append("docs/ENGINES.md: missing the "
                      "<!-- wire-codec-table:begin/end --> markers around "
                      "the codec table")
        return
    # first backticked token of each table row = the codec name column
    doc_names = set()
    for line in m.group(1).splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cell = line.split("|")[1]
        doc_names.update(re.findall(r"`([A-Za-z0-9_]+)`", cell))
    doc_names.discard("None")         # the f32 alias in prose
    registered = registered_codecs()
    for name in sorted(registered - doc_names):
        errors.append(f"docs/ENGINES.md: registered wire codec {name!r} "
                      "missing from the codec table")
    for name in sorted(doc_names - registered):
        errors.append(f"docs/ENGINES.md: codec table names {name!r}, which "
                      "is not a registered wire codec")


FAULT_TABLE = re.compile(
    r"<!--\s*fault-model-table:begin\s*-->(.*?)"
    r"<!--\s*fault-model-table:end\s*-->", re.S)
DEFENSE_TABLE = re.compile(
    r"<!--\s*defense-table:begin\s*-->(.*?)"
    r"<!--\s*defense-table:end\s*-->", re.S)


def registered_faults():
    """The fault-model registry + defense tuple, imported from the source
    tree: the sets the docs must mirror."""
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.core.faults import DEFENSES, FAULT_MODELS
        return set(FAULT_MODELS), set(DEFENSES)
    finally:
        sys.path.pop(0)


def _table_names(table_text: str, pattern: str = r"`([A-Za-z0-9_]+)`"):
    """First backticked token of each table row = the name column."""
    names = set()
    for line in table_text.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        names.update(re.findall(pattern, line.split("|")[1]))
    return names


def check_fault_registry(errors: list) -> None:
    """Fault/defense registries <-> docs consistency, both directions."""
    doc = REPO / "docs" / "ENGINES.md"
    text = doc.read_text() if doc.is_file() else ""
    faults, defenses = registered_faults()
    m = FAULT_TABLE.search(text)
    if not m:
        errors.append("docs/ENGINES.md: missing the "
                      "<!-- fault-model-table:begin/end --> markers around "
                      "the fault-model table")
    else:
        doc_names = _table_names(m.group(1))
        for name in sorted(faults - doc_names):
            errors.append(f"docs/ENGINES.md: registered fault model "
                          f"{name!r} missing from the fault-model table")
        for name in sorted(doc_names - faults):
            errors.append(f"docs/ENGINES.md: fault-model table names "
                          f"{name!r}, which is not a registered fault model")
    m = DEFENSE_TABLE.search(text)
    if not m:
        errors.append("docs/ENGINES.md: missing the "
                      "<!-- defense-table:begin/end --> markers around "
                      "the defense table")
    else:
        doc_names = _table_names(m.group(1))
        for name in sorted(defenses - doc_names):
            errors.append(f"docs/ENGINES.md: registered defense {name!r} "
                          "missing from the defense table")
        for name in sorted(doc_names - defenses):
            errors.append(f"docs/ENGINES.md: defense table names {name!r}, "
                          "which is not a registered defense")


LINT_TABLE = re.compile(
    r"<!--\s*lint-rule-table:begin\s*-->(.*?)"
    r"<!--\s*lint-rule-table:end\s*-->", re.S)


def registered_lint_rules():
    """The repro-lint rule registry, imported from tools/lint: the set of
    rule names the CONTRACTS.md table must mirror."""
    sys.path.insert(0, str(REPO / "tools"))
    try:
        from lint.rules import RULES
        return set(RULES)
    finally:
        sys.path.pop(0)


def check_lint_rules(errors: list) -> None:
    """Rule registry <-> docs/CONTRACTS.md consistency, both directions."""
    doc = REPO / "docs" / "CONTRACTS.md"
    text = doc.read_text() if doc.is_file() else ""
    m = LINT_TABLE.search(text)
    if not m:
        errors.append("docs/CONTRACTS.md: missing the "
                      "<!-- lint-rule-table:begin/end --> markers around "
                      "the rule table")
        return
    doc_names = set()
    for line in m.group(1).splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cell = line.split("|")[1]
        doc_names.update(re.findall(r"`([A-Za-z0-9_-]+)`", cell))
    registered = registered_lint_rules()
    for name in sorted(registered - doc_names):
        errors.append(f"docs/CONTRACTS.md: repro-lint rule {name!r} "
                      "missing from the rule table")
    for name in sorted(doc_names - registered):
        errors.append(f"docs/CONTRACTS.md: rule table names {name!r}, "
                      "which is not a registered repro-lint rule")


METRIC_TABLE = re.compile(
    r"<!--\s*metric-stream-table:begin\s*-->(.*?)"
    r"<!--\s*metric-stream-table:end\s*-->", re.S)


def registered_metric_streams():
    """The telemetry metric-stream registry, imported from the source
    tree: the set of stream names docs/OBSERVABILITY.md must mirror."""
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.core.telemetry import METRIC_STREAMS
        return set(METRIC_STREAMS)
    finally:
        sys.path.pop(0)


def check_metric_registry(errors: list) -> None:
    """Metric-stream registry <-> docs/OBSERVABILITY.md, both directions."""
    doc = REPO / "docs" / "OBSERVABILITY.md"
    text = doc.read_text() if doc.is_file() else ""
    m = METRIC_TABLE.search(text)
    if not m:
        errors.append("docs/OBSERVABILITY.md: missing the "
                      "<!-- metric-stream-table:begin/end --> markers "
                      "around the metric-stream table")
        return
    doc_names = _table_names(m.group(1))
    registered = registered_metric_streams()
    for name in sorted(registered - doc_names):
        errors.append(f"docs/OBSERVABILITY.md: registered metric stream "
                      f"{name!r} missing from the metric-stream table")
    for name in sorted(doc_names - registered):
        errors.append(f"docs/OBSERVABILITY.md: metric-stream table names "
                      f"{name!r}, which is not a registered metric stream")


def main() -> int:
    corpus = source_corpus()
    errors = []
    check_codec_registry(errors)
    check_fault_registry(errors)
    check_lint_rules(errors)
    check_metric_registry(errors)
    for doc in DOC_FILES:
        if not doc.is_file():
            continue
        for lineno, line in enumerate(doc.read_text().splitlines(), 1):
            for span in CODE_SPAN.findall(line):
                # multi-token spans (commands, signatures): check each token
                for raw in re.split(r"[\s=(),]+", span):
                    err = check_token(raw, corpus)
                    if err:
                        errors.append(
                            f"{doc.relative_to(REPO)}:{lineno}: {err}")
    if errors:
        print("check_docs: stale documentation references:")
        for e in errors:
            print("  " + e)
        return 1
    print(f"check_docs: OK ({len(DOC_FILES)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
