#!/usr/bin/env bash
# Tier-1 test runner (referenced from ROADMAP.md).
#
#   tools/run_tests.sh               full tier-1 suite
#   tools/run_tests.sh --fast        inner-loop subset (skips the slow
#                                    model-zoo and perf-profile suites)
#   tools/run_tests.sh --bench-smoke fast subset, then the population-scaling,
#                                    wire-quantization, robustness, serving
#                                    and telemetry-overhead benchmarks in
#                                    --quick mode (refreshing
#                                    BENCH_population_scaling.json /
#                                    BENCH_wire_quantization.json /
#                                    BENCH_robustness.json /
#                                    BENCH_serving.json /
#                                    BENCH_telemetry_overhead.json), then
#                                    tools/check_bench_regression.py compares
#                                    the fresh rates of ALL five benches
#                                    against the committed BENCH_*.json
#                                    baselines — an engine perf regression
#                                    (or a broken cross-engine wire-codec /
#                                    fault-model / serving-snapshot /
#                                    telemetry-invisibility parity probe, or
#                                    an armed-telemetry overhead ratio past
#                                    1.10x the committed one) fails loudly
#
# Every mode first runs tools/check_docs.py (a doc referencing a removed
# symbol fails tier 1) and tools/lint/run.py (repro-lint: the parity
# contracts in docs/CONTRACTS.md — RNG discipline, shard_map spec arity,
# merge-dtype purity, tracer leaks, codec literals — are machine-checked
# on every run). --bench-smoke additionally runs the retrace budget gate
# (tools/lint/retrace_guard.py): the engines must not compile more
# signatures than their pinned budgets, and a warm rerun must compile
# nothing.
#
# Installs the optional test extras (hypothesis) when an installer and
# network are available; the suite degrades gracefully without them
# (tests/test_merge_properties.py skips; tests/test_merge_equivalences.py
# keeps the Section V equivalences covered).
set -euo pipefail
cd "$(dirname "$0")/.."

python tools/check_docs.py
python tools/lint/run.py

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    echo "run_tests: hypothesis not installed; trying to install (best-effort)"
    python -m pip install --quiet hypothesis >/dev/null 2>&1 \
        || echo "run_tests: pip install failed (offline?) — property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    shift
    exec python -m pytest -x -q -k "not models and not perf" "$@"
fi
if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    python -m pytest -x -q -k "not models and not perf" "$@"
    python tools/lint/retrace_guard.py
    # snapshot the committed baselines BEFORE the quick benches overwrite
    # them, then fail loudly if the fresh rates regressed past the
    # tolerance band (or a wire-codec parity probe broke)
    baseline="$(mktemp /tmp/bench_baseline.XXXXXX.json)"
    wire_baseline="$(mktemp /tmp/wire_baseline.XXXXXX.json)"
    robust_baseline="$(mktemp /tmp/robust_baseline.XXXXXX.json)"
    serving_baseline="$(mktemp /tmp/serving_baseline.XXXXXX.json)"
    telem_baseline="$(mktemp /tmp/telem_baseline.XXXXXX.json)"
    trap 'rm -f "$baseline" "$wire_baseline" "$robust_baseline" "$serving_baseline" "$telem_baseline"' EXIT
    # mktemp pre-creates an EMPTY file: remove it so a tree without a
    # committed baseline takes the checker's "no baseline" skip path
    # instead of failing to parse zero bytes of JSON
    rm -f "$baseline" "$wire_baseline" "$robust_baseline" "$serving_baseline" "$telem_baseline"
    cp BENCH_population_scaling.json "$baseline" 2>/dev/null || true
    cp BENCH_wire_quantization.json "$wire_baseline" 2>/dev/null || true
    cp BENCH_robustness.json "$robust_baseline" 2>/dev/null || true
    cp BENCH_serving.json "$serving_baseline" 2>/dev/null || true
    cp BENCH_telemetry_overhead.json "$telem_baseline" 2>/dev/null || true
    python -m benchmarks.run --quick \
        --only population_scaling,wire_quantization,robustness,serving,telemetry_overhead
    python tools/check_bench_regression.py \
        --pair "$baseline" BENCH_population_scaling.json \
        --pair "$wire_baseline" BENCH_wire_quantization.json \
        --pair "$robust_baseline" BENCH_robustness.json \
        --pair "$serving_baseline" BENCH_serving.json \
        --pair "$telem_baseline" BENCH_telemetry_overhead.json
    exit 0
fi
exec python -m pytest -x -q "$@"
