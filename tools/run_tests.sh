#!/usr/bin/env bash
# Tier-1 test runner (referenced from ROADMAP.md).
#
#   tools/run_tests.sh               full tier-1 suite
#   tools/run_tests.sh --fast        inner-loop subset (skips the slow
#                                    model-zoo and perf-profile suites)
#   tools/run_tests.sh --bench-smoke fast subset, then the population-scaling
#                                    and wire-quantization benchmarks in
#                                    --quick mode — an engine perf regression
#                                    fails loudly (and refreshes
#                                    BENCH_population_scaling.json /
#                                    BENCH_wire_quantization.json)
#
# Every mode first runs tools/check_docs.py, so a doc referencing a removed
# symbol fails tier 1.
#
# Installs the optional test extras (hypothesis) when an installer and
# network are available; the suite degrades gracefully without them
# (tests/test_merge_properties.py skips; tests/test_merge_equivalences.py
# keeps the Section V equivalences covered).
set -euo pipefail
cd "$(dirname "$0")/.."

python tools/check_docs.py

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    echo "run_tests: hypothesis not installed; trying to install (best-effort)"
    python -m pip install --quiet hypothesis >/dev/null 2>&1 \
        || echo "run_tests: pip install failed (offline?) — property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    shift
    exec python -m pytest -x -q -k "not models and not perf" "$@"
fi
if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    python -m pytest -x -q -k "not models and not perf" "$@"
    exec python -m benchmarks.run --quick \
        --only population_scaling,wire_quantization
fi
exec python -m pytest -x -q "$@"
