"""Multi-device verification of the MoE combine-before-reduce path (§Perf
B-4): combine='reduce' must equal combine='gather' through forward AND grad.

Runs on 8 placeholder host devices — outside pytest because the test suite
pins the device count to 1 (tests/conftest.py).

    PYTHONPATH=src python tools/verify_moe_reduce.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import MoEConfig
from repro.models.moe import moe_ffn, moe_spec
from repro.models import layers as L
from repro.sharding.act import activation_sharding
from repro.utils.tree import tree_leaves_with_path


def main() -> None:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0,
                   dispatch_groups=2, sharding="tensor", combine="gather")
    mr = dataclasses.replace(mg, combine="reduce")
    params = L.init_params(jax.random.key(0), moe_spec(16, mg, "swiglu"))
    x = jax.random.normal(jax.random.key(1), (4, 8, 16), jnp.float32)

    with mesh, activation_sharding(mesh, ("data",)):
        og = jax.jit(lambda p, x: moe_ffn(p, mg, x, "swiglu")[0])(params, x)
        orr = jax.jit(lambda p, x: moe_ffn(p, mr, x, "swiglu")[0])(params, x)
        gg = jax.jit(jax.grad(
            lambda p, x: moe_ffn(p, mg, x, "swiglu")[0].sum()))(params, x)
        gr = jax.jit(jax.grad(
            lambda p, x: moe_ffn(p, mr, x, "swiglu")[0].sum()))(params, x)

    np.testing.assert_allclose(np.asarray(og), np.asarray(orr),
                               rtol=2e-5, atol=2e-5)
    for (path, a), (_, b) in zip(tree_leaves_with_path(gg),
                                 tree_leaves_with_path(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=str(path))
    print("OK: combine='reduce' == combine='gather' (forward + grad) "
          "on a 2x4 (data, model) mesh")


if __name__ == "__main__":
    main()
