import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS","") + " --xla_force_host_platform_device_count=512"
import sys, re, collections
import jax
from repro.config import get_config, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_step
from repro.launch.costs import _unrolled, _measure
from repro.launch import roofline as RL

arch = sys.argv[1] if len(sys.argv)>1 else "qwen3-1.7b"
shape_name = sys.argv[2] if len(sys.argv)>2 else "train_4k"
periods = int(sys.argv[3]) if len(sys.argv)>3 else 1
dist = sys.argv[4] if len(sys.argv)>4 else "allreduce"

cfg = get_config(arch)
from repro.launch.specs import resolve_variant
shape = INPUT_SHAPES[shape_name]
cfg, _ = resolve_variant(cfg, shape)
ucfg = _unrolled(cfg, periods)
mesh = make_production_mesh()
fn, arg_sds, in_sh, _ = build_step(ucfg, shape, mesh, dist=dist, optimizer="adamw")
with mesh:
    compiled = jax.jit(fn, in_shardings=in_sh).lower(*arg_sds).compile()
cost = compiled.cost_analysis()
if isinstance(cost, list): cost = cost[0]
print("flops/device", f"{cost.get('flops',0):.3e}", "bytes", f"{cost.get('bytes accessed',0):.3e}")
for k,v in sorted(cost.items(), key=lambda kv:-abs(kv[1]) if isinstance(kv[1],float) else 0)[:10]:
    print("  ", k, f"{v:.3e}" if isinstance(v,float) else v)
text = compiled.as_text()
# top collectives by result size
rows=[]
for line in text.splitlines():
    m = RL._COLL_RE.search(line)
    if not m or "-done(" in line: continue
    b = RL._shape_bytes(m.group(1), m.group(2))
    rows.append((b, m.group(3), m.group(1)+"["+m.group(2)+"]", line.strip()[:140]))
rows.sort(reverse=True)
agg = collections.Counter()
for b,op,shp,_ in rows: agg[op]+=b
print("collective result-bytes by op:", {k:f"{v:.3e}" for k,v in agg.items()}, "count", len(rows))
for b,op,shp,l in rows[:15]:
    print(f"  {b/1e6:9.1f}MB {op:20s} {shp:28s} {l[:100]}")
